//! Federated-VO failover scenario: the grid dynamicity the paper motivates
//! ("organizations resources that join or leaves the system at any time").
//!
//! Deploys 3 VOs, runs a query stream while nodes fail and rejoin, and
//! shows that (a) recall is preserved through replica re-planning, (b)
//! the perf-history scheduler shifts load away from degraded regions,
//! (c) response time degrades gracefully rather than failing.
//!
//! ```bash
//! cargo run --release --example federated_failover
//! ```

use anyhow::Result;

use gaps::config::GapsConfig;
use gaps::coordinator::GapsSystem;
use gaps::metrics::sample_queries;
use gaps::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false, &["no-xla"])?;
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 8_000;
    cfg.apply_args(&args)?;
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, using the rust scorer (run `make artifacts`)");
        cfg.search.use_xla = false;
    }

    let mut sys = GapsSystem::deploy(cfg, 12)?;
    let dep_queries = sample_queries(sys.deployment(), 18, 7);
    let total_docs = sys.deployment().locator.total_docs();
    let active = sys.deployment().active.clone();

    println!("phase 1: healthy grid (12 nodes)");
    run_phase(&mut sys, &dep_queries[0..6], total_docs)?;

    let (v1, v2) = (active[5], active[9]);
    println!("\nphase 2: {v1} and {v2} fail");
    sys.fail_node(v1);
    sys.fail_node(v2);
    run_phase(&mut sys, &dep_queries[6..12], total_docs)?;

    println!("\nphase 3: nodes rejoin");
    sys.recover_node(v1);
    sys.recover_node(v2);
    run_phase(&mut sys, &dep_queries[12..18], total_docs)?;

    println!("\nperf-history state after the storm:");
    for &node in &active {
        println!(
            "  {node}: {:>8.0} docs/s ({} samples)",
            sys.perf_db().estimate(node),
            sys.perf_db().samples(node)
        );
    }
    Ok(())
}

fn run_phase(sys: &mut GapsSystem, queries: &[String], total_docs: u64) -> Result<()> {
    let mut worst: f64 = 0.0;
    let mut sum = 0.0;
    for q in queries {
        let r = sys.search(q)?;
        anyhow::ensure!(
            r.docs_scanned == total_docs,
            "coverage lost: {} of {total_docs} docs scanned",
            r.docs_scanned
        );
        worst = worst.max(r.response_s());
        sum += r.response_s();
    }
    println!(
        "  {} queries, full coverage, mean {:.1} ms, worst {:.1} ms",
        queries.len(),
        sum / queries.len() as f64 * 1e3,
        worst * 1e3
    );
    Ok(())
}
