//! End-to-end validation driver: the paper's full
//! evaluation on a real (synthetic-corpus) workload through the production
//! XLA scoring path.
//!
//! Sweeps the grid from 1 to 11 nodes over a fixed corpus, runs the same
//! query mix through GAPS and the traditional baseline on identical
//! deployments, and prints the three paper figures' series (response
//! time, speedup, efficiency) plus the timeline decomposition that
//! explains them.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example grid_scaling
//! cargo run --release --example grid_scaling -- --docs 50000 --queries 16
//! ```

use anyhow::Result;

use gaps::config::GapsConfig;
use gaps::metrics::{run_node_sweep, System};
use gaps::util::bench::Table;
use gaps::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false, &["no-xla"])?;
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 20_000;
    cfg.workload.num_queries = 8;
    cfg.apply_args(&args)?;
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, using the rust scorer (run `make artifacts`)");
        cfg.search.use_xla = false;
    }

    let counts: Vec<usize> = vec![1, 2, 3, 5, 8, 11]
        .into_iter()
        .filter(|&n| n <= cfg.grid.total_nodes())
        .collect();
    eprintln!("{}\nsweeping {counts:?} nodes...\n", cfg.describe());

    let sweep = run_node_sweep(&cfg, &counts)?;
    let serial_g = sweep.serial_response_s(System::Gaps);
    let serial_t = sweep.serial_response_s(System::Traditional);

    println!("== Fig 3: response time (ms) ==");
    let mut t3 = Table::new(&["nodes", "gaps_ms", "trad_ms", "gaps_work", "gaps_net", "gaps_ovh"]);
    for p in &sweep.points {
        t3.row(vec![
            p.nodes.to_string(),
            format!("{:.1}", p.gaps.response_s * 1e3),
            format!("{:.1}", p.traditional.response_s * 1e3),
            format!("{:.1}", p.gaps.work_s * 1e3),
            format!("{:.1}", p.gaps.net_s * 1e3),
            format!("{:.1}", p.gaps.overhead_s * 1e3),
        ]);
    }
    print!("{}", t3.render());
    t3.write_csv("example_fig3");

    println!("\n== Fig 4: speedup ==");
    let mut t4 = Table::new(&["nodes", "gaps", "traditional"]);
    for p in &sweep.points {
        t4.row(vec![
            p.nodes.to_string(),
            format!("{:.2}", p.speedup(serial_g, System::Gaps)),
            format!("{:.2}", p.speedup(serial_t, System::Traditional)),
        ]);
    }
    print!("{}", t4.render());
    t4.write_csv("example_fig4");

    println!("\n== Fig 5: efficiency ==");
    let mut t5 = Table::new(&["nodes", "gaps", "traditional"]);
    for p in &sweep.points {
        t5.row(vec![
            p.nodes.to_string(),
            format!("{:.2}", p.efficiency(serial_g, System::Gaps)),
            format!("{:.2}", p.efficiency(serial_t, System::Traditional)),
        ]);
    }
    print!("{}", t5.render());
    t5.write_csv("example_fig5");

    // Headline check (paper abstract: "enhanced the performance").
    let last = sweep.points.last().unwrap();
    let gain = last.traditional.response_s / last.gaps.response_s;
    println!(
        "\nheadline: at {} nodes GAPS answers {:.2}x faster than traditional \
         ({:.0} ms vs {:.0} ms)",
        last.nodes,
        gain,
        last.gaps.response_s * 1e3,
        last.traditional.response_s * 1e3
    );
    Ok(())
}
