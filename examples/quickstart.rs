//! Quickstart: deploy a small GAPS grid and run a few searches.
//!
//! ```bash
//! make artifacts                       # once (python AOT compile path)
//! cargo run --release --example quickstart
//! cargo run --release --example quickstart -- --no-xla   # rust scorer
//! ```
//!
//! Walks the paper's whole flow: 3 VOs x 4 nodes, a synthetic publication
//! corpus distributed as replicated sub-shards, keyword + multivariate
//! queries through the USI, a node failure, and the perf-history database
//! adapting the execution plan.

use anyhow::Result;

use gaps::config::GapsConfig;
use gaps::coordinator::GapsSystem;
use gaps::search::{Field, SearchRequest};
use gaps::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false, &["no-xla"])?;
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 5_000;
    cfg.apply_args(&args)?;
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, falling back to the rust scorer (run `make artifacts`)");
        cfg.search.use_xla = false;
    }

    println!("== deploying ==\n{}\n", cfg.describe());
    let mut sys = GapsSystem::deploy(cfg, 12)?;
    println!(
        "deployed: {} active nodes, {} data sources, {} docs\n",
        sys.deployment().active.len(),
        sys.deployment().locator.len(),
        sys.deployment().locator.total_docs()
    );

    // --- keyword search -------------------------------------------------
    println!("== keyword search ==");
    let (rendered, timing) = gaps::usi::one_shot(&mut sys, "grid distributed search")?;
    print!("{rendered}");
    println!(
        "usi overhead: {:.3} ms ({:.2}% of response)\n",
        timing.interface_s * 1e3,
        timing.interface_fraction() * 100.0
    );

    // --- multivariate search --------------------------------------------
    println!("== multivariate search (field + year filters) ==");
    let (rendered, _) = gaps::usi::one_shot(&mut sys, "title:grid scheduling year:2005..2012")?;
    print!("{rendered}");
    println!();

    // --- typed request builder + batched execution ----------------------
    println!("== typed requests, one batched fan-out ==");
    let requests = vec![
        SearchRequest::new("\"grid computing\" -cloud").top_k(3),
        SearchRequest::new("storage AND replication").top_k(3),
        SearchRequest::new("scheduling")
            .require(Field::Venue, "conference")
            .year(2005..=2012)
            .explain(true),
    ];
    for (req, result) in requests.iter().zip(sys.search_batch(&requests)) {
        println!("-- {:?} --", req.query);
        match result {
            Ok(resp) => print!("{}", gaps::usi::format_response(&resp)),
            Err(e) => println!("error [{}]: {e}", e.kind()),
        }
    }
    println!();

    // --- grid dynamicity -------------------------------------------------
    let victim = sys.deployment().active[3];
    println!("== failing {victim} and searching again ==");
    sys.fail_node(victim);
    let resp = sys.search("massive academic publications")?;
    println!(
        "still scanned {} docs over {} jobs (replicas covered {victim})\n",
        resp.docs_scanned, resp.jobs
    );
    sys.recover_node(victim);

    // --- perf-history adaptation ------------------------------------------
    println!("== perf-history database after the session ==");
    for &node in &sys.deployment().active.clone()[..4] {
        println!(
            "  {node}: estimated {:>8.0} docs/s ({} samples)",
            sys.perf_db().estimate(node),
            sys.perf_db().samples(node),
        );
    }
    println!(
        "\njob table: {} created, {} completed",
        sys.query_manager().total_jobs(),
        sys.query_manager().completed_jobs()
    );
    Ok(())
}
