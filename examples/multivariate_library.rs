//! Digital-library scenario: the multivariate search type the USI offers
//! (paper §III.4), driven as a realistic session — a researcher narrowing
//! a literature search by field and year over a federated repository.
//!
//! ```bash
//! cargo run --release --example multivariate_library
//! ```

use anyhow::Result;

use gaps::config::GapsConfig;
use gaps::coordinator::GapsSystem;
use gaps::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false, &["no-xla"])?;
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 10_000;
    cfg.search.top_k = 5;
    cfg.apply_args(&args)?;
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, using the rust scorer (run `make artifacts`)");
        cfg.search.use_xla = false;
    }

    let mut sys = GapsSystem::deploy(cfg, 9)?;

    // A narrowing session: broad keyword -> field-scoped -> year-bounded.
    let session = [
        ("broad keyword", "grid scheduling".to_string()),
        ("field-scoped", "title:grid scheduling".to_string()),
        ("year-bounded", "title:grid scheduling year:2008..2014".to_string()),
        ("author-scoped", "authors:zhang grid".to_string()),
        ("venue-scoped", "venue:conference distributed storage".to_string()),
    ];

    for (label, query) in &session {
        println!("== {label}: {query:?} ==");
        match gaps::usi::one_shot(&mut sys, query) {
            Ok((rendered, _)) => print!("{rendered}\n"),
            Err(e) => println!("error: {e}\n"),
        }
    }

    // Verify the filters actually bound the result set.
    let narrow = sys.search("title:grid scheduling year:2008..2014")?;
    for h in &narrow.hits {
        let p = sys.deployment().publication(h.global_id).unwrap();
        assert!((2008..=2014).contains(&p.year), "year filter violated");
    }
    println!(
        "verified: {} year-bounded hits all fall in 2008..2014",
        narrow.hits.len()
    );
    Ok(())
}
