#!/usr/bin/env python3
"""Fail on broken intra-repo links in markdown files.

Usage: python3 tools/check_links.py README.md ARCHITECTURE.md ...

Checks every inline markdown link `[text](target)`:
  * external targets (http://, https://, mailto:) are skipped;
  * pure-anchor targets (#section) are checked against the headings of
    the same file;
  * everything else must resolve (relative to the linking file) to an
    existing file or directory; a #anchor suffix on a .md target is
    checked against that file's headings.

CI runs this over the top-level docs so refactors cannot silently orphan
the documentation graph.
"""

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")


def heading_anchors(path: Path) -> set:
    """GitHub-style anchors for every markdown heading in `path`."""
    anchors = set()
    in_code = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code or not line.startswith("#"):
            continue
        title = line.lstrip("#").strip()
        # GitHub slugging: lowercase, drop non-alphanumerics except
        # spaces/hyphens, spaces -> hyphens.
        slug = re.sub(r"[^\w\- ]", "", title.lower()).replace(" ", "-")
        anchors.add(slug)
    return anchors


def check_file(path: Path) -> list:
    errors = []
    text = path.read_text(encoding="utf-8")
    # Ignore links inside fenced code blocks (curl transcripts etc).
    stripped = re.sub(r"```.*?```", "", text, flags=re.S)
    for match in LINK_RE.finditer(stripped):
        target = match.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if target[1:] not in heading_anchors(path):
                errors.append(f"{path}: broken anchor {target}")
            continue
        file_part, _, anchor = target.partition("#")
        resolved = (path.parent / file_part).resolve()
        if not resolved.exists():
            errors.append(f"{path}: broken link {target} (missing {resolved})")
            continue
        if anchor and resolved.suffix == ".md":
            if anchor not in heading_anchors(resolved):
                errors.append(f"{path}: broken anchor {target}")
    return errors


def main(argv: list) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    errors = []
    for name in argv:
        path = Path(name)
        if not path.exists():
            errors.append(f"{name}: file does not exist")
            continue
        errors.extend(check_file(path))
    for e in errors:
        print(f"BROKEN: {e}", file=sys.stderr)
    if not errors:
        print(f"link check OK: {len(argv)} file(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
