#!/usr/bin/env python3
"""Validate a Prometheus text-exposition scrape.

Usage: python3 tools/check_metrics.py scrape.txt [required_family ...]

Structural checks (any failure exits non-zero):
  * every sample belongs to a family declared with `# TYPE` (histogram
    samples may carry a `_bucket`/`_sum`/`_count` suffix) and every
    family has a `# HELP` line;
  * the `# TYPE` kind is counter, gauge, or histogram;
  * label keys are consistent across every sample of a family
    (ignoring the histogram `le` label);
  * counter values are non-negative numbers, all values parse;
  * each histogram series has cumulative, bound-ordered buckets
    terminated by `le="+Inf"` whose value equals the `_count` sample,
    and a `_sum` sample.

Optional trailing arguments name families that must be present — CI's
scrape smoke passes the core `gaps_*` surface so a refactor cannot
silently drop it.
"""

import re
import sys
from collections import defaultdict

SAMPLE_RE = re.compile(r"^([A-Za-z_:][A-Za-z0-9_:]*)(\{([^}]*)\})? (\S+)$")
LABEL_RE = re.compile(r'([A-Za-z_][A-Za-z0-9_]*)="([^"]*)"')
KINDS = {"counter", "gauge", "histogram"}


def fail(msg):
    print(f"check_metrics: {msg}", file=sys.stderr)
    sys.exit(1)


def parse_labels(text):
    if not text:
        return []
    pairs = LABEL_RE.findall(text)
    # The reconstructed pair list must cover the whole label body, or the
    # scrape contains something the regex silently skipped.
    rebuilt = ",".join(f'{k}="{v}"' for k, v in pairs)
    if rebuilt != text:
        fail(f"unparseable label set {text!r}")
    return pairs


def main():
    if len(sys.argv) < 2:
        fail("usage: check_metrics.py scrape.txt [required_family ...]")
    text = open(sys.argv[1], encoding="utf-8").read()
    required = sys.argv[2:]

    kinds = {}
    helps = set()
    samples = []
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# HELP "):
            helps.add(line.split()[2])
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) < 4:
                fail(f"malformed TYPE line {line!r}")
            name, kind = parts[2], parts[3]
            if kind not in KINDS:
                fail(f"unknown kind {kind!r} for {name!r}")
            if name in kinds:
                fail(f"duplicate TYPE for {name!r}")
            kinds[name] = kind
            continue
        if line.startswith("#"):
            fail(f"unknown comment line {line!r}")
        m = SAMPLE_RE.match(line)
        if not m:
            fail(f"malformed sample line {line!r}")
        name, _, labels, value = m.groups()
        try:
            value = float(value)
        except ValueError:
            fail(f"non-numeric value in {line!r}")
        samples.append((name, parse_labels(labels), value))

    def family_of(sample_name):
        if sample_name in kinds:
            return sample_name
        for suffix in ("_bucket", "_sum", "_count"):
            base = sample_name.removesuffix(suffix)
            if base != sample_name and base in kinds:
                return base
        fail(f"sample {sample_name!r} has no TYPE declaration")

    by_family = defaultdict(list)
    for name, labels, value in samples:
        family = family_of(name)
        if family not in helps:
            fail(f"family {family!r} has no HELP line")
        kind = kinds[family]
        if kind != "histogram":
            if name != family:
                fail(f"suffixed sample {name!r} on a {kind} family")
            if kind == "counter" and value < 0:
                fail(f"negative counter {name!r}: {value}")
        by_family[family].append((name, labels, value))

    for family, kind in kinds.items():
        rows = by_family.get(family)
        if not rows:
            fail(f"family {family!r} declared but never sampled")
        keysets = {
            tuple(sorted(k for k, _ in labels if k != "le")) for _, labels, _ in rows
        }
        if len(keysets) != 1:
            fail(f"family {family!r} has divergent label keys: {keysets}")
        if kind == "histogram":
            check_histogram(family, rows)

    for family in required:
        if family not in by_family:
            fail(f"required family {family!r} missing from the scrape")

    print(
        f"check_metrics: OK — {len(kinds)} families, {len(samples)} samples"
    )


def check_histogram(family, rows):
    series = defaultdict(lambda: {"buckets": [], "sum": None, "count": None})
    for name, labels, value in rows:
        key = tuple(sorted((k, v) for k, v in labels if k != "le"))
        s = series[key]
        if name.endswith("_bucket"):
            le = dict(labels).get("le")
            if le is None:
                fail(f"{family}: bucket without le label")
            bound = float("inf") if le == "+Inf" else float(le)
            s["buckets"].append((bound, value))
        elif name.endswith("_sum"):
            s["sum"] = value
        elif name.endswith("_count"):
            s["count"] = value
        else:
            fail(f"{family}: stray histogram sample {name!r}")
    for key, s in series.items():
        where = f"{family}{{{dict(key)}}}"
        if s["count"] is None:
            fail(f"{where}: no _count sample")
        if s["sum"] is None:
            fail(f"{where}: no _sum sample")
        if not s["buckets"]:
            fail(f"{where}: no buckets")
        prev_bound, prev_cum = float("-inf"), -1.0
        for bound, cum in s["buckets"]:
            if bound <= prev_bound:
                fail(f"{where}: bucket bounds out of order")
            if cum < prev_cum:
                fail(f"{where}: buckets not cumulative")
            prev_bound, prev_cum = bound, cum
        last_bound, last_cum = s["buckets"][-1]
        if last_bound != float("inf"):
            fail(f'{where}: no le="+Inf" terminator')
        if last_cum != s["count"]:
            fail(f"{where}: +Inf bucket {last_cum} != _count {s['count']}")


if __name__ == "__main__":
    main()
