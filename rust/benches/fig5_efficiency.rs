//! Paper Figure 5 — "Efficiency scales as the increase of size."
//!
//! Efficiency = speedup / nodes. Paper series (shape targets):
//!   GAPS:        0.88 @ 2 nodes decreasing to 0.27 @ 11;
//!   traditional: 0.62 @ 2 nodes decreasing to 0.17 @ 11;
//!   GAPS +43% over traditional @ 2 nodes, +100% @ 11.
//!
//! Run: `cargo bench --bench fig5_efficiency`

use gaps::config::GapsConfig;
use gaps::metrics::{cached_node_sweep, System};
use gaps::util::bench::Table;

/// Paper-reported reference points (node count, gaps, traditional).
const PAPER: &[(usize, f64, f64)] = &[(2, 0.88, 0.62), (11, 0.27, 0.17)];

fn main() {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = std::env::var("GAPS_BENCH_DOCS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60_000);
    cfg.workload.num_queries = std::env::var("GAPS_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, using rust scorer");
        cfg.search.use_xla = false;
    }
    let counts = [1usize, 2, 3, 5, 8, 11];
    let sweep = cached_node_sweep(&cfg, &counts).expect("sweep failed");
    let serial_g = sweep.serial_response_s(System::Gaps);
    let serial_t = sweep.serial_response_s(System::Traditional);

    println!("\n== Figure 5: efficiency vs nodes ==");
    let mut t = Table::new(&["nodes", "gaps", "traditional", "paper_gaps", "paper_trad"]);
    for p in &sweep.points {
        let paper = PAPER.iter().find(|(n, _, _)| *n == p.nodes);
        t.row(vec![
            p.nodes.to_string(),
            format!("{:.2}", p.efficiency(serial_g, System::Gaps)),
            format!("{:.2}", p.efficiency(serial_t, System::Traditional)),
            paper.map(|(_, g, _)| format!("{g:.2}")).unwrap_or_else(|| "-".into()),
            paper.map(|(_, _, tr)| format!("{tr:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", t.render());
    t.write_csv("fig5_efficiency");

    let gaps_at = |n: usize| {
        sweep
            .points
            .iter()
            .find(|p| p.nodes == n)
            .map(|p| p.efficiency(serial_g, System::Gaps))
            .unwrap()
    };
    let trad_at = |n: usize| {
        sweep
            .points
            .iter()
            .find(|p| p.nodes == n)
            .map(|p| p.efficiency(serial_t, System::Traditional))
            .unwrap()
    };
    let mut ok = true;
    // 1. Efficiency decreases with node count for both systems.
    if gaps_at(11) >= gaps_at(2) {
        println!("SHAPE FAIL: gaps efficiency not decreasing");
        ok = false;
    }
    if trad_at(11) >= trad_at(2) {
        println!("SHAPE FAIL: traditional efficiency not decreasing");
        ok = false;
    }
    // 2. GAPS is more efficient than traditional at the paper's endpoints.
    for n in [2usize, 11] {
        if gaps_at(n) <= trad_at(n) {
            println!("SHAPE FAIL: n={n} gaps eff {:.2} !> trad {:.2}", gaps_at(n), trad_at(n));
            ok = false;
        }
    }
    // 3. Efficiencies live in (0, 1].
    for p in &sweep.points {
        let e = p.efficiency(serial_g, System::Gaps);
        if !(0.0..=1.2).contains(&e) {
            println!("SHAPE FAIL: n={} efficiency {e:.2} outside (0, 1.2]", p.nodes);
            ok = false;
        }
    }
    println!(
        "\ngaps over traditional: {:+.0}% @2, {:+.0}% @11 (paper: +43%, +100%)",
        (gaps_at(2) / trad_at(2) - 1.0) * 100.0,
        (gaps_at(11) / trad_at(11) - 1.0) * 100.0
    );
    assert!(ok, "figure 5 shape checks failed");
    println!("fig5 shape checks OK");
}
