//! Paper Figure 3 — "Response time scales as the increase of size."
//!
//! Regenerates the response-time-vs-nodes series for GAPS and the
//! traditional search over the default corpus. Paper claims to check
//! (shape, not absolute numbers — our substrate is a simulated fabric on
//! one host, not the authors' 2005-era campus grid):
//!
//! * GAPS is faster than traditional at every node count;
//! * the paper quantifies the gap as 54%–100% ("remains to be faster
//!   than the traditional search with 60% while other response time
//!   reaches 100%, and some response time decreases to reach 54%");
//! * response time dips with small node counts, then coordination
//!   overheads flatten / reverse the gains past the sweet spot.
//!
//! Run: `cargo bench --bench fig3_response_time`
//! Env: GAPS_BENCH_DOCS / GAPS_BENCH_QUERIES to resize the workload.

use gaps::config::GapsConfig;
use gaps::metrics::cached_node_sweep;
use gaps::util::bench::Table;

fn main() {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = std::env::var("GAPS_BENCH_DOCS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60_000);
    cfg.workload.num_queries = std::env::var("GAPS_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, using rust scorer");
        cfg.search.use_xla = false;
    }
    let counts = [1usize, 2, 3, 5, 8, 11];
    eprintln!(
        "fig3: {} docs, {} queries, sweeping {counts:?}",
        cfg.workload.num_docs, cfg.workload.num_queries
    );

    let sweep = cached_node_sweep(&cfg, &counts).expect("sweep failed");

    println!("\n== Figure 3: response time vs nodes ==");
    let mut t = Table::new(&[
        "nodes",
        "gaps_ms",
        "trad_ms",
        "trad/gaps",
        "gaps_work_ms",
        "gaps_net_ms",
        "gaps_ovh_ms",
    ]);
    for p in &sweep.points {
        t.row(vec![
            p.nodes.to_string(),
            format!("{:.1}", p.gaps.response_s * 1e3),
            format!("{:.1}", p.traditional.response_s * 1e3),
            format!("{:.2}x", p.traditional.response_s / p.gaps.response_s),
            format!("{:.1}", p.gaps.work_s * 1e3),
            format!("{:.1}", p.gaps.net_s * 1e3),
            format!("{:.1}", p.gaps.overhead_s * 1e3),
        ]);
    }
    print!("{}", t.render());
    t.write_csv("fig3_response_time");

    // Shape checks (reported, and enforced so regressions fail the bench).
    let mut ok = true;
    for p in &sweep.points {
        if p.gaps.response_s >= p.traditional.response_s {
            println!("SHAPE FAIL: n={} gaps not faster", p.nodes);
            ok = false;
        }
    }
    let gains: Vec<f64> = sweep
        .points
        .iter()
        .map(|p| (p.traditional.response_s / p.gaps.response_s - 1.0) * 100.0)
        .collect();
    println!(
        "\ngaps faster by {:.0}%..{:.0}% across the sweep (paper reports 54%..100%)",
        gains.iter().cloned().fold(f64::INFINITY, f64::min),
        gains.iter().cloned().fold(0.0, f64::max),
    );
    assert!(ok, "figure 3 shape checks failed");
    println!("fig3 shape checks OK");
}
