//! Paper Figure 3 — "Response time scales as the increase of size."
//!
//! Regenerates the response-time-vs-nodes series for GAPS and the
//! traditional search over the default corpus. Paper claims to check
//! (shape, not absolute numbers — our substrate is a simulated fabric on
//! one host, not the authors' 2005-era campus grid):
//!
//! * GAPS is faster than traditional at every node count;
//! * the paper quantifies the gap as 54%–100% ("remains to be faster
//!   than the traditional search with 60% while other response time
//!   reaches 100%, and some response time decreases to reach 54%");
//! * response time dips with small node counts, then coordination
//!   overheads flatten / reverse the gains past the sweet spot.
//!
//! Additionally this bench tracks the retrieval hot path across PRs in
//! machine-readable `BENCH_retrieval.json`:
//!
//! * **micro** — per-query retrieve time on a large shard, CSR arena +
//!   scratch + bounded heap vs the naive HashMap reference (the seed
//!   implementation, kept as `retrieve_reference`);
//! * **fanout** — end-to-end `search()` wall time at 4 nodes, parallel
//!   gridpool dispatch vs serial (`workers = 1`);
//! * **sweep** — the Fig 3 response-time percentiles.
//!
//! Run: `cargo bench --bench fig3_response_time`
//! Env: GAPS_BENCH_DOCS / GAPS_BENCH_QUERIES resize the sweep workload,
//!      GAPS_BENCH_MICRO_DOCS resizes the micro-benchmark shard.

use std::sync::Arc;
use std::time::Instant;

use gaps::config::GapsConfig;
use gaps::coordinator::{Deployment, GapsSystem};
use gaps::corpus::{CorpusGenerator, CorpusSpec};
use gaps::index::{RetrievalScratch, Shard};
use gaps::metrics::{cached_node_sweep, sample_queries};
use gaps::search::{Query, SearchRequest};
use gaps::util::bench::Table;
use gaps::util::json::Json;
use gaps::util::rng::Rng;
use gaps::util::stats::Summary;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Micro-benchmark: per-query OR-retrieve cost on one large shard,
/// 4-term queries, CSR+scratch vs the naive HashMap reference.
fn bench_retrieval_micro(features: usize) -> Json {
    let docs = env_usize("GAPS_BENCH_MICRO_DOCS", 100_000) as u64;
    let num_queries = 64usize;
    let rounds = 5usize;
    eprintln!("micro: analyzing {docs}-doc shard (F={features})...");
    let gen = CorpusGenerator::new(CorpusSpec { num_docs: docs, ..CorpusSpec::default() });
    let shard = Shard::build(0, gen.generate_range(0, docs), features);

    // 4-term queries sampled from corpus topics (realistic bucket skew).
    let mut rng = Rng::new(0xF16_3);
    let mut queries: Vec<Vec<u32>> = Vec::with_capacity(num_queries);
    let mut attempts = 0usize;
    while queries.len() < num_queries {
        attempts += 1;
        assert!(attempts <= 100_000, "corpus yields no usable queries — check CorpusSpec");
        let raw = gen.sample_query(&mut rng);
        let Ok(q) = Query::parse(&raw, features) else { continue };
        if q.buckets.len() >= 4 {
            queries.push(q.buckets[..4].to_vec());
        } else if attempts > 10_000 && !q.buckets.is_empty() {
            // Degenerate corpora: accept shorter queries rather than spin.
            queries.push(q.buckets.clone());
        }
    }

    let max_candidates = 1024usize;
    let mut scratch = RetrievalScratch::new();
    // Warmup both paths (sizes the scratch, faults pages in).
    for q in &queries {
        shard.inverted.retrieve_into(q, max_candidates, &mut scratch);
        std::hint::black_box(shard.inverted.retrieve_reference(q, max_candidates));
    }

    let (mut csr, mut naive) = (Summary::new(), Summary::new());
    for _ in 0..rounds {
        for q in &queries {
            let t = Instant::now();
            shard.inverted.retrieve_into(q, max_candidates, &mut scratch);
            csr.add(t.elapsed().as_secs_f64());
            std::hint::black_box(scratch.hits().len());

            let t = Instant::now();
            let r = shard.inverted.retrieve_reference(q, max_candidates);
            naive.add(t.elapsed().as_secs_f64());
            std::hint::black_box(r.len());
        }
    }

    let speedup = naive.p50() / csr.p50().max(1e-12);
    println!(
        "\n== retrieval micro ({docs} docs, 4-term queries) ==\n\
         csr   p50={:8.1}us p95={:8.1}us\n\
         naive p50={:8.1}us p95={:8.1}us\n\
         speedup(p50) = {speedup:.2}x  (target >= 3x)",
        csr.p50() * 1e6,
        csr.percentile(95.0) * 1e6,
        naive.p50() * 1e6,
        naive.percentile(95.0) * 1e6,
    );

    Json::obj(vec![
        ("docs", Json::from(docs)),
        ("queries", Json::from(num_queries)),
        ("terms_per_query", Json::from(4usize)),
        ("max_candidates", Json::from(max_candidates)),
        ("csr_p50_us", Json::from(csr.p50() * 1e6)),
        ("csr_p95_us", Json::from(csr.percentile(95.0) * 1e6)),
        ("naive_p50_us", Json::from(naive.p50() * 1e6)),
        ("naive_p95_us", Json::from(naive.percentile(95.0) * 1e6)),
        ("speedup_p50", Json::from(speedup)),
    ])
}

/// End-to-end fan-out: `search()` wall time at 4 nodes, parallel
/// gridpool dispatch vs serial (workers = 1), same deployment bits.
fn bench_fanout(cfg: &GapsConfig) -> Json {
    let nodes = 4usize;
    let dep = Arc::new(Deployment::build(cfg, nodes).expect("deploy"));
    let queries = sample_queries(&dep, cfg.workload.num_queries.max(8), 0xFA11);

    let measure = |workers: usize| -> Summary {
        let mut c = cfg.clone();
        c.search.workers = workers;
        // The XLA path serializes through the coordinator thread (PJRT
        // handles are !Send) and would ignore the workers knob — this
        // comparison only means something on the rust-scorer path.
        c.search.use_xla = false;
        let mut sys = GapsSystem::from_deployment(c, Arc::clone(&dep)).expect("system");
        for q in &queries {
            sys.search(q).expect("warmup search");
        }
        let mut wall = vec![f64::INFINITY; queries.len()];
        for _ in 0..3 {
            for (i, q) in queries.iter().enumerate() {
                let t = Instant::now();
                std::hint::black_box(sys.search(q).expect("search"));
                wall[i] = wall[i].min(t.elapsed().as_secs_f64());
            }
        }
        let mut s = Summary::new();
        for w in wall {
            s.add(w);
        }
        s
    };

    let mut serial = measure(1);
    let auto_workers = cfg.search.effective_workers();
    let mut parallel = measure(0);
    let speedup = serial.p50() / parallel.p50().max(1e-12);
    println!(
        "\n== shard fan-out ({nodes} nodes, {} workers) ==\n\
         serial   p50={:8.2}ms p95={:8.2}ms\n\
         parallel p50={:8.2}ms p95={:8.2}ms\n\
         speedup(p50) = {speedup:.2}x  (target > 1.5x on >=4-core hosts)",
        auto_workers,
        serial.p50() * 1e3,
        serial.percentile(95.0) * 1e3,
        parallel.p50() * 1e3,
        parallel.percentile(95.0) * 1e3,
    );

    Json::obj(vec![
        ("nodes", Json::from(nodes)),
        ("workers", Json::from(auto_workers)),
        ("serial_p50_ms", Json::from(serial.p50() * 1e3)),
        ("serial_p95_ms", Json::from(serial.percentile(95.0) * 1e3)),
        ("parallel_p50_ms", Json::from(parallel.p50() * 1e3)),
        ("parallel_p95_ms", Json::from(parallel.percentile(95.0) * 1e3)),
        ("speedup_p50", Json::from(speedup)),
    ])
}

/// Batched QPS: one `search_batch` of N typed requests (one plan + one
/// fan-out round + Q>1 scoring rows) vs N sequential `search_request`
/// calls over the same deployment bits.
fn bench_batch(cfg: &GapsConfig) -> Json {
    let nodes = 4usize;
    let dep = Arc::new(Deployment::build(cfg, nodes).expect("deploy"));
    let queries = sample_queries(&dep, cfg.workload.num_queries.max(16), 0xBA7C);
    let requests: Vec<SearchRequest> =
        queries.iter().map(|q| SearchRequest::new(q.clone())).collect();
    let n = requests.len();

    let mut c = cfg.clone();
    c.search.use_xla = false;
    let mut sys = GapsSystem::from_deployment(c, Arc::clone(&dep)).expect("system");
    // Warm both paths.
    for r in sys.search_batch(&requests) {
        r.expect("warmup batch");
    }
    for r in &requests {
        sys.search_request(r).expect("warmup serial");
    }

    let rounds = 3usize;
    let mut serial_s = f64::INFINITY;
    let mut batch_s = f64::INFINITY;
    for _ in 0..rounds {
        let t = Instant::now();
        for r in &requests {
            std::hint::black_box(sys.search_request(r).expect("serial search"));
        }
        serial_s = serial_s.min(t.elapsed().as_secs_f64());

        let t = Instant::now();
        for r in std::hint::black_box(sys.search_batch(&requests)) {
            r.expect("batched search");
        }
        batch_s = batch_s.min(t.elapsed().as_secs_f64());
    }
    let serial_qps = n as f64 / serial_s.max(1e-12);
    let batch_qps = n as f64 / batch_s.max(1e-12);
    println!(
        "\n== batched execution ({n} queries, {nodes} nodes) ==\n\
         serial  {:8.2} ms total  ({serial_qps:8.1} qps)\n\
         batched {:8.2} ms total  ({batch_qps:8.1} qps)\n\
         speedup = {:.2}x",
        serial_s * 1e3,
        batch_s * 1e3,
        batch_qps / serial_qps.max(1e-12),
    );

    Json::obj(vec![
        ("nodes", Json::from(nodes)),
        ("queries", Json::from(n)),
        ("serial_ms", Json::from(serial_s * 1e3)),
        ("batch_ms", Json::from(batch_s * 1e3)),
        ("serial_qps", Json::from(serial_qps)),
        ("batch_qps", Json::from(batch_qps)),
        ("speedup", Json::from(batch_qps / serial_qps.max(1e-12))),
    ])
}

fn main() {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = env_usize("GAPS_BENCH_DOCS", 60_000) as u64;
    cfg.workload.num_queries = env_usize("GAPS_BENCH_QUERIES", 10);
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, using rust scorer");
        cfg.search.use_xla = false;
    }
    let counts = [1usize, 2, 3, 5, 8, 11];
    eprintln!(
        "fig3: {} docs, {} queries, sweeping {counts:?}",
        cfg.workload.num_docs, cfg.workload.num_queries
    );

    let sweep = cached_node_sweep(&cfg, &counts).expect("sweep failed");

    println!("\n== Figure 3: response time vs nodes ==");
    let mut t = Table::new(&[
        "nodes",
        "gaps_ms",
        "trad_ms",
        "trad/gaps",
        "gaps_work_ms",
        "gaps_net_ms",
        "gaps_ovh_ms",
    ]);
    for p in &sweep.points {
        t.row(vec![
            p.nodes.to_string(),
            format!("{:.1}", p.gaps.response_s * 1e3),
            format!("{:.1}", p.traditional.response_s * 1e3),
            format!("{:.2}x", p.traditional.response_s / p.gaps.response_s),
            format!("{:.1}", p.gaps.work_s * 1e3),
            format!("{:.1}", p.gaps.net_s * 1e3),
            format!("{:.1}", p.gaps.overhead_s * 1e3),
        ]);
    }
    print!("{}", t.render());
    t.write_csv("fig3_response_time");

    // Retrieval-core trajectory (micro + fan-out + batch), tracked across PRs.
    let micro = bench_retrieval_micro(cfg.search.features);
    let fanout = bench_fanout(&cfg);
    let batch = bench_batch(&cfg);
    let micro_speedup = micro.get("speedup_p50").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let fan_speedup = fanout.get("speedup_p50").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let fan_workers = fanout.get("workers").and_then(|v| v.as_i64()).unwrap_or(1);
    let sweep_json = Json::obj(vec![
        ("nodes", Json::Arr(sweep.points.iter().map(|p| Json::from(p.nodes)).collect())),
        (
            "gaps_p50_ms",
            Json::Arr(sweep.points.iter().map(|p| Json::from(p.gaps.p50_s * 1e3)).collect()),
        ),
        (
            "gaps_p99_ms",
            Json::Arr(sweep.points.iter().map(|p| Json::from(p.gaps.p99_s * 1e3)).collect()),
        ),
        (
            "trad_p50_ms",
            Json::Arr(
                sweep.points.iter().map(|p| Json::from(p.traditional.p50_s * 1e3)).collect(),
            ),
        ),
    ]);
    let report = Json::obj(vec![
        ("bench", Json::str("retrieval")),
        ("micro", micro),
        ("fanout", fanout),
        ("batch", batch),
        ("sweep", sweep_json),
    ]);
    let path = "BENCH_retrieval.json";
    std::fs::write(path, report.to_string_pretty()).expect("write BENCH_retrieval.json");
    println!("\nwrote {path}");

    // Checks are enforced on real bench runs so regressions fail loudly;
    // GAPS_BENCH_NO_ASSERT=1 (CI smoke on shared runners, tiny query
    // counts) reports without asserting — wall-clock comparisons from a
    // handful of samples on a noisy host must not flake CI.
    let enforce = std::env::var("GAPS_BENCH_NO_ASSERT").is_err();

    // Perf-target checks for this PR's hot-path work (conservative
    // floors below the stated targets, to absorb host variance).
    if enforce {
        assert!(
            micro_speedup >= 2.0,
            "retrieval micro speedup regressed: {micro_speedup:.2}x (floor 2x, target 3x)"
        );
    }
    if enforce && fan_workers >= 4 {
        assert!(
            fan_speedup > 1.2,
            "fan-out speedup regressed: {fan_speedup:.2}x with {fan_workers} workers \
             (floor 1.2x, target 1.5x)"
        );
    }

    // Shape checks (reported, and enforced so regressions fail the bench).
    let mut ok = true;
    for p in &sweep.points {
        if p.gaps.response_s >= p.traditional.response_s {
            println!("SHAPE FAIL: n={} gaps not faster", p.nodes);
            ok = false;
        }
    }
    let gains: Vec<f64> = sweep
        .points
        .iter()
        .map(|p| (p.traditional.response_s / p.gaps.response_s - 1.0) * 100.0)
        .collect();
    println!(
        "\ngaps faster by {:.0}%..{:.0}% across the sweep (paper reports 54%..100%)",
        gains.iter().cloned().fold(f64::INFINITY, f64::min),
        gains.iter().cloned().fold(0.0, f64::max),
    );
    if enforce {
        assert!(ok, "figure 3 shape checks failed");
        println!("fig3 shape checks OK");
    } else if !ok {
        println!("fig3 shape checks failed (not enforced: GAPS_BENCH_NO_ASSERT set)");
    }
}
