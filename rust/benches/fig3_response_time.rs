//! Paper Figure 3 — "Response time scales as the increase of size."
//!
//! Regenerates the response-time-vs-nodes series for GAPS and the
//! traditional search over the default corpus. Paper claims to check
//! (shape, not absolute numbers — our substrate is a simulated fabric on
//! one host, not the authors' 2005-era campus grid):
//!
//! * GAPS is faster than traditional at every node count;
//! * the paper quantifies the gap as 54%–100% ("remains to be faster
//!   than the traditional search with 60% while other response time
//!   reaches 100%, and some response time decreases to reach 54%");
//! * response time dips with small node counts, then coordination
//!   overheads flatten / reverse the gains past the sweet spot.
//!
//! Additionally this bench tracks the retrieval hot path across PRs in
//! machine-readable `BENCH_retrieval.json`:
//!
//! * **micro** — per-query retrieve time on a large shard, block-max
//!   WAND + scratch vs the naive HashMap reference (the seed
//!   implementation semantics, kept as `retrieve_reference`);
//! * **fanout** — end-to-end `search()` wall time at 4 nodes, parallel
//!   gridpool dispatch vs serial (`workers = 1`);
//! * **serve** — multi-user closed-loop QPS: 8 concurrent users through
//!   the admission queue (coalesced `search_batch` rounds on the
//!   resident gridpool) vs a single closed-loop user, with the
//!   admission counters (rounds formed, average/largest batch) and a
//!   histogram-sourced latency series: p50/p95/p99 interpolated
//!   PromQL-style from the stack's own `gaps_request_seconds`
//!   histogram — the same cells `GET /metrics` exposes — rather than a
//!   bench-side stopwatch;
//! * **cache** — fixed-seed zipfian repeat-query workload through the
//!   serving stack: result-cache hit rate, hot-query p50 cached vs the
//!   identical stack with the cache disabled, plan-cache counters, and
//!   a deterministic single-flight burst of identical co-arrivals.
//!   Written to `BENCH_cache.json` and gated against the committed
//!   baseline's `cache` section — the hit rate is a deterministic
//!   function of the fixed seed, so a >5% relative regression fails
//!   even under `GAPS_BENCH_NO_ASSERT`;
//! * **availability** — fixed-seed chaos schedules replayed against a
//!   fault-free oracle: success/degraded/error rates and failover retry
//!   counters, with structural invariants asserted even under
//!   `GAPS_BENCH_NO_ASSERT`;
//! * **persistence** — cold boot (generate + analyze + index) vs
//!   snapshot load of the same deployment, plus live ingestion
//!   throughput (docs/s through `GapsSystem::ingest`, seals included).
//!   The parity checks inside it (snapshot-booted node bit-identical to
//!   the writer) are **structural** and asserted even under
//!   `GAPS_BENCH_NO_ASSERT`;
//! * **traffic** — heavy-traffic closed-loop serving over real HTTP: a
//!   ladder of keep-alive user counts (up to ~200 simulated users)
//!   against the sharded executor behind the bounded handler pool, for
//!   1 and 2 shards. Reports the p50/p95/p99 latency ladder, sustained
//!   QPS, the saturation knee, and the shed-rate series; written to
//!   `BENCH_traffic.json` and into the `traffic` section here. The
//!   serving-shape invariants (no shedding below the handler bound,
//!   typed shed + `Retry-After` beyond it, multi-shard QPS exceeding
//!   single-shard at equal offered load) are **structural** and
//!   asserted even under `GAPS_BENCH_NO_ASSERT`; the workload pins are
//!   gated against the committed baseline so the series stays
//!   comparable across PRs;
//! * **sweep** — the Fig 3 response-time percentiles;
//! * **counters** — deterministic block-max pruning counters on a
//!   *fixed* workload (seeds, sizes, and k are constants — deliberately
//!   not env-resizable), written to `BENCH_counters.json` and gated
//!   against the committed `BENCH_baseline.json`. Unlike the wall-clock
//!   series, the counter gate runs even under `GAPS_BENCH_NO_ASSERT`:
//!   integer counters at fixed seeds cannot flake on shared runners, so
//!   CI holds the line on pruning effectiveness there.
//!
//! Run: `cargo bench --bench fig3_response_time`
//! Env: GAPS_BENCH_DOCS / GAPS_BENCH_QUERIES resize the sweep workload,
//!      GAPS_BENCH_MICRO_DOCS resizes the micro-benchmark shard,
//!      GAPS_BENCH_BASELINE points at an alternate baseline file,
//!      GAPS_BENCH_WRITE_BASELINE=1 skips the counter and cache gates
//!      and rewrites the baseline file (both sections) from this run
//!      (commit the result after intentional retrieval or caching
//!      changes).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use gaps::config::GapsConfig;
use gaps::coordinator::{counters_to_json, Deployment, GapsSystem};
use gaps::fault::ChaosPlan;
use gaps::corpus::{CorpusGenerator, CorpusSpec};
use gaps::index::{RetrievalCounters, RetrievalScratch, Shard};
use gaps::metrics::{cached_node_sweep, sample_queries};
use gaps::obs::{Registry, SampleValue};
use gaps::search::{Query, SearchRequest};
use gaps::serve::{HttpConfig, HttpServer, QueueConfig, QueueStats, SearchServer, ServeObs};
use gaps::util::bench::Table;
use gaps::util::json::Json;
use gaps::util::rng::{Rng, Zipf};
use gaps::util::stats::Summary;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Micro-benchmark: per-query OR-retrieve cost on one large shard,
/// 4-term queries, CSR+scratch vs the naive HashMap reference.
fn bench_retrieval_micro(features: usize) -> Json {
    let docs = env_usize("GAPS_BENCH_MICRO_DOCS", 100_000) as u64;
    let num_queries = 64usize;
    let rounds = 5usize;
    eprintln!("micro: analyzing {docs}-doc shard (F={features})...");
    let gen = CorpusGenerator::new(CorpusSpec { num_docs: docs, ..CorpusSpec::default() });
    let shard = Shard::build(0, gen.generate_range(0, docs), features);

    // 4-term queries sampled from corpus topics (realistic bucket skew).
    let mut rng = Rng::new(0xF16_3);
    let mut queries: Vec<Vec<u32>> = Vec::with_capacity(num_queries);
    let mut attempts = 0usize;
    while queries.len() < num_queries {
        attempts += 1;
        assert!(attempts <= 100_000, "corpus yields no usable queries — check CorpusSpec");
        let raw = gen.sample_query(&mut rng);
        let Ok(q) = Query::parse(&raw, features) else { continue };
        if q.buckets.len() >= 4 {
            queries.push(q.buckets[..4].to_vec());
        } else if attempts > 10_000 && !q.buckets.is_empty() {
            // Degenerate corpora: accept shorter queries rather than spin.
            queries.push(q.buckets.clone());
        }
    }

    let max_candidates = 1024usize;
    let mut scratch = RetrievalScratch::new();
    // Warmup both paths (sizes the scratch, faults pages in).
    for q in &queries {
        shard.inverted.retrieve_into(q, max_candidates, &mut scratch);
        std::hint::black_box(shard.inverted.retrieve_reference(q, max_candidates));
    }

    let (mut csr, mut naive) = (Summary::new(), Summary::new());
    for _ in 0..rounds {
        for q in &queries {
            let t = Instant::now();
            shard.inverted.retrieve_into(q, max_candidates, &mut scratch);
            csr.add(t.elapsed().as_secs_f64());
            std::hint::black_box(scratch.hits().len());

            let t = Instant::now();
            let r = shard.inverted.retrieve_reference(q, max_candidates);
            naive.add(t.elapsed().as_secs_f64());
            std::hint::black_box(r.len());
        }
    }

    let speedup = naive.p50() / csr.p50().max(1e-12);
    println!(
        "\n== retrieval micro ({docs} docs, 4-term queries) ==\n\
         csr   p50={:8.1}us p95={:8.1}us\n\
         naive p50={:8.1}us p95={:8.1}us\n\
         speedup(p50) = {speedup:.2}x  (target >= 3x)",
        csr.p50() * 1e6,
        csr.percentile(95.0) * 1e6,
        naive.p50() * 1e6,
        naive.percentile(95.0) * 1e6,
    );

    Json::obj(vec![
        ("docs", Json::from(docs)),
        ("queries", Json::from(num_queries)),
        ("terms_per_query", Json::from(4usize)),
        ("max_candidates", Json::from(max_candidates)),
        ("csr_p50_us", Json::from(csr.p50() * 1e6)),
        ("csr_p95_us", Json::from(csr.percentile(95.0) * 1e6)),
        ("naive_p50_us", Json::from(naive.p50() * 1e6)),
        ("naive_p95_us", Json::from(naive.percentile(95.0) * 1e6)),
        ("speedup_p50", Json::from(speedup)),
    ])
}

/// Deterministic block-max pruning counters on a **fixed** workload:
/// 40k-doc shard, F=512, 32 disjunctive queries sampled from corpus
/// topics at a fixed seed (the Fig 3 query mix), k = the default
/// `max_candidates`. Everything is a local constant — deliberately not
/// env-resizable and not read from `GapsConfig`, so the committed
/// `BENCH_baseline.json` pins these numbers exactly and CI fails if
/// pruning effectiveness regresses.
fn bench_counters() -> Json {
    const DOCS: u64 = 40_000;
    const FEATURES: usize = 512; // SearchConfig::default().features
    const NUM_QUERIES: usize = 32;
    const MAX_CANDIDATES: usize = 1024; // SearchConfig::default().max_candidates
    const SEED: u64 = 0xB10C_3A5;
    let features = FEATURES;
    eprintln!("counters: analyzing fixed {DOCS}-doc shard (F={features})...");
    let gen = CorpusGenerator::new(CorpusSpec { num_docs: DOCS, ..CorpusSpec::default() });
    let shard = Shard::build(0, gen.generate_range(0, DOCS), features);

    // Disjunctive topical queries with >= 3 scored terms (the same
    // sampler the Fig 3 sweep uses; short draws are rejected so the mix
    // is genuinely disjunctive).
    let mut rng = Rng::new(SEED);
    let mut queries: Vec<Vec<u32>> = Vec::with_capacity(NUM_QUERIES);
    let mut attempts = 0usize;
    while queries.len() < NUM_QUERIES {
        attempts += 1;
        assert!(attempts <= 100_000, "corpus yields no disjunctive queries");
        let raw = gen.sample_query(&mut rng);
        let Ok(q) = Query::parse(&raw, features) else { continue };
        if q.buckets.len() >= 3 {
            queries.push(q.buckets.clone());
        }
    }

    let mut scratch = RetrievalScratch::new();
    let mut total = RetrievalCounters::default();
    for q in &queries {
        shard.inverted.retrieve_into(q, MAX_CANDIDATES, &mut scratch);
        total.merge(scratch.counters());
    }
    println!(
        "\n== retrieval counters ({DOCS} docs, {NUM_QUERIES} queries, k={MAX_CANDIDATES}) ==\n\
         postings touched {}/{} ({:.1}% skipped)\n\
         blocks skipped   {}/{} ({:.1}%)\n\
         candidates emitted {}",
        total.postings_touched,
        total.postings_total,
        total.skipped_fraction() * 100.0,
        total.blocks_skipped,
        total.blocks_total,
        100.0 * total.blocks_skipped as f64 / total.blocks_total.max(1) as f64,
        total.candidates_emitted,
    );

    Json::obj(vec![
        ("bench", Json::str("counters")),
        (
            "workload",
            Json::obj(vec![
                ("docs", Json::from(DOCS)),
                ("features", Json::from(features)),
                ("queries", Json::from(NUM_QUERIES)),
                ("max_candidates", Json::from(MAX_CANDIDATES)),
                ("seed", Json::from(SEED)),
            ]),
        ),
        ("counters", counters_to_json(&total)),
    ])
}

/// The workload fields that must match between a counter report and the
/// baseline for the gate comparison to be meaningful.
const WORKLOAD_KEYS: [&str; 5] = ["docs", "features", "queries", "max_candidates", "seed"];

/// The `cache` section's workload pins, compared the same way.
const CACHE_WORKLOAD_KEYS: [&str; 7] =
    ["docs", "nodes", "distinct", "draws", "theta", "seed", "burst"];

/// Baseline location: the committed `BENCH_baseline.json` unless
/// `GAPS_BENCH_BASELINE` points elsewhere.
fn baseline_path() -> String {
    std::env::var("GAPS_BENCH_BASELINE").unwrap_or_else(|_| "BENCH_baseline.json".to_string())
}

/// `GAPS_BENCH_WRITE_BASELINE=1` path: record this run's deterministic
/// sections (pruning counters + cache behaviour + heavy-traffic
/// workload pins) as the new reference — the escape hatch for
/// *intentional* retrieval, caching, or serving changes (gating first
/// would panic before the write, making regeneration impossible). The
/// gates are skipped on a write run.
fn write_baseline(counter_report: &Json, cache_report: &Json, traffic_report: &Json) {
    let baseline_path = baseline_path();
    let mut pairs = vec![("provisional", Json::Bool(false))];
    if let (Some(w), Some(c)) = (counter_report.get("workload"), counter_report.get("counters")) {
        pairs.push(("workload", w.clone()));
        pairs.push(("counters", c.clone()));
    }
    let mut cache = Vec::new();
    for key in ["workload", "hit_rate", "singleflight"] {
        if let Some(v) = cache_report.get(key) {
            cache.push((key, v.clone()));
        }
    }
    pairs.push(("cache", Json::obj(cache)));
    if let Some(w) = traffic_report.get("workload") {
        pairs.push(("traffic", Json::obj(vec![("workload", w.clone())])));
    }
    std::fs::write(&baseline_path, Json::obj(pairs).to_string_pretty())
        .unwrap_or_else(|e| panic!("write {baseline_path}: {e}"));
    println!(
        "wrote {baseline_path} (commit it to pin this run as the gate baseline — \
         counter and cache gates skipped this run)"
    );
}

/// Gate the deterministic counters against the committed baseline:
/// effectiveness must stay above the hard 30% floor and within 5% of the
/// baseline's recorded fraction (same workload only — a baseline
/// recorded for a different workload fails loudly instead of masking a
/// regression). Panics (fails the bench / CI) on regression. Runs
/// regardless of `GAPS_BENCH_NO_ASSERT`.
fn gate_counters(report: &Json) {
    let skipped = report
        .get("counters")
        .and_then(|c| c.get("skipped_fraction"))
        .and_then(|v| v.as_f64())
        .expect("counter report has skipped_fraction");
    let baseline_path = baseline_path();

    assert!(
        skipped > 0.30,
        "block-max pruning below the 30% floor: {:.1}% of postings skipped",
        skipped * 100.0
    );

    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let base = Json::parse(&text)
                .unwrap_or_else(|e| panic!("{baseline_path}: invalid JSON: {e}"));
            // The comparison is only meaningful on the exact same
            // workload: every pinned field must match.
            for key in WORKLOAD_KEYS {
                let got = report.get("workload").and_then(|w| w.get(key)).and_then(|v| v.as_i64());
                let want = base.get("workload").and_then(|w| w.get(key)).and_then(|v| v.as_i64());
                assert!(
                    got.is_some() && got == want,
                    "{baseline_path}: workload.{key} = {want:?} does not match this \
                     bench's {got:?} — the baseline was recorded for a different \
                     workload; regenerate it with GAPS_BENCH_WRITE_BASELINE=1 and commit."
                );
            }
            let base_skipped = base
                .get("counters")
                .and_then(|c| c.get("skipped_fraction"))
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("{baseline_path}: missing counters.skipped_fraction"));
            let floor = base_skipped * 0.95;
            assert!(
                skipped >= floor,
                "pruning effectiveness regressed >5%: {:.2}% skipped vs baseline {:.2}% \
                 (floor {:.2}%). If the retrieval change is intentional, regenerate the \
                 baseline with GAPS_BENCH_WRITE_BASELINE=1 and commit it.",
                skipped * 100.0,
                base_skipped * 100.0,
                floor * 100.0,
            );
            let provisional = base
                .get("provisional")
                .and_then(|v| v.as_bool())
                .unwrap_or(false);
            if provisional {
                println!(
                    "note: {baseline_path} is provisional — regenerate with \
                     GAPS_BENCH_WRITE_BASELINE=1 cargo bench --bench \
                     fig3_response_time and commit it to tighten the gate to \
                     this host-independent run ({:.1}% skipped).",
                    skipped * 100.0
                );
            }
            println!(
                "counter gate OK: {:.1}% skipped (baseline {:.1}%, floor {:.1}%)",
                skipped * 100.0,
                base_skipped * 100.0,
                floor * 100.0
            );
        }
        Err(_) => println!(
            "note: {baseline_path} missing — counter gate ran against the 30% floor only"
        ),
    }
}

/// End-to-end fan-out: `search()` wall time at 4 nodes, parallel
/// gridpool dispatch vs serial (workers = 1), same deployment bits.
fn bench_fanout(cfg: &GapsConfig) -> Json {
    let nodes = 4usize;
    let dep = Arc::new(Deployment::build(cfg, nodes).expect("deploy"));
    let queries = sample_queries(&dep, cfg.workload.num_queries.max(8), 0xFA11);

    let measure = |workers: usize| -> Summary {
        let mut c = cfg.clone();
        c.search.workers = workers;
        // The XLA path serializes through the coordinator thread (PJRT
        // handles are !Send) and would ignore the workers knob — this
        // comparison only means something on the rust-scorer path.
        c.search.use_xla = false;
        let mut sys = GapsSystem::from_deployment(c, Arc::clone(&dep)).expect("system");
        for q in &queries {
            sys.search(q).expect("warmup search");
        }
        let mut wall = vec![f64::INFINITY; queries.len()];
        for _ in 0..3 {
            for (i, q) in queries.iter().enumerate() {
                let t = Instant::now();
                std::hint::black_box(sys.search(q).expect("search"));
                wall[i] = wall[i].min(t.elapsed().as_secs_f64());
            }
        }
        let mut s = Summary::new();
        for w in wall {
            s.add(w);
        }
        s
    };

    let mut serial = measure(1);
    let auto_workers = cfg.search.effective_workers();
    let mut parallel = measure(0);
    let speedup = serial.p50() / parallel.p50().max(1e-12);
    println!(
        "\n== shard fan-out ({nodes} nodes, {} workers) ==\n\
         serial   p50={:8.2}ms p95={:8.2}ms\n\
         parallel p50={:8.2}ms p95={:8.2}ms\n\
         speedup(p50) = {speedup:.2}x  (target > 1.5x on >=4-core hosts)",
        auto_workers,
        serial.p50() * 1e3,
        serial.percentile(95.0) * 1e3,
        parallel.p50() * 1e3,
        parallel.percentile(95.0) * 1e3,
    );

    Json::obj(vec![
        ("nodes", Json::from(nodes)),
        ("workers", Json::from(auto_workers)),
        ("serial_p50_ms", Json::from(serial.p50() * 1e3)),
        ("serial_p95_ms", Json::from(serial.percentile(95.0) * 1e3)),
        ("parallel_p50_ms", Json::from(parallel.p50() * 1e3)),
        ("parallel_p95_ms", Json::from(parallel.percentile(95.0) * 1e3)),
        ("speedup_p50", Json::from(speedup)),
    ])
}

/// Batched QPS: one `search_batch` of N typed requests (one plan + one
/// fan-out round + Q>1 scoring rows) vs N sequential `search_request`
/// calls over the same deployment bits.
fn bench_batch(cfg: &GapsConfig) -> Json {
    let nodes = 4usize;
    let dep = Arc::new(Deployment::build(cfg, nodes).expect("deploy"));
    let queries = sample_queries(&dep, cfg.workload.num_queries.max(16), 0xBA7C);
    let requests: Vec<SearchRequest> =
        queries.iter().map(|q| SearchRequest::new(q.clone())).collect();
    let n = requests.len();

    let mut c = cfg.clone();
    c.search.use_xla = false;
    let mut sys = GapsSystem::from_deployment(c, Arc::clone(&dep)).expect("system");
    // Warm both paths.
    for r in sys.search_batch(&requests) {
        r.expect("warmup batch");
    }
    for r in &requests {
        sys.search_request(r).expect("warmup serial");
    }

    let rounds = 3usize;
    let mut serial_s = f64::INFINITY;
    let mut batch_s = f64::INFINITY;
    for _ in 0..rounds {
        let t = Instant::now();
        for r in &requests {
            std::hint::black_box(sys.search_request(r).expect("serial search"));
        }
        serial_s = serial_s.min(t.elapsed().as_secs_f64());

        let t = Instant::now();
        for r in std::hint::black_box(sys.search_batch(&requests)) {
            r.expect("batched search");
        }
        batch_s = batch_s.min(t.elapsed().as_secs_f64());
    }
    let serial_qps = n as f64 / serial_s.max(1e-12);
    let batch_qps = n as f64 / batch_s.max(1e-12);
    println!(
        "\n== batched execution ({n} queries, {nodes} nodes) ==\n\
         serial  {:8.2} ms total  ({serial_qps:8.1} qps)\n\
         batched {:8.2} ms total  ({batch_qps:8.1} qps)\n\
         speedup = {:.2}x",
        serial_s * 1e3,
        batch_s * 1e3,
        batch_qps / serial_qps.max(1e-12),
    );

    Json::obj(vec![
        ("nodes", Json::from(nodes)),
        ("queries", Json::from(n)),
        ("serial_ms", Json::from(serial_s * 1e3)),
        ("batch_ms", Json::from(batch_s * 1e3)),
        ("serial_qps", Json::from(serial_qps)),
        ("batch_qps", Json::from(batch_qps)),
        ("speedup", Json::from(batch_qps / serial_qps.max(1e-12))),
    ])
}

/// Interpolate one quantile from cumulative histogram buckets, the way
/// PromQL's `histogram_quantile` does: find the first bucket whose
/// cumulative count covers the rank, then interpolate linearly inside
/// it. Past the last finite bound, report that bound.
fn histogram_quantile(q: f64, buckets: &[(f64, u64)], count: u64) -> f64 {
    if count == 0 {
        return 0.0;
    }
    let rank = q * count as f64;
    let mut prev_bound = 0.0;
    let mut prev_cum = 0u64;
    for &(bound, cum) in buckets {
        if cum as f64 >= rank {
            let in_bucket = (cum - prev_cum) as f64;
            let frac =
                if in_bucket > 0.0 { (rank - prev_cum as f64) / in_bucket } else { 1.0 };
            return prev_bound + (bound - prev_bound) * frac.clamp(0.0, 1.0);
        }
        prev_bound = bound;
        prev_cum = cum;
    }
    buckets.last().map(|&(b, _)| b).unwrap_or(0.0)
}

/// p50/p95/p99 (seconds) of the server's end-to-end
/// `gaps_request_seconds` histogram — the same series an operator gets
/// from scraping `/metrics`, not a bench-side stopwatch.
fn request_quantiles(registry: &Registry) -> [f64; 3] {
    let fam = registry
        .gather()
        .into_iter()
        .find(|f| f.name == "gaps_request_seconds")
        .expect("request histogram registered");
    match &fam.samples[0].value {
        SampleValue::Histogram { buckets, count, .. } => {
            [0.50, 0.95, 0.99].map(|q| histogram_quantile(q, buckets, *count))
        }
        other => panic!("gaps_request_seconds is not a histogram: {other:?}"),
    }
}

/// Multi-user closed-loop serving: U concurrent users, each looping over
/// the query mix and submitting single-query requests through the
/// admission queue (the executor coalesces co-arrivals into
/// `search_batch` rounds on the resident gridpool). The paper's
/// experiment shape — many independent searchers, one always-on grid —
/// measured as sustained QPS, against a single closed-loop user on the
/// identical deployment.
fn bench_serve(cfg: &GapsConfig) -> Json {
    let nodes = 4usize;
    let dep = Arc::new(Deployment::build(cfg, nodes).expect("deploy"));
    // Closed-loop users only submit requests that compile — a sampled
    // query with no searchable terms would settle as a parse error and
    // pollute the QPS series.
    let queries: Vec<String> = sample_queries(&dep, cfg.workload.num_queries.max(16), 0x5E7E)
        .into_iter()
        .filter(|q| {
            SearchRequest::new(q.clone()).compile(cfg.search.features, cfg.search.top_k).is_ok()
        })
        .collect();
    assert!(!queries.is_empty(), "no usable serve queries sampled");
    let rounds = 3usize;

    let run = |users: usize| -> (f64, QueueStats, [f64; 3]) {
        let mut c = cfg.clone();
        c.search.use_xla = false;
        let dep = Arc::clone(&dep);
        // Zero linger: closed-loop users coalesce *naturally* (arrivals
        // queue up while the executor runs the previous round), and the
        // solo baseline is not taxed with idle linger latency.
        // Observability on: the latency series below is read back from
        // the same `gaps_request_seconds` histogram `/metrics` exposes.
        let obs = ServeObs::default();
        let server = SearchServer::start_sharded_with_obs(
            QueueConfig { max_batch: 16, max_linger: Duration::ZERO, ..QueueConfig::default() },
            1,
            obs.clone(),
            move |_shard| GapsSystem::from_deployment(c.clone(), Arc::clone(&dep)),
        )
        .expect("serve start");
        let queue = server.queue();
        // Warm the deployment (pool threads, scratches, page cache).
        queue.submit(SearchRequest::new(queries[0].clone())).expect("warmup");
        // Report admission counters for the measured workload only (the
        // warm-up added one singleton round of its own).
        let warm = server.stats();

        let t = Instant::now();
        std::thread::scope(|s| {
            for u in 0..users {
                let queue = &queue;
                let queries = &queries;
                // Staggered starting offsets: identical co-arrivals now
                // single-flight into one queue slot, so users marching
                // in lockstep over the same list would form size-1
                // rounds; offset starts keep *distinct* queries
                // co-pending, the mix the coalescing path is for.
                s.spawn(move || {
                    for i in 0..rounds * queries.len() {
                        let q = &queries[(u + i) % queries.len()];
                        queue.submit(SearchRequest::new(q.clone())).expect("serve");
                    }
                });
            }
        });
        let elapsed = t.elapsed().as_secs_f64();
        let total = server.stats();
        // Histogram-derived latency (includes the one warm-up sample —
        // noise at these request counts).
        let quantiles = request_quantiles(&obs.registry);
        server.shutdown();
        let stats = QueueStats {
            submitted: total.submitted - warm.submitted,
            executed: total.executed - warm.executed,
            batches: total.batches - warm.batches,
            coalesced: total.coalesced - warm.coalesced,
            // Max since boot; the size-1 warm-up round cannot hold it.
            largest_batch: total.largest_batch,
            singleflight: total.singleflight - warm.singleflight,
            shed: total.shed - warm.shed,
            expired: total.expired - warm.expired,
            ingest_batches: total.ingest_batches - warm.ingest_batches,
            ingest_docs: total.ingest_docs - warm.ingest_docs,
            plan_hits: total.plan_hits - warm.plan_hits,
            plan_misses: total.plan_misses - warm.plan_misses,
            result_hits: total.result_hits - warm.result_hits,
            result_misses: total.result_misses - warm.result_misses,
            result_evicted: total.result_evicted - warm.result_evicted,
            result_invalidated: total.result_invalidated - warm.result_invalidated,
        };
        ((users * rounds * queries.len()) as f64 / elapsed.max(1e-12), stats, quantiles)
    };

    let (solo_qps, _, solo_lat) = run(1);
    let users = 8usize;
    let (multi_qps, stats, multi_lat) = run(users);
    let avg_batch = stats.executed as f64 / stats.batches.max(1) as f64;
    println!(
        "\n== multi-user serving ({} queries x {rounds} rounds, {nodes} nodes) ==\n\
         1 user   {solo_qps:8.1} qps\n\
         {users} users  {multi_qps:8.1} qps  (x{:.2})\n\
         admission: {} rounds for {} requests (avg batch {avg_batch:.1}, \
         largest {}, {} coalesced, {} single-flight; {} result-cache hits)\n\
         latency from gaps_request_seconds (p50/p95/p99 ms): \
         1 user {:.2}/{:.2}/{:.2}, {users} users {:.2}/{:.2}/{:.2}",
        queries.len(),
        multi_qps / solo_qps.max(1e-12),
        stats.batches,
        stats.executed,
        stats.largest_batch,
        stats.coalesced,
        stats.singleflight,
        stats.result_hits,
        solo_lat[0] * 1e3,
        solo_lat[1] * 1e3,
        solo_lat[2] * 1e3,
        multi_lat[0] * 1e3,
        multi_lat[1] * 1e3,
        multi_lat[2] * 1e3,
    );

    let lat_json = |lat: [f64; 3]| {
        Json::obj(vec![
            ("p50_ms", Json::from(lat[0] * 1e3)),
            ("p95_ms", Json::from(lat[1] * 1e3)),
            ("p99_ms", Json::from(lat[2] * 1e3)),
        ])
    };

    Json::obj(vec![
        ("nodes", Json::from(nodes)),
        ("queries", Json::from(queries.len())),
        ("rounds", Json::from(rounds)),
        ("users", Json::from(users)),
        ("solo_qps", Json::from(solo_qps)),
        ("multi_qps", Json::from(multi_qps)),
        ("speedup", Json::from(multi_qps / solo_qps.max(1e-12))),
        ("admission_batches", Json::from(stats.batches)),
        ("admission_requests", Json::from(stats.executed)),
        ("avg_batch", Json::from(avg_batch)),
        ("largest_batch", Json::from(stats.largest_batch)),
        ("coalesced", Json::from(stats.coalesced)),
        ("singleflight", Json::from(stats.singleflight)),
        ("result_hits", Json::from(stats.result_hits)),
        ("solo_latency", lat_json(solo_lat)),
        ("multi_latency", lat_json(multi_lat)),
    ])
}

/// Deterministic caching behaviour on a **fixed** zipfian workload: 512
/// draws from a Zipf(1.1) popularity curve over 16 distinct queries at a
/// fixed seed, submitted serially through the serving stack. Like
/// `bench_counters`, every constant is local and deliberately not
/// env-resizable, so the committed baseline's `cache` section pins the
/// hit rate exactly. Three series come out:
///
/// * **hit rate** — result-cache hits / draws. With a capacity far above
///   the pool size and no ingest, misses == distinct queries drawn, so
///   the rate is a pure function of the seed (asserted structurally,
///   always on).
/// * **hot-query p50** — per-request wall time, cached vs the identical
///   stack with `cache.enabled = false` (wall-clock, so only reported
///   here; the speedup floor lives with the other enforced wall-clock
///   checks in `main`).
/// * **single-flight** — a burst of identical requests enqueued under
///   one queue lock: all but one must attach to the first's flight
///   (exactly `BURST - 1`, asserted structurally, always on).
fn bench_cache() -> Json {
    const DOCS: u64 = 4_000;
    const NODES: usize = 4;
    const DISTINCT: usize = 16;
    const DRAWS: usize = 512;
    const THETA: f64 = 1.1;
    const SEED: u64 = 0x2AC4E;
    const BURST: usize = 8;
    // Distinct leading terms (distinct stems) guarantee 16 distinct
    // normalized-AST fingerprints — the hit-rate arithmetic below
    // depends on pool index i <=> one cache key.
    const TOPICS: [&str; DISTINCT] = [
        "cloud", "storage", "retrieval", "indexing", "ranking", "parallel", "distributed",
        "semantic", "crawler", "cluster", "archive", "metadata", "citation", "corpus",
        "replication", "scheduling",
    ];

    let mut c = GapsConfig::default();
    c.workload.num_docs = DOCS;
    c.search.use_xla = false;
    eprintln!("cache: deploying fixed {DOCS}-doc grid ({NODES} nodes)...");
    let dep = Arc::new(Deployment::build(&c, NODES).expect("deploy"));
    let queries: Vec<String> =
        TOPICS.iter().map(|t| format!("{t} grid computing")).collect();

    let zipf = Zipf::new(DISTINCT, THETA);
    let mut rng = Rng::new(SEED);
    let seq: Vec<usize> = (0..DRAWS).map(|_| zipf.sample(&mut rng)).collect();
    let unique = {
        let mut seen = [false; DISTINCT];
        for &r in &seq {
            seen[r] = true;
        }
        seen.iter().filter(|&&s| s).count()
    };

    let start = |cache_on: bool| {
        let mut cc = c.clone();
        cc.cache.enabled = cache_on;
        let dep = Arc::clone(&dep);
        SearchServer::start(
            QueueConfig { max_batch: 16, max_linger: Duration::ZERO, ..QueueConfig::default() },
            move || GapsSystem::from_deployment(cc, dep),
        )
        .expect("serve start")
    };

    // Cold reference: the identical stack with the result cache off.
    let cold_server = start(false);
    let cold_queue = cold_server.queue();
    cold_queue.submit(SearchRequest::new(queries[0].clone())).expect("cold warmup");
    let mut cold = Summary::new();
    for &r in &seq {
        let t = Instant::now();
        cold_queue.submit(SearchRequest::new(queries[r].clone())).expect("cold serve");
        cold.add(t.elapsed().as_secs_f64());
    }
    cold_server.shutdown();

    // Cached pass: same sequence, cache on. The warm-up query is from
    // *outside* the pool so it seeds nothing the workload draws.
    let server = start(true);
    let queue = server.queue();
    queue.submit(SearchRequest::new("offpool warmup probe".to_string())).expect("warmup");
    let warm = server.stats();
    let (mut cached, mut cold_miss) = (Summary::new(), Summary::new());
    let mut hits_seen = warm.result_hits;
    for &r in &seq {
        let t = Instant::now();
        queue.submit(SearchRequest::new(queries[r].clone())).expect("cached serve");
        let dt = t.elapsed().as_secs_f64();
        // Serial submission: the executor publishes counters before the
        // reply, so the hit/miss split per request is exact.
        let now = queue.stats().result_hits;
        if now > hits_seen {
            cached.add(dt);
        } else {
            cold_miss.add(dt);
        }
        hits_seen = now;
    }
    let after = server.stats();
    let hits = after.result_hits - warm.result_hits;
    let misses = after.result_misses - warm.result_misses;
    let plan_hits = after.plan_hits - warm.plan_hits;
    let plan_misses = after.plan_misses - warm.plan_misses;
    // Structural, always on: with capacity >> pool size and no ingest,
    // the fixed seed pins the split exactly.
    assert_eq!(hits + misses, DRAWS as u64, "every draw must probe the result cache");
    assert_eq!(
        misses, unique as u64,
        "result-cache misses must equal the distinct queries drawn"
    );
    let hit_rate = hits as f64 / DRAWS as f64;

    // Single-flight burst: BURST copies of one fresh request enqueued
    // atomically (one lock hold), so exactly BURST-1 attach.
    let pre = server.stats();
    let tickets = queue.enqueue_all(
        (0..BURST).map(|_| SearchRequest::new("coalesced burst probe".to_string())).collect(),
    );
    for t in tickets {
        t.wait().expect("burst");
    }
    let singleflight = server.stats().singleflight - pre.singleflight;
    assert_eq!(
        singleflight,
        (BURST - 1) as u64,
        "identical co-pending requests must share one flight"
    );
    server.shutdown();

    let speedup = cold.p50() / cached.p50().max(1e-12);
    println!(
        "\n== result cache (zipf({THETA}) over {DISTINCT} queries, {DRAWS} draws, \
         {NODES} nodes) ==\n\
         hit rate   {:5.1}%  ({hits} hits / {misses} misses, {unique} distinct drawn)\n\
         hot p50    {:8.1}us cached vs {:8.1}us cold  ({speedup:.1}x)\n\
         plan cache {plan_hits} hits / {plan_misses} misses\n\
         single-flight: {singleflight} of {BURST} identical co-arrivals attached",
        hit_rate * 100.0,
        cached.p50() * 1e6,
        cold.p50() * 1e6,
    );

    Json::obj(vec![
        ("bench", Json::str("cache")),
        (
            "workload",
            Json::obj(vec![
                ("docs", Json::from(DOCS)),
                ("nodes", Json::from(NODES)),
                ("distinct", Json::from(DISTINCT)),
                ("draws", Json::from(DRAWS)),
                ("theta", Json::from(THETA)),
                ("seed", Json::from(SEED)),
                ("burst", Json::from(BURST)),
            ]),
        ),
        ("hit_rate", Json::from(hit_rate)),
        ("result_hits", Json::from(hits)),
        ("result_misses", Json::from(misses)),
        ("unique_queries", Json::from(unique)),
        ("cold_p50_us", Json::from(cold.p50() * 1e6)),
        ("cached_p50_us", Json::from(cached.p50() * 1e6)),
        ("miss_p50_us", Json::from(cold_miss.p50() * 1e6)),
        ("speedup_p50", Json::from(speedup)),
        ("plan_hits", Json::from(plan_hits)),
        ("plan_misses", Json::from(plan_misses)),
        ("singleflight", Json::from(singleflight)),
    ])
}

/// Gate the deterministic cache series against the committed baseline's
/// `cache` section: the hit rate may not regress more than 5% relative,
/// and the single-flight burst count must match exactly. Like
/// `gate_counters`, this runs regardless of `GAPS_BENCH_NO_ASSERT` —
/// both numbers are pure functions of fixed seeds and cannot flake on
/// shared runners. Baselines predating the section (or a missing file)
/// only note the gap instead of failing.
fn gate_cache(report: &Json) {
    let hit_rate =
        report.get("hit_rate").and_then(|v| v.as_f64()).expect("cache report has hit_rate");
    let singleflight = report
        .get("singleflight")
        .and_then(|v| v.as_i64())
        .expect("cache report has singleflight");
    let baseline_path = baseline_path();
    let Ok(text) = std::fs::read_to_string(&baseline_path) else {
        println!("note: {baseline_path} missing — cache gate ran structural checks only");
        return;
    };
    let base =
        Json::parse(&text).unwrap_or_else(|e| panic!("{baseline_path}: invalid JSON: {e}"));
    let Some(cache) = base.get("cache") else {
        println!(
            "note: {baseline_path} has no cache section — regenerate with \
             GAPS_BENCH_WRITE_BASELINE=1 and commit to arm the cache gate"
        );
        return;
    };
    for key in CACHE_WORKLOAD_KEYS {
        let got = report.get("workload").and_then(|w| w.get(key)).and_then(|v| v.as_f64());
        let want = cache.get("workload").and_then(|w| w.get(key)).and_then(|v| v.as_f64());
        assert!(
            got.is_some() && got == want,
            "{baseline_path}: cache.workload.{key} = {want:?} does not match this \
             bench's {got:?} — the baseline was recorded for a different workload; \
             regenerate it with GAPS_BENCH_WRITE_BASELINE=1 and commit."
        );
    }
    let base_rate = cache
        .get("hit_rate")
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("{baseline_path}: missing cache.hit_rate"));
    let floor = base_rate * 0.95;
    assert!(
        hit_rate >= floor,
        "cache hit rate regressed >5%: {:.2}% vs baseline {:.2}% (floor {:.2}%). If the \
         caching change is intentional, regenerate the baseline with \
         GAPS_BENCH_WRITE_BASELINE=1 and commit it.",
        hit_rate * 100.0,
        base_rate * 100.0,
        floor * 100.0,
    );
    if let Some(base_sf) = cache.get("singleflight").and_then(|v| v.as_i64()) {
        assert_eq!(
            singleflight, base_sf,
            "single-flight burst count diverged from the committed baseline"
        );
    }
    println!(
        "cache gate OK: {:.1}% hit rate (baseline {:.1}%, floor {:.1}%), \
         {singleflight} single-flight",
        hit_rate * 100.0,
        base_rate * 100.0,
        floor * 100.0
    );
}

/// Availability under deterministic chaos: a fixed set of seeded fault
/// schedules ([`ChaosPlan::from_seed`]) replayed against a fixed query
/// mix on a fixed 800-doc deployment, every response classified against
/// a fault-free oracle on the identical deployment. The classification
/// invariants (clean responses bit-identical, degradation only with
/// `allow_partial`, errors typed) are **structural** and asserted even
/// under `GAPS_BENCH_NO_ASSERT` — integer outcomes at fixed seeds cannot
/// flake on shared runners. The success/degraded rates and failover
/// counters land in the `availability` section of `BENCH_retrieval.json`
/// so the fault-tolerance trajectory is tracked across PRs.
fn bench_availability(cfg: &GapsConfig) -> Json {
    const SEEDS: [u64; 12] = [1, 2, 3, 5, 8, 13, 21, 34, 55, 89, 144, 233];
    let nodes = 6usize;
    let mut c = cfg.clone();
    c.workload.num_docs = 800;
    c.workload.sub_shards = 8;
    c.search.use_xla = false;
    let dep = Arc::new(Deployment::build(&c, nodes).expect("deploy"));
    // Fixed query mix; only compiling queries (a parse error tells us
    // nothing about availability). Every other request opts into
    // graceful degradation, the rest demand full fidelity.
    let requests: Vec<SearchRequest> = sample_queries(&dep, 8, 0xA7A1_1)
        .into_iter()
        .filter(|q| {
            SearchRequest::new(q.clone()).compile(c.search.features, c.search.top_k).is_ok()
        })
        .enumerate()
        .map(|(i, q)| {
            let req = SearchRequest::new(q);
            if i % 2 == 0 {
                req.allow_partial(true)
            } else {
                req
            }
        })
        .collect();
    assert!(!requests.is_empty(), "no usable availability queries sampled");

    let (mut exact, mut degraded, mut errors) = (0u64, 0u64, 0u64);
    let (mut jobs_failed, mut replans, mut recoveries) = (0u64, 0u64, 0u64);
    for &seed in &SEEDS {
        let mut oracle =
            GapsSystem::from_deployment(c.clone(), Arc::clone(&dep)).expect("oracle");
        let mut chaos =
            GapsSystem::from_deployment(c.clone(), Arc::clone(&dep)).expect("chaos");
        chaos.set_fault_injector(ChaosPlan::from_seed(seed, &dep.active));

        let want = oracle.search_batch(&requests);
        let got = chaos.search_batch(&requests);
        for ((req, want), got) in requests.iter().zip(&want).zip(&got) {
            match got {
                Ok(resp) if !resp.degraded => {
                    let want = want
                        .as_ref()
                        .unwrap_or_else(|e| panic!("seed {seed}: oracle failed ({e})"));
                    let ids_w: Vec<u64> = want.hits.iter().map(|h| h.global_id).collect();
                    let ids_g: Vec<u64> = resp.hits.iter().map(|h| h.global_id).collect();
                    assert_eq!(ids_w, ids_g, "seed {seed}: chaos hits diverged from oracle");
                    exact += 1;
                }
                Ok(resp) => {
                    assert!(req.allow_partial, "seed {seed}: degraded without allow_partial");
                    assert!(
                        !resp.missing_sources.is_empty(),
                        "seed {seed}: degraded with empty missing-source list"
                    );
                    degraded += 1;
                }
                Err(e) => {
                    assert!(
                        matches!(
                            e.kind(),
                            "unavailable" | "no-live-replica" | "no-nodes" | "deadline-exceeded"
                        ),
                        "seed {seed}: unexpected error kind {:?}",
                        e.kind()
                    );
                    errors += 1;
                }
            }
        }
        let fs = chaos.failover_stats();
        jobs_failed += fs.jobs_failed;
        replans += fs.replans;
        recoveries += fs.recoveries;
    }

    let total = exact + degraded + errors;
    let success_rate = (exact + degraded) as f64 / total.max(1) as f64;
    println!(
        "\n== availability under chaos ({} seeds x {} requests, {nodes} nodes) ==\n\
         exact     {exact:5}  (bit-identical to the fault-free oracle)\n\
         degraded  {degraded:5}  (truthful partial results via allow_partial)\n\
         errors    {errors:5}  (typed availability errors)\n\
         answered  {:.1}%   failover: {jobs_failed} jobs failed, {replans} replans, \
         {recoveries} node recoveries",
        requests.len(),
        success_rate * 100.0,
    );

    Json::obj(vec![
        ("seeds", Json::from(SEEDS.len())),
        ("requests_per_seed", Json::from(requests.len())),
        ("exact", Json::from(exact)),
        ("degraded", Json::from(degraded)),
        ("errors", Json::from(errors)),
        ("success_rate", Json::from(success_rate)),
        ("jobs_failed", Json::from(jobs_failed)),
        ("replans", Json::from(replans)),
        ("recoveries", Json::from(recoveries)),
    ])
}

/// Persistence: cold boot (generate + tokenize + index the corpus) vs
/// booting the identical deployment from an on-disk snapshot, plus live
/// ingestion throughput (docs/s through `GapsSystem::ingest`, seals and
/// compaction merges included). The wall-clock ratio is the headline —
/// snapshot load skips the whole analysis pipeline — but the parity
/// checks are **structural** and asserted even under
/// `GAPS_BENCH_NO_ASSERT`: a snapshot that loads fast and serves
/// different bits is a broken snapshot, not a slow one.
fn bench_persistence(cfg: &GapsConfig) -> Json {
    let nodes = 4usize;
    let mut c = cfg.clone();
    c.search.use_xla = false;
    c.storage.seal_docs = 64;

    let t = Instant::now();
    let mut sys = GapsSystem::deploy(c.clone(), nodes).expect("cold deploy");
    let cold_s = t.elapsed().as_secs_f64();

    // Live ingestion: fresh publications from the same generator family
    // (generation is pure in `(seed, i)`, so a wider generator extends
    // the corpus seamlessly), measured through ingest + flush so seal
    // and merge work is part of the cost, exactly as a serving node
    // pays it.
    let base = sys.deployment().locator.total_docs();
    let ingest_n = (c.workload.num_docs / 8).clamp(256, 4096);
    let spec = CorpusSpec {
        seed: c.workload.seed,
        num_docs: base + ingest_n,
        ..CorpusSpec::default()
    };
    let fresh = CorpusGenerator::new(spec).generate_range(base, ingest_n);
    let t = Instant::now();
    let rep = sys.ingest(fresh);
    let flushed = sys.flush_ingest();
    let ingest_s = t.elapsed().as_secs_f64();
    let docs_per_s = ingest_n as f64 / ingest_s.max(1e-12);
    assert_eq!(rep.accepted as u64, ingest_n, "ingest dropped documents");
    let seals = rep.sealed + flushed.sealed;
    let merges = rep.merges + flushed.merges;

    let dir = std::env::temp_dir().join("gaps_bench_persistence");
    let _ = std::fs::remove_dir_all(&dir);
    let t = Instant::now();
    sys.write_snapshot(&dir).expect("write snapshot");
    let write_s = t.elapsed().as_secs_f64();
    let snapshot_bytes: u64 = std::fs::read_dir(&dir)
        .expect("snapshot dir")
        .map(|e| e.unwrap().metadata().unwrap().len())
        .sum();

    let t = Instant::now();
    let mut restored =
        GapsSystem::deploy_from_snapshot(c.clone(), nodes, &dir).expect("snapshot boot");
    let load_s = t.elapsed().as_secs_f64();
    let _ = std::fs::remove_dir_all(&dir);

    // Structural parity: the snapshot-booted node answers with the
    // writer's exact bits (ids and scores), at the writer's epoch.
    assert_eq!(restored.index_epoch(), sys.index_epoch());
    assert_eq!(
        restored.index_health().searchable_docs,
        sys.index_health().searchable_docs
    );
    for q in sample_queries(sys.deployment(), 4, 0x5AFE) {
        match (sys.search(&q), restored.search(&q)) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.hits.len(), b.hits.len(), "snapshot parity broke for {q:?}");
                for (x, y) in a.hits.iter().zip(&b.hits) {
                    assert_eq!(x.global_id, y.global_id, "snapshot parity broke for {q:?}");
                    assert_eq!(
                        x.score.to_bits(),
                        y.score.to_bits(),
                        "snapshot parity broke for {q:?}"
                    );
                }
            }
            (a, b) => {
                assert_eq!(a.is_err(), b.is_err(), "snapshot parity broke for {q:?}")
            }
        }
    }

    let load_speedup = cold_s / load_s.max(1e-12);
    println!(
        "\n== persistence ({base} + {ingest_n} docs, {nodes} nodes) ==\n\
         cold boot     {:8.1} ms  (generate + analyze + index)\n\
         snapshot load {:8.1} ms  ({load_speedup:.2}x vs cold boot; {:.1} MiB \
         on disk, written in {:.1} ms)\n\
         ingestion     {docs_per_s:8.0} docs/s  ({seals} seals, {merges} merges)",
        cold_s * 1e3,
        load_s * 1e3,
        snapshot_bytes as f64 / (1024.0 * 1024.0),
        write_s * 1e3,
    );

    Json::obj(vec![
        ("nodes", Json::from(nodes)),
        ("base_docs", Json::from(base)),
        ("ingest_docs", Json::from(ingest_n)),
        ("cold_boot_ms", Json::from(cold_s * 1e3)),
        ("snapshot_load_ms", Json::from(load_s * 1e3)),
        ("load_speedup", Json::from(load_speedup)),
        ("snapshot_write_ms", Json::from(write_s * 1e3)),
        ("snapshot_bytes", Json::from(snapshot_bytes)),
        ("ingest_docs_per_s", Json::from(docs_per_s)),
        ("seals", Json::from(seals)),
        ("merges", Json::from(merges)),
        ("epoch", Json::from(sys.index_epoch())),
    ])
}

/// Parse one framed HTTP response (status + `Content-Length` body) off
/// a persistent connection; `None` means the connection died mid-read
/// (the closed-loop user reconnects). Returns the status and the
/// `Retry-After` value, if any.
fn read_traffic_response(reader: &mut BufReader<TcpStream>) -> Option<(u16, Option<u64>)> {
    let mut line = String::new();
    if reader.read_line(&mut line).ok()? == 0 {
        return None;
    }
    let status: u16 = line.split_whitespace().nth(1)?.parse().ok()?;
    let mut content_length = 0usize;
    let mut retry_after = None;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header).ok()? == 0 {
            return None;
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().ok()?;
            } else if name.eq_ignore_ascii_case("retry-after") {
                retry_after = Some(value.trim().parse().ok()?);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).ok()?;
    Some((status, retry_after))
}

/// One closed-loop keep-alive user: complete `per_user` requests,
/// pipelining nothing (submit, await, submit — the closed loop), and
/// reconnect after a short backoff whenever the acceptor sheds the
/// connection. Returns the latency of every *completed* request and
/// whether every shed response carried `Retry-After`.
fn traffic_user(
    addr: SocketAddr,
    queries: &[String],
    per_user: usize,
    uid: usize,
) -> (Vec<f64>, bool) {
    let mut lat = Vec::with_capacity(per_user);
    let mut retry_ok = true;
    let mut done = 0usize;
    while done < per_user {
        let Ok(stream) = TcpStream::connect(addr) else {
            std::thread::sleep(Duration::from_millis(2));
            continue;
        };
        stream.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
        let Ok(mut writer) = stream.try_clone() else { continue };
        let mut reader = BufReader::new(stream);
        while done < per_user {
            let q = &queries[(uid + done) % queries.len()];
            let body = Json::obj(vec![("query", Json::str(q.clone()))]).to_string_compact();
            let wire = format!(
                "POST /search HTTP/1.1\r\nHost: gaps-bench\r\n\
                 Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            let t = Instant::now();
            if writer.write_all(wire.as_bytes()).is_err() {
                break;
            }
            match read_traffic_response(&mut reader) {
                Some((200, _)) => {
                    lat.push(t.elapsed().as_secs_f64());
                    done += 1;
                }
                Some((503, retry)) => {
                    // Shed at the acceptor: the server closed this
                    // connection after a complete typed response. Back
                    // off and reconnect; the request is not consumed.
                    if retry.is_none() {
                        retry_ok = false;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                    break;
                }
                Some((status, _)) => panic!("traffic user got status {status} for {q:?}"),
                None => break,
            }
        }
    }
    (lat, retry_ok)
}

/// One heavy-traffic cell: `users` concurrent closed-loop keep-alive
/// users against a fresh `shards`-shard server behind a
/// `handlers`-bounded pool. Returns sustained QPS, the per-request
/// latency summary, the acceptor's shed count, total connection
/// attempts that were answered (completed + shed), and the
/// `Retry-After` flag.
fn traffic_cell(
    c: &GapsConfig,
    dep: &Arc<Deployment>,
    shards: usize,
    handlers: usize,
    users: usize,
    per_user: usize,
    queries: &[String],
) -> (f64, Summary, u64, u64, bool) {
    let cc = c.clone();
    let dep_for_server = Arc::clone(dep);
    let server = SearchServer::start_sharded(
        QueueConfig { max_batch: 16, max_linger: Duration::ZERO, ..QueueConfig::default() },
        shards,
        move |_shard| GapsSystem::from_deployment(cc.clone(), Arc::clone(&dep_for_server)),
    )
    .expect("traffic serve start");
    let http = HttpServer::bind_with(
        "127.0.0.1:0",
        server.router(),
        HttpConfig { handlers, ..HttpConfig::default() },
    )
    .expect("traffic bind");
    let addr = http.local_addr().expect("local addr");
    let stopper = http.shutdown_handle().expect("shutdown handle");
    let accept_thread = std::thread::spawn(move || http.serve().expect("serve"));

    // Warm every shard (pool threads, scratches) outside the timed
    // window; direct submits bypass the HTTP counters.
    for _ in 0..shards {
        server.router().submit(SearchRequest::new(queries[0].clone())).expect("warmup");
    }
    let shed_before = server.router().http().stats().shed;

    let barrier = Barrier::new(users);
    let mut all_lat: Vec<Vec<f64>> = vec![Vec::new(); users];
    let mut retry_flags = vec![true; users];
    let t = Instant::now();
    std::thread::scope(|s| {
        for (u, (lat, flag)) in all_lat.iter_mut().zip(retry_flags.iter_mut()).enumerate() {
            let barrier = &barrier;
            s.spawn(move || {
                barrier.wait();
                let (l, ok) = traffic_user(addr, queries, per_user, u);
                *lat = l;
                *flag = ok;
            });
        }
    });
    let elapsed = t.elapsed().as_secs_f64();
    let shed = server.router().http().stats().shed - shed_before;
    stopper.stop();
    accept_thread.join().expect("accept thread");
    server.shutdown();

    let mut lat = Summary::new();
    for l in all_lat.iter().flatten() {
        lat.add(*l);
    }
    let completed = (users * per_user) as u64;
    (
        completed as f64 / elapsed.max(1e-12),
        lat,
        shed,
        completed + shed,
        retry_flags.iter().all(|&ok| ok),
    )
}

/// Heavy-traffic closed-loop serving over real HTTP: a fixed ladder of
/// keep-alive user counts against the sharded executor behind the
/// bounded handler pool, swept over 1 and 2 shards. Like
/// `bench_counters`, every workload constant is local and deliberately
/// not env-resizable, so the committed baseline's `traffic.workload`
/// section pins the series shape across PRs.
///
/// The wall-clock numbers (QPS, latency ladder) are informational on
/// shared runners, but the serving-shape invariants are **structural**
/// and asserted even under `GAPS_BENCH_NO_ASSERT`:
///
/// * below the handler bound no connection is ever shed;
/// * beyond it the acceptor sheds, and every shed response carries
///   `Retry-After` (no client hangs, no silent drops);
/// * at equal offered load (`users == handlers`) the 2-shard server
///   sustains strictly more closed-loop QPS than the single shard —
///   each shard runs one compute lane (`workers = 1`, cache off), so
///   this isolates executor sharding itself.
fn bench_traffic() -> Json {
    const DOCS: u64 = 4_000;
    const NODES: usize = 4;
    const HANDLERS: usize = 32;
    const PER_USER: usize = 8;
    const USERS: [usize; 5] = [2, 8, 32, 96, 192];
    const SHARDS: [usize; 2] = [1, 2];
    const QUERY_SEED: u64 = 0x7AFF1C;

    let mut c = GapsConfig::default();
    c.workload.num_docs = DOCS;
    c.search.use_xla = false;
    // One compute lane per shard: the shard comparison must measure
    // executor sharding, not the gridpool's internal worker fan-out.
    c.search.workers = 1;
    // Cache off: repeated queries must cost real grid rounds, or the
    // executors never saturate and the knee disappears.
    c.cache.enabled = false;
    eprintln!("traffic: deploying fixed {DOCS}-doc grid ({NODES} nodes)...");
    let dep = Arc::new(Deployment::build(&c, NODES).expect("deploy"));
    let queries: Vec<String> = sample_queries(&dep, 16, QUERY_SEED)
        .into_iter()
        .filter(|q| {
            SearchRequest::new(q.clone()).compile(c.search.features, c.search.top_k).is_ok()
        })
        .collect();
    assert!(!queries.is_empty(), "no usable traffic queries sampled");

    println!(
        "\n== heavy traffic (keep-alive closed loop, {HANDLERS} handlers, \
         {PER_USER} requests/user) =="
    );
    let mut series = Vec::new();
    let mut qps_at_parity = [0.0f64; SHARDS.len()];
    for (si, &shards) in SHARDS.iter().enumerate() {
        let mut points = Vec::new();
        let mut knee_users = USERS[0];
        let mut knee_qps = 0.0f64;
        for &users in &USERS {
            let (qps, mut lat, shed, attempts, retry_ok) =
                traffic_cell(&c, &dep, shards, HANDLERS, users, PER_USER, &queries);
            // Structural, always on: the handler bound is the only
            // shedding trigger, and it must actually trigger.
            if users <= HANDLERS {
                assert_eq!(
                    shed,
                    0,
                    "{shards} shard(s), {users} users: shed below the handler bound"
                );
            } else {
                assert!(
                    shed > 0,
                    "{shards} shard(s), {users} users: no shed beyond the handler bound"
                );
            }
            assert!(
                retry_ok,
                "{shards} shard(s), {users} users: a shed response lacked Retry-After"
            );
            if users == HANDLERS {
                qps_at_parity[si] = qps;
            }
            if qps > knee_qps {
                knee_qps = qps;
                knee_users = users;
            }
            let shed_rate = shed as f64 / attempts.max(1) as f64;
            println!(
                "  {shards} shard(s) {users:4} users  {qps:8.1} qps  \
                 p50={:7.2}ms p95={:7.2}ms p99={:7.2}ms  shed {shed:5} ({:.1}%)",
                lat.p50() * 1e3,
                lat.percentile(95.0) * 1e3,
                lat.percentile(99.0) * 1e3,
                shed_rate * 100.0,
            );
            points.push(Json::obj(vec![
                ("users", Json::from(users)),
                ("qps", Json::from(qps)),
                ("p50_ms", Json::from(lat.p50() * 1e3)),
                ("p95_ms", Json::from(lat.percentile(95.0) * 1e3)),
                ("p99_ms", Json::from(lat.percentile(99.0) * 1e3)),
                ("shed", Json::from(shed)),
                ("shed_rate", Json::from(shed_rate)),
            ]));
        }
        println!("  {shards} shard(s): saturation knee at {knee_users} users");
        series.push(Json::obj(vec![
            ("shards", Json::from(shards)),
            ("knee_users", Json::from(knee_users)),
            ("points", Json::Arr(points)),
        ]));
    }

    // Structural, always on: at equal offered load the extra shard must
    // buy real throughput — replicas that don't scale are dead weight.
    let multi_over_single = qps_at_parity[1] / qps_at_parity[0].max(1e-12);
    assert!(
        multi_over_single > 1.0,
        "2 shards did not out-serve 1 shard at {HANDLERS} users: {:.1} vs {:.1} qps",
        qps_at_parity[1],
        qps_at_parity[0],
    );
    println!("  2 shards / 1 shard at {HANDLERS} users: {multi_over_single:.2}x closed-loop QPS");

    Json::obj(vec![
        ("bench", Json::str("traffic")),
        (
            "workload",
            Json::obj(vec![
                ("docs", Json::from(DOCS)),
                ("nodes", Json::from(NODES)),
                ("handlers", Json::from(HANDLERS)),
                ("per_user", Json::from(PER_USER)),
                ("users", Json::Arr(USERS.iter().map(|&u| Json::from(u)).collect())),
                ("shards", Json::Arr(SHARDS.iter().map(|&s| Json::from(s)).collect())),
                ("query_seed", Json::from(QUERY_SEED)),
            ]),
        ),
        ("series", Json::Arr(series)),
        ("multi_over_single_at_parity", Json::from(multi_over_single)),
    ])
}

/// Gate the heavy-traffic section against the committed baseline: the
/// wall-clock series is informational (closed-loop QPS on a shared
/// runner cannot be pinned), but the workload constants must match or
/// the series silently stops being comparable across PRs. The serving
/// shape itself is asserted inside `bench_traffic`, always. Baselines
/// predating the section (or a missing file) only note the gap.
fn gate_traffic(report: &Json) {
    let baseline_path = baseline_path();
    let Ok(text) = std::fs::read_to_string(&baseline_path) else {
        println!("note: {baseline_path} missing — traffic gate ran structural checks only");
        return;
    };
    let base = Json::parse(&text).unwrap_or_else(|e| panic!("{baseline_path}: invalid JSON: {e}"));
    let Some(traffic) = base.get("traffic") else {
        println!(
            "note: {baseline_path} has no traffic section — regenerate with \
             GAPS_BENCH_WRITE_BASELINE=1 and commit to arm the traffic gate"
        );
        return;
    };
    for key in ["docs", "nodes", "handlers", "per_user", "query_seed"] {
        let got = report.get("workload").and_then(|w| w.get(key)).and_then(|v| v.as_f64());
        let want = traffic.get("workload").and_then(|w| w.get(key)).and_then(|v| v.as_f64());
        assert!(
            got.is_some() && got == want,
            "{baseline_path}: traffic.workload.{key} = {want:?} does not match this \
             bench's {got:?} — the heavy-traffic series is no longer comparable across \
             PRs; regenerate it with GAPS_BENCH_WRITE_BASELINE=1 and commit."
        );
    }
    for key in ["users", "shards"] {
        let ladder = |v: &Json| -> Option<Vec<i64>> {
            Some(v.get("workload")?.get(key)?.as_arr()?.iter().filter_map(Json::as_i64).collect())
        };
        let got = ladder(report);
        let want = ladder(traffic);
        assert!(
            got.is_some() && got == want,
            "{baseline_path}: traffic.workload.{key} ladder {want:?} does not match this \
             bench's {got:?} — regenerate with GAPS_BENCH_WRITE_BASELINE=1 and commit."
        );
    }
    println!("traffic gate OK: workload pins match the committed baseline");
}

fn main() {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = env_usize("GAPS_BENCH_DOCS", 60_000) as u64;
    cfg.workload.num_queries = env_usize("GAPS_BENCH_QUERIES", 10);
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, using rust scorer");
        cfg.search.use_xla = false;
    }
    let counts = [1usize, 2, 3, 5, 8, 11];
    eprintln!(
        "fig3: {} docs, {} queries, sweeping {counts:?}",
        cfg.workload.num_docs, cfg.workload.num_queries
    );

    let sweep = cached_node_sweep(&cfg, &counts).expect("sweep failed");

    println!("\n== Figure 3: response time vs nodes ==");
    let mut t = Table::new(&[
        "nodes",
        "gaps_ms",
        "trad_ms",
        "trad/gaps",
        "gaps_work_ms",
        "gaps_net_ms",
        "gaps_ovh_ms",
    ]);
    for p in &sweep.points {
        t.row(vec![
            p.nodes.to_string(),
            format!("{:.1}", p.gaps.response_s * 1e3),
            format!("{:.1}", p.traditional.response_s * 1e3),
            format!("{:.2}x", p.traditional.response_s / p.gaps.response_s),
            format!("{:.1}", p.gaps.work_s * 1e3),
            format!("{:.1}", p.gaps.net_s * 1e3),
            format!("{:.1}", p.gaps.overhead_s * 1e3),
        ]);
    }
    print!("{}", t.render());
    t.write_csv("fig3_response_time");

    // Retrieval-core trajectory (micro + fan-out + batch + multi-user
    // serving), tracked across PRs.
    let micro = bench_retrieval_micro(cfg.search.features);
    let fanout = bench_fanout(&cfg);
    let batch = bench_batch(&cfg);
    let serve = bench_serve(&cfg);
    let cache = bench_cache();
    let availability = bench_availability(&cfg);
    let persistence = bench_persistence(&cfg);
    let traffic = bench_traffic();
    let cache_speedup = cache.get("speedup_p50").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let load_speedup =
        persistence.get("load_speedup").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let micro_speedup = micro.get("speedup_p50").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let fan_speedup = fanout.get("speedup_p50").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let fan_workers = fanout.get("workers").and_then(|v| v.as_i64()).unwrap_or(1);
    let sweep_json = Json::obj(vec![
        ("nodes", Json::Arr(sweep.points.iter().map(|p| Json::from(p.nodes)).collect())),
        (
            "gaps_p50_ms",
            Json::Arr(sweep.points.iter().map(|p| Json::from(p.gaps.p50_s * 1e3)).collect()),
        ),
        (
            "gaps_p99_ms",
            Json::Arr(sweep.points.iter().map(|p| Json::from(p.gaps.p99_s * 1e3)).collect()),
        ),
        (
            "trad_p50_ms",
            Json::Arr(
                sweep.points.iter().map(|p| Json::from(p.traditional.p50_s * 1e3)).collect(),
            ),
        ),
    ]);
    // Structural (not wall-clock) serving check: a loaded admission
    // queue must actually form multi-request rounds. Enforced even on
    // CI smoke runs — under 8 closed-loop users, singleton-only rounds
    // mean the queue is broken, not the host noisy.
    let coalesced = serve.get("coalesced").and_then(|v| v.as_i64()).unwrap_or(0);
    assert!(
        coalesced > 0,
        "8 closed-loop users produced no coalesced rounds — admission queue inert"
    );

    let report = Json::obj(vec![
        ("bench", Json::str("retrieval")),
        ("micro", micro),
        ("fanout", fanout),
        ("batch", batch),
        ("serve", serve),
        ("cache", cache.clone()),
        ("availability", availability),
        ("persistence", persistence),
        ("traffic", traffic.clone()),
        ("sweep", sweep_json),
    ]);
    let path = "BENCH_retrieval.json";
    std::fs::write(path, report.to_string_pretty()).expect("write BENCH_retrieval.json");
    println!("\nwrote {path}");

    // ---- Deterministic counters + cache behaviour + CI gates ---------
    // Run before (and independently of) the wall-clock assertions:
    // integer counters at fixed seeds are reproducible anywhere, so
    // these gates hold even on noisy shared runners (GAPS_BENCH_NO_ASSERT
    // does not disable them).
    let counter_report = bench_counters();
    std::fs::write("BENCH_counters.json", counter_report.to_string_pretty())
        .expect("write BENCH_counters.json");
    println!("wrote BENCH_counters.json");
    std::fs::write("BENCH_cache.json", cache.to_string_pretty())
        .expect("write BENCH_cache.json");
    println!("wrote BENCH_cache.json");
    std::fs::write("BENCH_traffic.json", traffic.to_string_pretty())
        .expect("write BENCH_traffic.json");
    println!("wrote BENCH_traffic.json");
    if std::env::var("GAPS_BENCH_WRITE_BASELINE").is_ok() {
        write_baseline(&counter_report, &cache, &traffic);
    } else {
        gate_counters(&counter_report);
        gate_cache(&cache);
        gate_traffic(&traffic);
    }

    // Checks are enforced on real bench runs so regressions fail loudly;
    // GAPS_BENCH_NO_ASSERT=1 (CI smoke on shared runners, tiny query
    // counts) reports without asserting — wall-clock comparisons from a
    // handful of samples on a noisy host must not flake CI.
    let enforce = std::env::var("GAPS_BENCH_NO_ASSERT").is_err();

    // Perf-target checks for this PR's hot-path work (conservative
    // floors below the stated targets, to absorb host variance).
    if enforce {
        assert!(
            micro_speedup >= 2.0,
            "retrieval micro speedup regressed: {micro_speedup:.2}x (floor 2x, target 3x)"
        );
    }
    if enforce {
        // Snapshot boot skips generation + tokenization + indexing —
        // on any real corpus it must beat the cold path outright.
        assert!(
            load_speedup > 1.0,
            "snapshot load slower than cold boot: {load_speedup:.2}x"
        );
    }
    if enforce && fan_workers >= 4 {
        assert!(
            fan_speedup > 1.2,
            "fan-out speedup regressed: {fan_speedup:.2}x with {fan_workers} workers \
             (floor 1.2x, target 1.5x)"
        );
    }
    if enforce {
        // A cache hit skips the whole grid round; it must beat the cold
        // path outright on any host (conservative 1x floor for noise).
        assert!(
            cache_speedup > 1.0,
            "cached hot-query p50 not faster than cold execution: {cache_speedup:.2}x"
        );
    }

    // Shape checks (reported, and enforced so regressions fail the bench).
    let mut ok = true;
    for p in &sweep.points {
        if p.gaps.response_s >= p.traditional.response_s {
            println!("SHAPE FAIL: n={} gaps not faster", p.nodes);
            ok = false;
        }
    }
    let gains: Vec<f64> = sweep
        .points
        .iter()
        .map(|p| (p.traditional.response_s / p.gaps.response_s - 1.0) * 100.0)
        .collect();
    println!(
        "\ngaps faster by {:.0}%..{:.0}% across the sweep (paper reports 54%..100%)",
        gains.iter().cloned().fold(f64::INFINITY, f64::min),
        gains.iter().cloned().fold(0.0, f64::max),
    );
    if enforce {
        assert!(ok, "figure 3 shape checks failed");
        println!("fig3 shape checks OK");
    } else if !ok {
        println!("fig3 shape checks failed (not enforced: GAPS_BENCH_NO_ASSERT set)");
    }
}
