//! Paper Figure 4 — "Speedup scales as the increase of size."
//!
//! Speedup = T(serial) / T(n nodes), per system against its own 1-node
//! time (the paper's definition: "the ratio of the time to execute the
//! job on a small system [to] the time to execute the same job on large
//! systems").
//!
//! Paper series to compare against (shape targets):
//!   GAPS:        1.55 @ 2 nodes rising monotonically to 2.59 @ 11;
//!   traditional: 1.2 @ 2, peaking ~1.9 @ 5, falling back to ~1.5 @ 11;
//!   GAPS +33% over traditional @ 2 nodes, +73% @ 11 nodes.
//!
//! Run: `cargo bench --bench fig4_speedup`

use gaps::config::GapsConfig;
use gaps::metrics::{cached_node_sweep, System};
use gaps::util::bench::Table;

/// Paper-reported reference points (node count, gaps, traditional).
const PAPER: &[(usize, f64, f64)] = &[(2, 1.55, 1.2), (5, 2.0, 1.9), (11, 2.59, 1.5)];

fn main() {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = std::env::var("GAPS_BENCH_DOCS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60_000);
    cfg.workload.num_queries = std::env::var("GAPS_BENCH_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, using rust scorer");
        cfg.search.use_xla = false;
    }
    let counts = [1usize, 2, 3, 5, 8, 11];
    let sweep = cached_node_sweep(&cfg, &counts).expect("sweep failed");
    let serial_g = sweep.serial_response_s(System::Gaps);
    let serial_t = sweep.serial_response_s(System::Traditional);

    println!("\n== Figure 4: speedup vs nodes ==");
    let mut t = Table::new(&["nodes", "gaps", "traditional", "paper_gaps", "paper_trad"]);
    for p in &sweep.points {
        let paper = PAPER.iter().find(|(n, _, _)| *n == p.nodes);
        t.row(vec![
            p.nodes.to_string(),
            format!("{:.2}", p.speedup(serial_g, System::Gaps)),
            format!("{:.2}", p.speedup(serial_t, System::Traditional)),
            paper.map(|(_, g, _)| format!("{g:.2}")).unwrap_or_else(|| "-".into()),
            paper.map(|(_, _, tr)| format!("{tr:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", t.render());
    t.write_csv("fig4_speedup");

    // Shape checks.
    let gaps_at = |n: usize| {
        sweep
            .points
            .iter()
            .find(|p| p.nodes == n)
            .map(|p| p.speedup(serial_g, System::Gaps))
            .unwrap()
    };
    let trad_at = |n: usize| {
        sweep
            .points
            .iter()
            .find(|p| p.nodes == n)
            .map(|p| p.speedup(serial_t, System::Traditional))
            .unwrap()
    };
    let mut ok = true;
    // 1. GAPS speedup grows from 2 to 11 nodes.
    if gaps_at(11) <= gaps_at(2) {
        println!("SHAPE FAIL: gaps speedup not increasing ({:.2} -> {:.2})", gaps_at(2), gaps_at(11));
        ok = false;
    }
    // 2. GAPS exceeds 1 at scale (the grid actually helps).
    if gaps_at(11) <= 1.0 {
        println!("SHAPE FAIL: gaps speedup at 11 nodes <= 1 ({:.2})", gaps_at(11));
        ok = false;
    }
    // 3. GAPS beats traditional speedup at the edges (paper: +33%, +73%).
    for n in [2usize, 11] {
        if gaps_at(n) <= trad_at(n) {
            println!("SHAPE FAIL: n={n} gaps {:.2} !> trad {:.2}", gaps_at(n), trad_at(n));
            ok = false;
        }
    }
    // 4. Traditional turns over: its speedup at 11 is below its peak.
    let trad_peak = counts[1..].iter().map(|&n| trad_at(n)).fold(0.0, f64::max);
    if trad_at(11) >= trad_peak && trad_peak > 0.0 {
        println!(
            "SHAPE NOTE: traditional did not turn over (peak {:.2}, @11 {:.2})",
            trad_peak,
            trad_at(11)
        );
    }
    println!(
        "\ngaps over traditional: {:+.0}% @2, {:+.0}% @11 (paper: +33%, +73%)",
        (gaps_at(2) / trad_at(2) - 1.0) * 100.0,
        (gaps_at(11) / trad_at(11) - 1.0) * 100.0
    );
    assert!(ok, "figure 4 shape checks failed");
    println!("fig4 shape checks OK");
}
