//! Ablations over the repo's design choices (not in the
//! paper, but they isolate *why* GAPS wins):
//!
//! 1. **Scheduling policy** — perf-history LPT vs blind round-robin on a
//!    heterogeneous grid (paper: "execution plan ... depends on the
//!    previous performance").
//! 2. **Resident services** — the globus-container design vs per-job
//!    cold starts (paper §III.3).
//! 3. **Query batching** — one q8 artifact execution vs 8 q1 executions
//!    (the MXU-utilization argument:
//!    the contraction's MXU rows scale with Q).
//!
//! Run: `cargo bench --bench ablations`

use std::sync::Arc;

use gaps::config::{GapsConfig, SchedulePolicy};
use gaps::coordinator::{Deployment, GapsSystem};
use gaps::corpus::{CorpusGenerator, CorpusSpec};
use gaps::index::{build_query_weights, pack_block, Shard, ShardStats};
use gaps::metrics::{measure_gaps, sample_queries};
use gaps::runtime::Executor;
use gaps::util::bench::{Bencher, Table};

fn main() {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 20_000;
    cfg.workload.num_queries = 8;
    cfg.grid.speed_min = 0.4;
    cfg.grid.speed_max = 1.6;
    let have_artifacts =
        std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists();
    if !have_artifacts {
        eprintln!("note: artifacts/ missing, using rust scorer (batching ablation skipped)");
        cfg.search.use_xla = false;
    }

    let dep = Arc::new(Deployment::build(&cfg, 9).expect("deployment"));
    let queries = sample_queries(&dep, cfg.workload.num_queries, 0xAB1A);

    println!("== Ablation 1: scheduling policy (9 heterogeneous nodes) ==");
    let mut t = Table::new(&["policy", "response_ms", "critical_work_ms"]);
    for policy in [SchedulePolicy::PerfHistory, SchedulePolicy::RoundRobin] {
        let mut c = cfg.clone();
        c.search.policy = policy;
        let mut sys = GapsSystem::from_deployment(c, Arc::clone(&dep)).expect("deploy");
        for q in &queries {
            sys.search(q).expect("warmup"); // perf-history needs samples
        }
        let point = measure_gaps(&mut sys, &queries).expect("measure");
        t.row(vec![
            policy.name().into(),
            format!("{:.1}", point.response_s * 1e3),
            format!("{:.1}", point.work_s * 1e3),
        ]);
    }
    print!("{}", t.render());
    t.write_csv("ablation_policy");

    println!("\n== Ablation 2: resident services vs per-job cold start ==");
    let mut t = Table::new(&["container", "response_ms", "overhead_ms"]);
    for resident in [true, false] {
        let mut c = cfg.clone();
        c.grid.resident_services = resident;
        let mut sys = GapsSystem::from_deployment(c, Arc::clone(&dep)).expect("deploy");
        for q in &queries {
            sys.search(q).expect("warmup");
        }
        let point = measure_gaps(&mut sys, &queries).expect("measure");
        t.row(vec![
            if resident { "resident (GAPS)" } else { "cold-start" }.into(),
            format!("{:.1}", point.response_s * 1e3),
            format!("{:.1}", point.overhead_s * 1e3),
        ]);
    }
    print!("{}", t.render());
    t.write_csv("ablation_container");

    if have_artifacts {
        println!("\n== Ablation 3: query batching through the q8 artifact ==");
        batching_ablation();
    }
}

/// 8 queries through one q8 execution vs eight q1 executions.
fn batching_ablation() {
    let spec = CorpusSpec { num_docs: 2_000, vocab_size: 800, ..CorpusSpec::default() };
    let gen = CorpusGenerator::new(spec);
    let shard = Shard::build(0, gen.generate_range(0, 2_000), 512);
    let mut acc = ShardStats::empty(512);
    acc.merge(&shard.stats);
    let stats = acc.finalize();
    let mut exec = Executor::new(std::path::Path::new("artifacts")).expect("executor");

    let candidates: Vec<u32> = (0..1024).collect();
    let block = pack_block(&shard, &stats, &candidates, 1024, 0.75);
    let queries: Vec<Vec<u32>> = (0..8)
        .map(|i| {
            gaps::search::Query::parse(&shard.pubs[i * 11].title, 512)
                .unwrap()
                .buckets
        })
        .collect();
    let qw8 = build_query_weights(&queries, &stats, 512, 8);
    let field_w = [2.0f32, 1.0, 1.5, 0.5];

    let bencher = Bencher::quick();
    let mut batched = bencher.run("q8 artifact, 1 execution, 8 queries", || {
        exec.rank(&block, &qw8, 8, &field_w).expect("rank");
    });
    let singles: Vec<Vec<f32>> = queries
        .iter()
        .map(|q| build_query_weights(&[q.clone()], &stats, 512, 1))
        .collect();
    let mut unbatched = bencher.run("q1 artifact, 8 executions", || {
        for qw in &singles {
            exec.rank(&block, qw, 1, &field_w).expect("rank");
        }
    });
    println!("{}", batched.report_line());
    println!("{}", unbatched.report_line());
    let speedup = unbatched.summary.p50() / batched.summary.p50();
    println!(
        "batching speedup: {speedup:.2}x for 8 queries (MXU rows scale with Q \
         on real TPUs)"
    );
}
