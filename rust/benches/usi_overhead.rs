//! Paper §III.4 claim — "the experiment shows that the USI overhead is
//! very small as compared with the response time."
//!
//! Measures the USI layer (input handling + result rendering) against the
//! grid response time it wraps, plus microbenchmarks of its parts (query
//! parsing, result formatting).
//!
//! Run: `cargo bench --bench usi_overhead`

use gaps::config::GapsConfig;
use gaps::coordinator::GapsSystem;
use gaps::search::Query;
use gaps::util::bench::{black_box, Bencher, Table};
use gaps::util::stats::Summary;

fn main() {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 10_000;
    if !std::path::Path::new(&cfg.search.artifact_dir).join("manifest.json").exists() {
        eprintln!("note: artifacts/ missing, using rust scorer");
        cfg.search.use_xla = false;
    }
    let mut sys = GapsSystem::deploy(cfg, 12).expect("deploy");

    // Warm all paths.
    for q in ["grid computing", "massive academic publications year:2005..2012"] {
        sys.search(q).expect("warmup");
    }

    // --- end-to-end split: interface vs grid --------------------------
    let mut iface = Summary::new();
    let mut grid = Summary::new();
    let queries = [
        "grid computing",
        "distributed search academic publication",
        "title:grid scheduling year:2005..2012",
        "venue:conference storage",
    ];
    for _ in 0..25 {
        for q in &queries {
            let (_, timing) = gaps::usi::one_shot(&mut sys, q).expect("query");
            iface.add(timing.interface_s);
            grid.add(timing.grid_s);
        }
    }
    let frac = iface.mean() / (iface.mean() + grid.mean());

    println!("\n== USI overhead vs grid response (paper: \"very small\") ==");
    let mut t = Table::new(&["component", "mean_ms", "p99_ms"]);
    t.row(vec![
        "usi interface".into(),
        format!("{:.4}", iface.mean() * 1e3),
        format!("{:.4}", iface.p99() * 1e3),
    ]);
    t.row(vec![
        "grid response".into(),
        format!("{:.2}", grid.mean() * 1e3),
        format!("{:.2}", grid.p99() * 1e3),
    ]);
    print!("{}", t.render());
    t.write_csv("usi_overhead");
    println!("interface share of total: {:.3}%", frac * 100.0);

    // --- microbenchmarks of the USI parts ------------------------------
    let bencher = Bencher::quick();
    let mut parse = bencher.run("parse multivariate query", || {
        black_box(Query::parse("title:grid scheduling year:2005..2012", 512).unwrap());
    });
    println!("\n{}", parse.report_line());
    let resp = sys.search("grid computing scheduling").expect("query");
    let mut fmt = bencher.run("format response", || {
        black_box(gaps::usi::format_response(&resp));
    });
    println!("{}", fmt.report_line());

    // The claim, enforced: interface under 2% of end-to-end time.
    assert!(
        frac < 0.02,
        "USI overhead {:.2}% is not 'very small' vs response time",
        frac * 100.0
    );
    println!("\nusi_overhead shape check OK (interface {:.3}% < 2%)", frac * 100.0);
}
