//! Observability conformance over the wire: `/metrics` exposition
//! format, `/healthz` snapshot atomicity under concurrent load,
//! `/debug/slow` ring behaviour, and the unified `Retry-After` hint on
//! both shed paths (admission high-water and acceptor overflow).
//!
//! The exposition checks use a test-side Prometheus text parser: every
//! sample must belong to a `# TYPE`-declared family, label keys must be
//! stable within a family and across scrapes, and histograms must
//! expose cumulative buckets terminated by `le="+Inf"` that equals the
//! `_count` sample.
//!
//! CI runs this file as an explicit job step (see
//! `.github/workflows/ci.yml`).

use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use gaps::config::GapsConfig;
use gaps::coordinator::GapsSystem;
use gaps::obs::{Registry, SlowLog};
use gaps::serve::{
    retry_after_hint, HttpConfig, HttpServer, QueueConfig, SearchServer, ServeObs, ShutdownHandle,
};
use gaps::util::json::Json;

fn small_cfg() -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 400;
    cfg.workload.sub_shards = 4;
    cfg.search.use_xla = false;
    cfg
}

/// A sharded serving stack with observability on, torn down on drop.
struct TestStack {
    addr: SocketAddr,
    stopper: ShutdownHandle,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    server: Option<SearchServer>,
}

impl TestStack {
    fn start(shards: usize, obs: ServeObs, http_cfg: HttpConfig) -> TestStack {
        let cfg = small_cfg();
        let server =
            SearchServer::start_sharded_with_obs(QueueConfig::default(), shards, obs, move |_| {
                GapsSystem::deploy(cfg.clone(), 3)
            })
            .unwrap();
        let http = HttpServer::bind_with("127.0.0.1:0", server.router(), http_cfg).unwrap();
        let addr = http.local_addr().unwrap();
        let stopper = http.shutdown_handle().unwrap();
        let accept_thread = std::thread::spawn(move || {
            http.serve().unwrap();
        });
        TestStack { addr, stopper, accept_thread: Some(accept_thread), server: Some(server) }
    }
}

impl Drop for TestStack {
    fn drop(&mut self) {
        self.stopper.stop();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(s) = self.server.take() {
            s.shutdown();
        }
    }
}

/// One request on a fresh closed connection; returns status + raw body.
fn http_text(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: gaps-test\r\n");
    if let Some(body) = body {
        req.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            body.len()
        ));
    }
    req.push_str("Connection: close\r\n\r\n");
    if let Some(body) = body {
        req.push_str(body);
    }
    stream.write_all(req.as_bytes()).expect("send");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("receive");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("").to_string();
    (status, body)
}

fn http_json(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let (status, text) = http_text(addr, method, path, body);
    (status, Json::parse(&text).unwrap_or_else(|e| panic!("bad body {text:?}: {e}")))
}

// ---------------------------------------------------------------------
// Test-side Prometheus text parser
// ---------------------------------------------------------------------

/// One parsed sample: full sample name (`family`, `family_bucket`, ...),
/// label pairs in exposition order, numeric value.
#[derive(Debug, Clone)]
struct Sample {
    name: String,
    labels: Vec<(String, String)>,
    value: f64,
}

/// A parsed scrape: family name -> (declared kind, samples).
type Scrape = BTreeMap<String, (String, Vec<Sample>)>;

fn parse_labels(s: &str) -> Vec<(String, String)> {
    // `k="v",k="v"` — values in this codebase never contain commas or
    // escaped quotes, but reject anything that fails to split cleanly.
    let mut out = Vec::new();
    for pair in s.split(',') {
        let (k, v) = pair.split_once('=').unwrap_or_else(|| panic!("bad label pair {pair:?}"));
        let v = v
            .strip_prefix('"')
            .and_then(|v| v.strip_suffix('"'))
            .unwrap_or_else(|| panic!("unquoted label value in {pair:?}"));
        out.push((k.to_string(), v.to_string()));
    }
    out
}

/// Map a sample name back to its family: histogram samples carry a
/// `_bucket`/`_sum`/`_count` suffix on the family name.
fn family_of(sample_name: &str, declared: &BTreeSet<String>) -> String {
    if declared.contains(sample_name) {
        return sample_name.to_string();
    }
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = sample_name.strip_suffix(suffix) {
            if declared.contains(base) {
                return base.to_string();
            }
        }
    }
    panic!("sample {sample_name:?} has no # TYPE declaration");
}

/// Parse a full exposition and enforce structural conformance:
/// `# TYPE` before samples, known kinds, consistent label keys within
/// a family, and well-formed cumulative histograms.
fn parse_scrape(text: &str) -> Scrape {
    let mut kinds: BTreeMap<String, String> = BTreeMap::new();
    let mut helps: BTreeSet<String> = BTreeSet::new();
    let mut samples: Vec<Sample> = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().expect("HELP name");
            helps.insert(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next().expect("TYPE name").to_string();
            let kind = parts.next().expect("TYPE kind").to_string();
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind.as_str()),
                "unknown kind {kind:?} for {name:?}"
            );
            assert!(kinds.insert(name, kind).is_none(), "duplicate # TYPE in:\n{text}");
            continue;
        }
        assert!(!line.starts_with('#'), "unknown comment line {line:?}");
        let (name_part, value) = line.rsplit_once(' ').expect("sample line");
        let value: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in {line:?}"));
        let (name, labels) = match name_part.split_once('{') {
            Some((name, rest)) => {
                let rest = rest.strip_suffix('}').unwrap_or_else(|| panic!("bad {line:?}"));
                (name.to_string(), parse_labels(rest))
            }
            None => (name_part.to_string(), Vec::new()),
        };
        samples.push(Sample { name, labels, value });
    }

    let declared: BTreeSet<String> = kinds.keys().cloned().collect();
    let mut scrape: Scrape =
        kinds.iter().map(|(n, k)| (n.clone(), (k.clone(), Vec::new()))).collect();
    for s in samples {
        let family = family_of(&s.name, &declared);
        assert!(helps.contains(&family), "family {family:?} has no # HELP");
        let (kind, sink) = scrape.get_mut(&family).unwrap();
        if kind != "histogram" {
            assert_eq!(s.name, family, "suffixed sample on a {kind} family");
            assert!(s.value >= 0.0 || *kind == "gauge", "negative {kind} {}", s.name);
        }
        sink.push(s);
    }

    for (family, (kind, samples)) in &scrape {
        assert!(!samples.is_empty(), "family {family:?} declared but never sampled");
        // Label keys (minus `le`) must agree across every sample of the
        // family — scrapers treat divergent keys as schema drift.
        let keys: BTreeSet<Vec<String>> = samples
            .iter()
            .map(|s| {
                s.labels.iter().map(|(k, _)| k.clone()).filter(|k| k != "le").collect::<Vec<_>>()
            })
            .collect();
        assert_eq!(keys.len(), 1, "family {family:?} has divergent label keys: {keys:?}");
        if kind == "histogram" {
            validate_histogram(family, samples);
        }
    }
    scrape
}

/// Group one histogram family's samples by their non-`le` label set and
/// check each series: buckets cumulative and non-decreasing, ordered by
/// bound, terminated by `+Inf` equal to `_count`, with `_sum` present.
fn validate_histogram(family: &str, samples: &[Sample]) {
    #[derive(Default)]
    struct Series {
        buckets: Vec<(f64, f64)>, // (le bound, cumulative), +Inf as f64::INFINITY
        sum: Option<f64>,
        count: Option<f64>,
    }
    let mut series: BTreeMap<String, Series> = BTreeMap::new();
    for s in samples {
        let key: Vec<String> = s
            .labels
            .iter()
            .filter(|(k, _)| k != "le")
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        let entry = series.entry(key.join(",")).or_default();
        if s.name.ends_with("_bucket") {
            let le = s
                .labels
                .iter()
                .find(|(k, _)| k == "le")
                .map(|(_, v)| if v == "+Inf" { f64::INFINITY } else { v.parse().unwrap() })
                .unwrap_or_else(|| panic!("{family}: bucket without le: {s:?}"));
            entry.buckets.push((le, s.value));
        } else if s.name.ends_with("_sum") {
            entry.sum = Some(s.value);
        } else if s.name.ends_with("_count") {
            entry.count = Some(s.value);
        } else {
            panic!("{family}: stray histogram sample {s:?}");
        }
    }
    for (labels, s) in series {
        let count = s.count.unwrap_or_else(|| panic!("{family}{{{labels}}}: no _count"));
        assert!(s.sum.is_some(), "{family}{{{labels}}}: no _sum");
        assert!(!s.buckets.is_empty(), "{family}{{{labels}}}: no buckets");
        let mut prev_bound = f64::NEG_INFINITY;
        let mut prev_cum = 0.0;
        for (bound, cum) in &s.buckets {
            assert!(*bound > prev_bound, "{family}{{{labels}}}: bounds out of order");
            assert!(*cum >= prev_cum, "{family}{{{labels}}}: buckets not cumulative");
            prev_bound = *bound;
            prev_cum = *cum;
        }
        let (last_bound, last_cum) = *s.buckets.last().unwrap();
        assert!(last_bound.is_infinite(), "{family}{{{labels}}}: no le=\"+Inf\" terminator");
        assert_eq!(last_cum, count, "{family}{{{labels}}}: +Inf bucket != _count");
    }
}

/// Sample-identity key: name plus full label set.
fn sample_key(s: &Sample) -> String {
    let labels: Vec<String> = s.labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
    format!("{}{{{}}}", s.name, labels.join(","))
}

// ---------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------

#[test]
fn metrics_exposition_is_conformant_and_stable_across_scrapes() {
    let stack = TestStack::start(2, ServeObs::default(), HttpConfig::default());
    // Repeats so cache-hit counters move; distinct queries so both
    // shards see work.
    for q in ["grid computing", "data retrieval", "grid computing", "data retrieval"] {
        let (status, body) =
            http_json(stack.addr, "POST", "/search", Some(&format!(r#"{{"query": "{q}"}}"#)));
        assert_eq!(status, 200, "{body:?}");
    }

    let (status, text1) = http_text(stack.addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let scrape1 = parse_scrape(&text1);

    // The registered surface is present, with per-shard labels.
    for family in [
        "gaps_http_requests_total",
        "gaps_http_active",
        "gaps_queue_submitted_total",
        "gaps_queue_depth",
        "gaps_cache_result_hits_total",
        "gaps_failover_jobs_failed_total",
        "gaps_index_epoch",
        "gaps_stage_seconds",
        "gaps_request_seconds",
        "gaps_requests_slow_total",
    ] {
        assert!(scrape1.contains_key(family), "family {family:?} missing:\n{text1}");
    }
    let (_, submitted) = &scrape1["gaps_queue_submitted_total"];
    let shard_labels: BTreeSet<String> = submitted
        .iter()
        .flat_map(|s| s.labels.iter().filter(|(k, _)| k == "shard").map(|(_, v)| v.clone()))
        .collect();
    assert_eq!(shard_labels, BTreeSet::from(["0".to_string(), "1".to_string()]));
    let total: f64 = submitted.iter().map(|s| s.value).sum();
    assert_eq!(total, 4.0, "4 searches submitted");

    // Stage histograms label both dimensions.
    let (_, stages) = &scrape1["gaps_stage_seconds"];
    let stage_names: BTreeSet<String> = stages
        .iter()
        .flat_map(|s| s.labels.iter().filter(|(k, _)| k == "stage").map(|(_, v)| v.clone()))
        .collect();
    for stage in ["queued", "probe", "search", "compile", "plan", "execute", "merge", "store"] {
        assert!(stage_names.contains(stage), "no {stage} series: {stage_names:?}");
    }

    // Second scrape: the schema is frozen (identical sample identity
    // sets) and counters are monotone.
    let (status, body) =
        http_json(stack.addr, "POST", "/search", Some(r#"{"query": "academic publications"}"#));
    assert_eq!(status, 200, "{body:?}");
    let (status, text2) = http_text(stack.addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let scrape2 = parse_scrape(&text2);

    let keys = |scrape: &Scrape| -> BTreeSet<String> {
        scrape.values().flat_map(|(_, ss)| ss.iter().map(sample_key)).collect()
    };
    assert_eq!(keys(&scrape1), keys(&scrape2), "sample identity drifted between scrapes");
    for (family, (kind, samples)) in &scrape1 {
        if kind != "counter" {
            continue;
        }
        let later: BTreeMap<String, f64> =
            scrape2[family].1.iter().map(|s| (sample_key(s), s.value)).collect();
        for s in samples {
            let now = later[&sample_key(s)];
            assert!(
                now >= s.value,
                "{} went backwards: {} -> {now}",
                sample_key(s),
                s.value
            );
        }
    }
}

#[test]
fn healthz_is_one_atomic_snapshot_under_concurrent_load() {
    let stack = TestStack::start(2, ServeObs::default(), HttpConfig::default());
    let addr = stack.addr;
    let writers = 4;
    let barrier = Arc::new(Barrier::new(writers + 1));

    std::thread::scope(|s| {
        for w in 0..writers {
            let barrier = Arc::clone(&barrier);
            s.spawn(move || {
                barrier.wait();
                for i in 0..6 {
                    let (status, body) = http_json(
                        addr,
                        "POST",
                        "/search",
                        Some(&format!(r#"{{"query": "grid search {w} {i}"}}"#)),
                    );
                    assert_eq!(status, 200, "{body:?}");
                }
            });
        }
        let barrier = Arc::clone(&barrier);
        s.spawn(move || {
            barrier.wait();
            for _ in 0..20 {
                let (status, health) = http_json(addr, "GET", "/healthz", None);
                assert_eq!(status, 200);
                // Atomicity evidence, twice over. (1) The aggregate
                // `queue` block and the `shards` blocks come from one
                // frozen read: they must agree *exactly*, even
                // mid-flight. (2) The HTTP front counts a request
                // before the router submits it, so a consistent
                // snapshot can never show more submissions than
                // requests — the drift the old unfenced reads allowed.
                let agg = health.get("queue").unwrap().get("submitted").unwrap().as_i64().unwrap();
                let split: i64 = health
                    .get("shards")
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|s| s.get("submitted").unwrap().as_i64().unwrap())
                    .sum();
                assert_eq!(agg, split, "aggregate and per-shard blocks torn apart");
                let requests =
                    health.get("http").unwrap().get("requests").unwrap().as_i64().unwrap();
                assert!(
                    requests >= split,
                    "snapshot shows {split} submissions but only {requests} http requests"
                );
            }
        });
    });
}

#[test]
fn debug_slow_ring_is_bounded_and_structured() {
    // Capacity 2, threshold 0: every request is slow, only the last two
    // survive in the ring.
    let obs = ServeObs {
        registry: Arc::new(Registry::new()),
        slow: Arc::new(SlowLog::new(2)),
        slow_query_ms: 0,
    };
    let stack = TestStack::start(1, obs, HttpConfig::default());
    for q in ["first", "second grid", "third grid", "grid computing"] {
        let (status, _) =
            http_json(stack.addr, "POST", "/search", Some(&format!(r#"{{"query": "{q}"}}"#)));
        assert!(status == 200 || status == 400, "unexpected status {status}");
    }
    let (status, body) = http_json(stack.addr, "GET", "/debug/slow", None);
    assert_eq!(status, 200);
    assert_eq!(body.get("capacity").unwrap().as_i64(), Some(2));
    let entries = body.get("entries").unwrap().as_arr().unwrap();
    assert_eq!(entries.len(), 2, "ring must drop the oldest entries");
    // Newest-last: the ring ends with the most recent request.
    assert_eq!(entries[1].get("query").unwrap().as_str(), Some("grid computing"));
    for e in entries {
        assert!(e.get("total_s").unwrap().as_f64().unwrap() >= 0.0);
        assert!(e.get("shard").is_some());
        assert!(e.get("stages").is_some(), "slow entries carry the stage tree: {e:?}");
    }
}

#[test]
fn retry_after_hint_is_shared_by_both_shed_paths() {
    // The hint function itself: linger-floored and depth-scaled.
    assert_eq!(retry_after_hint(0, 0, 16), 1, "zero linger still hints 1ms");
    assert_eq!(retry_after_hint(2, 0, 16), 2);
    assert_eq!(retry_after_hint(2, 64, 16), 2 * (1 + 4));
    assert!(retry_after_hint(2, 1024, 16) > retry_after_hint(2, 512, 16), "monotone in depth");

    // Acceptor path over the wire: a handler pool of 1, pinned by a
    // keep-alive holder, sheds the next connection with the same hint
    // the queue path would give at the current depth (empty queue,
    // default 2ms linger -> 2ms body hint, 1s header ceiling).
    let stack = TestStack::start(
        1,
        ServeObs::default(),
        HttpConfig { handlers: 1, ..HttpConfig::default() },
    );
    let holder = TcpStream::connect(stack.addr).expect("connect holder");
    holder.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = holder.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(holder);
    // Occupy the only handler with one complete round-trip, keeping the
    // connection open.
    let body = r#"{"query": "grid search"}"#;
    let req = format!(
        "POST /search HTTP/1.1\r\nHost: gaps-test\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    writer.write_all(req.as_bytes()).expect("holder send");
    let mut line = String::new();
    std::io::BufRead::read_line(&mut reader, &mut line).expect("holder status");
    assert!(line.contains("200"), "{line}");
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        std::io::BufRead::read_line(&mut reader, &mut header).expect("header");
        if header.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = header.trim_end().split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap();
            }
        }
    }
    let mut buf = vec![0u8; content_length];
    reader.read_exact(&mut buf).expect("holder body");

    // Overflow connection: shed by the acceptor with the unified hint.
    let (status, text) = http_text(stack.addr, "POST", "/search", Some(body));
    assert_eq!(status, 503, "{text}");
    let shed = Json::parse(&text).expect("typed shed body");
    assert_eq!(shed.get("kind").unwrap().as_str(), Some("overloaded"));
    assert_eq!(
        shed.get("retry_after_ms").unwrap().as_i64(),
        Some(retry_after_hint(2, 0, 16) as i64),
        "acceptor shed must carry the queue-derived hint"
    );
    drop((writer, reader));
}
