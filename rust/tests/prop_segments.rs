//! Segmented-index parity properties (referenced from
//! `gaps::storage::segment`'s module docs): a `SegmentedIndex` over any
//! partition of a doc array — 1, 2, or up to 5 segments at random
//! boundaries, with or without an unsealed mutable tail — must return
//! hits bit-identical (ids *and* scores) to one monolithic
//! `InvertedIndex` over the same docs, and its work counters must be
//! exactly the sum of the per-segment counters. Shard-level compaction
//! (`merge_shards`) must likewise be invisible: merging any partition
//! of a publication range equals building the whole shard directly.

use std::cell::RefCell;

use gaps::corpus::{CorpusGenerator, CorpusSpec};
use gaps::index::{InvertedIndex, RetrievalCounters, RetrievalScratch, Shard};
use gaps::storage::{merge_shards, SegmentedIndex};
use gaps::util::prop::{check, Config};

fn prop_cfg(cases: usize) -> Config {
    Config { cases, ..Config::default() }
}

#[test]
fn prop_segmented_retrieval_matches_monolithic() {
    const FEATURES: usize = 128;
    let spec = CorpusSpec { num_docs: 360, vocab_size: 400, seed: 9, ..CorpusSpec::default() };
    let gen = CorpusGenerator::new(spec);
    let docs = Shard::build(0, gen.generate_range(0, 360), FEATURES).docs;
    let mono = InvertedIndex::build(&docs, FEATURES);
    let scratch = RefCell::new(RetrievalScratch::new());

    check(
        "segmented-vs-monolithic",
        &prop_cfg(120),
        |rng, size| {
            // 1, 2 or 5 segments at random boundaries (duplicate cuts
            // collapse, so "up to"); the last segment optionally stays
            // mutable instead of sealing.
            let nseg = [1usize, 2, 5][rng.range(0, 3)];
            let mut cuts: Vec<usize> =
                (0..nseg - 1).map(|_| rng.range(1, docs.len())).collect();
            cuts.push(docs.len());
            cuts.sort_unstable();
            cuts.dedup();
            let mutable_tail = rng.chance(0.5);
            let n = rng.range(1, size.max(2).min(8));
            let buckets: Vec<u32> =
                (0..n).map(|_| rng.below(FEATURES as u64) as u32).collect();
            let k = rng.range(1, 100);
            (cuts, mutable_tail, buckets, k)
        },
        |(cuts, mutable_tail, buckets, k)| {
            let mut seg = SegmentedIndex::new(FEATURES);
            let mut start = 0usize;
            for (i, &cut) in cuts.iter().enumerate() {
                seg.add_docs(docs[start..cut].to_vec());
                if !(i == cuts.len() - 1 && *mutable_tail) {
                    seg.seal();
                }
                start = cut;
            }
            assert_eq!(seg.num_docs(), docs.len());

            let mut s = scratch.borrow_mut();
            let (hits, counters) = seg.retrieve_into(buckets, *k, &mut s);
            let want = mono.retrieve(buckets, *k);
            if hits != want {
                return Err(format!(
                    "cuts {cuts:?} mutable_tail={mutable_tail} k={k}: \
                     {} hits != monolithic {}",
                    hits.len(),
                    want.len()
                ));
            }

            // Counter aggregation: the segmented counters are exactly
            // the sum over per-segment indexes built from the same
            // slices (postings partition across segments, so
            // postings_total also equals the monolithic total).
            let mut sum = RetrievalCounters::default();
            let mut prev = 0usize;
            for &cut in cuts.iter() {
                let part = InvertedIndex::build(&docs[prev..cut], FEATURES);
                part.retrieve_into(buckets, *k, &mut s);
                sum.merge(s.counters());
                prev = cut;
            }
            if counters != sum {
                return Err(format!("aggregated counters {counters:?} != sum {sum:?}"));
            }
            mono.retrieve_into(buckets, *k, &mut s);
            if counters.postings_total != s.counters().postings_total {
                return Err(format!(
                    "postings_total {} != monolithic {}",
                    counters.postings_total,
                    s.counters().postings_total
                ));
            }

            // AND-retrieval parity rides along on the same partition.
            let (all, _) = seg.retrieve_all(buckets, docs.len());
            if all != mono.retrieve_all(buckets, docs.len()) {
                return Err(format!("retrieve_all diverged for cuts {cuts:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_merge_shards_equals_direct_build() {
    const FEATURES: usize = 64;
    let spec = CorpusSpec { num_docs: 150, vocab_size: 300, seed: 17, ..CorpusSpec::default() };
    let gen = CorpusGenerator::new(spec);
    let pubs = gen.generate_range(0, 150);
    let whole = Shard::build(5, pubs.clone(), FEATURES);

    check(
        "merge-shards-invariance",
        &prop_cfg(60),
        |rng, _| {
            let nparts = rng.range(1, 5);
            let mut cuts: Vec<usize> =
                (0..nparts - 1).map(|_| rng.range(1, pubs.len())).collect();
            cuts.push(pubs.len());
            cuts.sort_unstable();
            cuts.dedup();
            let buckets: Vec<u32> =
                (0..rng.range(1, 5)).map(|_| rng.below(FEATURES as u64) as u32).collect();
            (cuts, buckets)
        },
        |(cuts, buckets)| {
            let mut parts = Vec::new();
            let mut prev = 0usize;
            for &cut in cuts.iter() {
                parts.push(Shard::build(5, pubs[prev..cut].to_vec(), FEATURES));
                prev = cut;
            }
            let merged = merge_shards(5, parts);
            if merged.pubs != whole.pubs {
                return Err("merged pubs differ from direct build".into());
            }
            if merged.docs != whole.docs {
                return Err("merged docs differ from direct build".into());
            }
            if merged.stats != whole.stats {
                return Err("merged stats differ from direct build".into());
            }
            let (got, want) =
                (merged.inverted.retrieve(buckets, 25), whole.inverted.retrieve(buckets, 25));
            if got != want {
                return Err(format!("merged retrieval {} hits != {}", got.len(), want.len()));
            }
            Ok(())
        },
    );
}
