//! Saturation behaviour of the bounded handler pool: when more
//! connections arrive than `--handlers` can serve, the acceptor sheds
//! the overflow with a complete, typed `503` + `Retry-After` response —
//! it never hangs a client and never drops a connection silently — and
//! the shed count is visible in `/healthz`. Once load drops, the
//! handler slots free up and new connections are served again.
//!
//! CI runs this file as an explicit job step (see
//! `.github/workflows/ci.yml`) together with the conformance and parity
//! suites.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use gaps::config::GapsConfig;
use gaps::coordinator::GapsSystem;
use gaps::serve::{HttpConfig, HttpServer, QueueConfig, SearchServer, ShutdownHandle};
use gaps::util::json::Json;

fn small_cfg() -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 400;
    cfg.workload.sub_shards = 4;
    cfg.search.use_xla = false;
    cfg
}

/// A serving stack with a deliberately tiny handler pool.
struct TestStack {
    addr: SocketAddr,
    stopper: ShutdownHandle,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    server: Option<SearchServer>,
}

impl TestStack {
    fn start(handlers: usize) -> TestStack {
        let cfg = small_cfg();
        let queue_cfg = QueueConfig {
            max_batch: 4,
            max_linger: Duration::ZERO,
            ..QueueConfig::default()
        };
        let http_cfg = HttpConfig { handlers, ..HttpConfig::default() };
        let server = SearchServer::start(queue_cfg, move || GapsSystem::deploy(cfg, 3)).unwrap();
        let http = HttpServer::bind_with("127.0.0.1:0", server.router(), http_cfg).unwrap();
        let addr = http.local_addr().unwrap();
        let stopper = http.shutdown_handle().unwrap();
        let accept_thread = std::thread::spawn(move || {
            http.serve().unwrap();
        });
        TestStack { addr, stopper, accept_thread: Some(accept_thread), server: Some(server) }
    }
}

impl Drop for TestStack {
    fn drop(&mut self) {
        self.stopper.stop();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(s) = self.server.take() {
            s.shutdown();
        }
    }
}

fn post(path: &str, body: &str) -> String {
    format!(
        "POST {path} HTTP/1.1\r\nHost: gaps-test\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
}

/// Read one framed response (status + `Content-Length` body) off a
/// persistent connection without consuming the stream to EOF.
fn read_framed(reader: &mut BufReader<TcpStream>) -> (u16, Json) {
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {line:?}"));
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).expect("header line");
        if header.trim_end().is_empty() {
            break;
        }
        if let Some((name, value)) = header.trim_end().split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().expect("numeric content-length");
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("body");
    (status, Json::parse(std::str::from_utf8(&body).expect("utf-8")).expect("json body"))
}

/// Fetch `/healthz` on a fresh connection; `None` if this probe itself
/// got shed (caller retries).
fn try_healthz(addr: SocketAddr) -> Option<Json> {
    let mut stream = TcpStream::connect(addr).ok()?;
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    stream
        .write_all(b"GET /healthz HTTP/1.1\r\nHost: gaps-test\r\nConnection: close\r\n\r\n")
        .ok()?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw).ok()?;
    if !raw.starts_with("HTTP/1.1 200 ") {
        return None;
    }
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b)?;
    Json::parse(body).ok()
}

#[test]
fn overflow_beyond_the_handler_pool_is_shed_typed() {
    let handlers = 2;
    let stack = TestStack::start(handlers);

    // Occupy every handler slot: each holder completes one round-trip
    // (proving its handler is engaged) and then keeps the connection
    // open, so the keep-alive loop pins the handler thread.
    let mut holders = Vec::new();
    for i in 0..handlers {
        let stream = TcpStream::connect(stack.addr).expect("connect holder");
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut writer = stream.try_clone().expect("clone");
        let mut reader = BufReader::new(stream);
        writer
            .write_all(post("/search", &format!(r#"{{"query": "grid computing {i}"}}"#)).as_bytes())
            .expect("holder send");
        let (status, body) = read_framed(&mut reader);
        assert_eq!(status, 200, "{body:?}");
        holders.push((writer, reader));
    }

    // Every additional connection must be answered — completely and
    // typed — not hung (the client read timeout turns a hang into a
    // failure) and not reset (read_to_string returning Ok proves a
    // clean close after a full response).
    for i in 0..4 {
        let mut stream = TcpStream::connect(stack.addr).expect("connect overflow");
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        stream
            .write_all(post("/search", &format!(r#"{{"query": "overflow {i}"}}"#)).as_bytes())
            .expect("overflow send");
        let mut raw = String::new();
        stream.read_to_string(&mut raw).expect("shed response must arrive, not hang");
        assert!(raw.starts_with("HTTP/1.1 503 "), "{raw}");
        assert!(raw.contains("Retry-After: 1"), "shed without retry hint: {raw}");
        assert!(raw.contains("Connection: close"), "{raw}");
        let body = raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap();
        let body = Json::parse(body).expect("typed shed body");
        assert_eq!(body.get("kind").unwrap().as_str(), Some("overloaded"));
        assert!(body.get("retry_after_ms").is_some(), "{body:?}");
    }

    // Release the handler slots.
    drop(holders);

    // The pool recovers: /healthz is served again (possibly after a few
    // sheds while the holders' handlers unwind), reports every shed,
    // and shows no connection still active.
    let mut health = None;
    for _ in 0..250 {
        if let Some(h) = try_healthz(stack.addr) {
            let http = h.get("http").expect("connection counters");
            if http.get("active").unwrap().as_i64() == Some(0) {
                health = Some(h);
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    let health = health.expect("handler pool never recovered after holders closed");
    let http = health.get("http").unwrap();
    assert!(
        http.get("shed").unwrap().as_i64().unwrap() >= 4,
        "shed connections must be counted: {http:?}"
    );
    assert!(http.get("accepted").unwrap().as_i64().unwrap() >= handlers as i64 + 1);

    // And real work is served again, end to end.
    let mut stream = TcpStream::connect(stack.addr).expect("connect after recovery");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    writer
        .write_all(post("/search", r#"{"query": "grid computing"}"#).as_bytes())
        .expect("send");
    let (status, body) = read_framed(&mut reader);
    assert_eq!(status, 200, "{body:?}");
}

#[test]
fn shed_never_consumes_a_handler_slot() {
    // Shedding happens inline on the acceptor: a burst of overflow
    // connections must not starve the holders' in-flight keep-alive
    // sessions, which keep answering throughout.
    let stack = TestStack::start(1);

    let stream = TcpStream::connect(stack.addr).expect("connect holder");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    writer
        .write_all(post("/search", r#"{"query": "grid computing"}"#).as_bytes())
        .expect("send");
    assert_eq!(read_framed(&mut reader).0, 200);

    // Burst of sheds while the single handler is pinned.
    for _ in 0..3 {
        let mut s = TcpStream::connect(stack.addr).expect("connect overflow");
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(post("/search", r#"{"query": "overflow"}"#).as_bytes()).expect("send");
        let mut raw = String::new();
        s.read_to_string(&mut raw).expect("shed response");
        assert!(raw.starts_with("HTTP/1.1 503 "), "{raw}");
    }

    // The pinned holder still works — sheds were absorbed by the
    // acceptor, not by its handler.
    writer
        .write_all(post("/search", r#"{"query": "data retrieval"}"#).as_bytes())
        .expect("send");
    let (status, body) = read_framed(&mut reader);
    assert_eq!(status, 200, "{body:?}");
}
