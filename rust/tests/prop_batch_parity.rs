//! Batch-vs-serial parity: `search_batch([q1..qn])` must return
//! bit-identical hits and scores to n sequential `search_request` calls,
//! across scheduling policies, replica preferences, and with a failed
//! node — while issuing one plan and one fan-out round per batch.
//!
//! Parity holds by construction (replicas host identical data and BM25F
//! scores are per-(query, doc), independent of the rest of the scoring
//! block); this property test keeps it true as the batch path evolves.

use std::sync::{Arc, OnceLock};

use gaps::config::{GapsConfig, SchedulePolicy};
use gaps::coordinator::{Deployment, GapsSystem};
use gaps::metrics::sample_queries;
use gaps::search::{Field, ReplicaPref, SearchError, SearchRequest};
use gaps::util::prop::{check, Config};
use gaps::util::rng::Rng;

fn cfg(policy: SchedulePolicy) -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 600;
    cfg.workload.sub_shards = 8;
    cfg.search.use_xla = false;
    cfg.search.policy = policy;
    cfg
}

/// One deployment + query pool shared across every case (building the
/// corpus is the expensive part; systems are cheap to re-deploy).
fn fixture() -> &'static (Arc<Deployment>, Vec<String>) {
    static FIXTURE: OnceLock<(Arc<Deployment>, Vec<String>)> = OnceLock::new();
    FIXTURE.get_or_init(|| {
        let dep = Arc::new(Deployment::build(&cfg(SchedulePolicy::PerfHistory), 6).unwrap());
        let queries = sample_queries(&dep, 24, 0xBA7C4);
        (dep, queries)
    })
}

#[derive(Debug, Clone)]
struct BatchCase {
    requests: Vec<SearchRequest>,
    policy: SchedulePolicy,
    fail_node: bool,
}

fn gen_request(rng: &mut Rng, pool: &[String]) -> SearchRequest {
    let base = pool[rng.range(0, pool.len())].clone();
    let mut query = base;
    // Mutations exercising the grammar: duplicates, phrases, AND chains,
    // negations, invalid inputs.
    if rng.chance(0.2) {
        // Duplicate the first word (dedup regression surface).
        if let Some(w) = query.split_whitespace().next().map(str::to_string) {
            query = format!("{w} {query}");
        }
    }
    if rng.chance(0.15) {
        // Quote the first two words into a phrase.
        let words: Vec<&str> = query.split_whitespace().collect();
        if words.len() >= 2 {
            query = format!("\"{} {}\" {}", words[0], words[1], words[2..].join(" "));
        }
    }
    if rng.chance(0.15) {
        query = query.replacen(' ', " AND ", 1);
    }
    if rng.chance(0.1) {
        query.push_str(" -zzzyqx");
    }
    if rng.chance(0.08) {
        // Deliberately invalid inputs: error parity matters too.
        query = ["", "the of and", "bogus:grid", "year:20x4"][rng.range(0, 4)].to_string();
    }
    let mut req = SearchRequest::new(query);
    if rng.chance(0.4) {
        req = req.top_k(rng.range(1, 15));
    }
    if rng.chance(0.2) {
        let lo = 1998 + rng.below(10) as u32;
        req = req.year(lo..=lo + 6);
    }
    if rng.chance(0.1) {
        req = req.require(Field::Title, "grid");
    }
    if rng.chance(0.3) {
        req = req.prefer_replicas(match rng.range(0, 3) {
            0 => ReplicaPref::Any,
            1 => ReplicaPref::SameVo,
            _ => ReplicaPref::Primary,
        });
    }
    if rng.chance(0.1) {
        req = req.explain(true);
    }
    req
}

fn gen_case(rng: &mut Rng, size: usize) -> BatchCase {
    let (_, pool) = fixture();
    let n = rng.range(1, size.clamp(2, 7));
    BatchCase {
        requests: (0..n).map(|_| gen_request(rng, pool)).collect(),
        policy: if rng.chance(0.5) {
            SchedulePolicy::PerfHistory
        } else {
            SchedulePolicy::RoundRobin
        },
        fail_node: rng.chance(0.3),
    }
}

fn run_case(case: &BatchCase) -> Result<(), String> {
    let (dep, _) = fixture();
    let mut batch_sys =
        GapsSystem::from_deployment(cfg(case.policy), Arc::clone(dep)).map_err(|e| e.to_string())?;
    let mut serial_sys =
        GapsSystem::from_deployment(cfg(case.policy), Arc::clone(dep)).map_err(|e| e.to_string())?;
    if case.fail_node {
        let victim = dep.active[1];
        batch_sys.fail_node(victim);
        serial_sys.fail_node(victim);
    }

    let batch: Vec<Result<_, SearchError>> = batch_sys.search_batch(&case.requests);
    if batch.len() != case.requests.len() {
        return Err(format!("{} results for {} requests", batch.len(), case.requests.len()));
    }
    for (i, (req, b)) in case.requests.iter().zip(&batch).enumerate() {
        let s = serial_sys.search_request(req);
        match (b, s) {
            (Err(be), Err(se)) => {
                if be.kind() != se.kind() {
                    return Err(format!(
                        "request {i} {:?}: batch error {} vs serial error {}",
                        req.query,
                        be.kind(),
                        se.kind()
                    ));
                }
            }
            (Ok(_), Err(se)) => {
                return Err(format!("request {i} {:?}: serial failed ({se}), batch ok", req.query));
            }
            (Err(be), Ok(_)) => {
                return Err(format!("request {i} {:?}: batch failed ({be}), serial ok", req.query));
            }
            (Ok(b), Ok(s)) => {
                let ids_b: Vec<u64> = b.hits.iter().map(|h| h.global_id).collect();
                let ids_s: Vec<u64> = s.hits.iter().map(|h| h.global_id).collect();
                if ids_b != ids_s {
                    return Err(format!(
                        "request {i} {:?}: hits {ids_b:?} != {ids_s:?}",
                        req.query
                    ));
                }
                for (hb, hs) in b.hits.iter().zip(&s.hits) {
                    if hb.score.to_bits() != hs.score.to_bits() {
                        return Err(format!(
                            "request {i} {:?}: score {} != {} for doc {}",
                            req.query, hb.score, hs.score, hb.global_id
                        ));
                    }
                }
                if b.candidates != s.candidates {
                    return Err(format!(
                        "request {i} {:?}: candidates {} != {}",
                        req.query, b.candidates, s.candidates
                    ));
                }
                if b.docs_scanned != s.docs_scanned {
                    return Err(format!(
                        "request {i} {:?}: docs {} != {}",
                        req.query, b.docs_scanned, s.docs_scanned
                    ));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn prop_batch_matches_serial_execution() {
    let prop_cfg = Config { cases: 60, max_size: 7, ..Config::default() };
    check("batch-serial-parity", &prop_cfg, gen_case, run_case);
}

/// XLA-path parity (the branchy side of `rank_xla`): batched hits must
/// match sequential hits on the artifact scorer too, including a
/// `top_k` above the artifact's per-block `k`. Skips (like
/// `integration_e2e.rs`) when `make artifacts` has not run.
#[test]
fn xla_batch_matches_serial() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let mut xla_cfg = cfg(SchedulePolicy::PerfHistory);
    xla_cfg.search.use_xla = true;
    let (dep, pool) = fixture();
    let Ok(mut batch_sys) = GapsSystem::from_deployment(xla_cfg.clone(), Arc::clone(dep)) else {
        eprintln!("SKIP: xla executor unavailable in this build");
        return;
    };
    let mut serial_sys = GapsSystem::from_deployment(xla_cfg, Arc::clone(dep)).unwrap();
    let requests: Vec<SearchRequest> = pool
        .iter()
        .take(4)
        .enumerate()
        // Mix of top_k values, including one above the artifact k=32.
        .map(|(i, q)| SearchRequest::new(q.clone()).top_k([5, 10, 50, 3][i]))
        .collect();
    for (req, b) in requests.iter().zip(batch_sys.search_batch(&requests)) {
        let b = b.unwrap();
        let s = serial_sys.search_request(req).unwrap();
        assert_eq!(
            b.hits.iter().map(|h| h.global_id).collect::<Vec<_>>(),
            s.hits.iter().map(|h| h.global_id).collect::<Vec<_>>(),
            "xla batch hits diverged for {:?}",
            req.query
        );
    }
}

/// The amortization contract: a batch acquires each node's search
/// service once per fan-out, not once per query.
#[test]
fn batch_issues_one_fanout_round() {
    let (dep, pool) = fixture();
    let mut sys =
        GapsSystem::from_deployment(cfg(SchedulePolicy::PerfHistory), Arc::clone(dep)).unwrap();
    let requests: Vec<SearchRequest> =
        pool.iter().take(6).map(|q| SearchRequest::new(q.clone())).collect();
    for r in sys.search_batch(&requests) {
        r.unwrap();
    }
    for &node in &dep.active {
        assert!(
            sys.service_acquisitions(node) <= 1,
            "node {node} acquired more than once for a single batch"
        );
    }
    // Jobs: one per participating node, not per (node, query).
    assert!(sys.query_manager().total_jobs() <= dep.active.len());
}
