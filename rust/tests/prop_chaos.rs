//! Chaos property: under an arbitrary seeded fault schedule, every
//! response the system returns is exactly one of
//!
//! (a) **bit-identical** to the fault-free oracle (failover hid the
//!     faults entirely — same hits, same score bits, same counters);
//! (b) a **truthful degraded** response: the request opted in with
//!     `allow_partial`, the missing-source list is sorted, deduplicated
//!     and non-empty, every replica of every missing source carries a
//!     crash-capable injected fault, no hit leaks out of a missing
//!     source's doc range, and `docs_scanned` accounts for exactly the
//!     reachable remainder of the corpus; or
//! (c) a **typed** availability/deadline/parse error from the known set
//!
//! — never a panic, a hang, or a silently wrong answer. Schedules are
//! pure functions of a `u64` seed ([`ChaosPlan::from_seed`]), so any
//! failure this test finds replays exactly (`GAPS_PROP_SEED=...`).

use std::sync::{Arc, OnceLock};

use gaps::config::GapsConfig;
use gaps::coordinator::{Deployment, GapsSystem, SearchResponse};
use gaps::fault::ChaosPlan;
use gaps::metrics::sample_queries;
use gaps::search::{SearchError, SearchRequest};
use gaps::util::prop::{check, Config};
use gaps::util::rng::Rng;

const TOTAL_DOCS: u64 = 600;

fn cfg() -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = TOTAL_DOCS as usize;
    cfg.workload.sub_shards = 8;
    cfg.search.use_xla = false;
    cfg
}

/// One deployment + query pool shared across every case (systems are
/// rebuilt per case — they are cheap over a shared deployment — so a
/// case's fault history never bleeds into the next).
fn fixture() -> &'static (Arc<Deployment>, Vec<String>) {
    static FIXTURE: OnceLock<(Arc<Deployment>, Vec<String>)> = OnceLock::new();
    FIXTURE.get_or_init(|| {
        let dep = Arc::new(Deployment::build(&cfg(), 6).unwrap());
        let queries = sample_queries(&dep, 24, 0xC4A05_1);
        (dep, queries)
    })
}

#[derive(Debug, Clone)]
struct ChaosCase {
    /// Seed for [`ChaosPlan::from_seed`] over the active nodes.
    seed: u64,
    requests: Vec<SearchRequest>,
}

fn gen_case(rng: &mut Rng, size: usize) -> ChaosCase {
    let (_, pool) = fixture();
    let n = rng.range(1, size.clamp(2, 6));
    let requests = (0..n)
        .map(|_| {
            let mut query = pool[rng.range(0, pool.len())].clone();
            if rng.chance(0.08) {
                // Stopword-only input: parse errors must ride through
                // chaos unchanged.
                query = "the of and".to_string();
            }
            let mut req = SearchRequest::new(query);
            if rng.chance(0.5) {
                req = req.allow_partial(true);
            }
            if rng.chance(0.3) {
                req = req.top_k(rng.range(1, 12));
            }
            if rng.chance(0.05) {
                // An already-blown deadline: must surface as the typed
                // deadline error, faults or not.
                req = req.deadline_ms(0);
            }
            req
        })
        .collect();
    ChaosCase { seed: rng.next_u64(), requests }
}

/// Error kinds a chaos run may legitimately surface.
const TYPED_KINDS: &[&str] =
    &["parse", "deadline-exceeded", "unavailable", "no-live-replica", "no-nodes"];

fn classify(
    i: usize,
    req: &SearchRequest,
    plan: &ChaosPlan,
    dep: &Deployment,
    want: &Result<SearchResponse, SearchError>,
    got: &Result<SearchResponse, SearchError>,
) -> Result<(), String> {
    let label = format!("request {i} {:?} (seed {})", req.query, plan.seed);
    match got {
        // (a) clean response: bit-identical to the fault-free oracle.
        Ok(resp) if !resp.degraded => {
            if !resp.missing_sources.is_empty() {
                return Err(format!("{label}: non-degraded but missing {:?}", resp.missing_sources));
            }
            let want = match want {
                Ok(w) => w,
                Err(e) => return Err(format!("{label}: chaos ok but oracle failed ({e})")),
            };
            let ids_w: Vec<u64> = want.hits.iter().map(|h| h.global_id).collect();
            let ids_g: Vec<u64> = resp.hits.iter().map(|h| h.global_id).collect();
            if ids_w != ids_g {
                return Err(format!("{label}: hits {ids_g:?} != oracle {ids_w:?}"));
            }
            for (w, g) in want.hits.iter().zip(&resp.hits) {
                if w.score.to_bits() != g.score.to_bits() {
                    return Err(format!(
                        "{label}: score {} != oracle {} for doc {}",
                        g.score, w.score, g.global_id
                    ));
                }
            }
            if resp.candidates != want.candidates || resp.docs_scanned != want.docs_scanned {
                return Err(format!(
                    "{label}: counters ({}, {}) != oracle ({}, {})",
                    resp.candidates, resp.docs_scanned, want.candidates, want.docs_scanned
                ));
            }
            Ok(())
        }
        // (b) degraded response: opted-in, and truthful about the damage.
        Ok(resp) => {
            if !req.allow_partial {
                return Err(format!("{label}: degraded without allow_partial"));
            }
            let mut canon = resp.missing_sources.clone();
            canon.sort_unstable();
            canon.dedup();
            if canon != resp.missing_sources || canon.is_empty() {
                return Err(format!(
                    "{label}: missing list not sorted/deduped/non-empty: {:?}",
                    resp.missing_sources
                ));
            }
            let mut missing_docs = 0u64;
            for &s in &resp.missing_sources {
                let src = dep
                    .locator
                    .source(s)
                    .ok_or_else(|| format!("{label}: unknown missing source {s}"))?;
                // Truthfulness: a source may only go missing if every
                // replica carries a fault that can actually crash jobs.
                for &node in &src.replicas {
                    if !plan.can_crash(node) {
                        return Err(format!(
                            "{label}: source {s} reported missing but replica {node} \
                             has no crash-capable fault"
                        ));
                    }
                }
                missing_docs += src.doc_count;
                for h in &resp.hits {
                    if (src.doc_start..src.doc_start + src.doc_count).contains(&h.global_id) {
                        return Err(format!(
                            "{label}: hit {} leaked from missing source {s}",
                            h.global_id
                        ));
                    }
                }
            }
            if resp.docs_scanned != TOTAL_DOCS - missing_docs {
                return Err(format!(
                    "{label}: docs_scanned {} != {} - {missing_docs} missing",
                    resp.docs_scanned, TOTAL_DOCS
                ));
            }
            Ok(())
        }
        // (c) typed error from the documented set.
        Err(e) => {
            if !TYPED_KINDS.contains(&e.kind()) {
                return Err(format!("{label}: unexpected error kind {:?} ({e})", e.kind()));
            }
            // A parse error is a property of the request, not the
            // faults: the oracle must agree.
            if e.kind() == "parse" && !matches!(want, Err(w) if w.kind() == "parse") {
                return Err(format!("{label}: chaos-only parse error"));
            }
            Ok(())
        }
    }
}

fn run_case(case: &ChaosCase) -> Result<(), String> {
    let (dep, _) = fixture();
    let mut oracle =
        GapsSystem::from_deployment(cfg(), Arc::clone(dep)).map_err(|e| e.to_string())?;
    let mut chaos =
        GapsSystem::from_deployment(cfg(), Arc::clone(dep)).map_err(|e| e.to_string())?;
    let plan = ChaosPlan::from_seed(case.seed, &dep.active);
    chaos.set_fault_injector(plan.clone());

    let want = oracle.search_batch(&case.requests);
    let got = chaos.search_batch(&case.requests);
    for (i, ((req, want), got)) in case.requests.iter().zip(&want).zip(&got).enumerate() {
        classify(i, req, &plan, dep, want, got)?;
    }
    Ok(())
}

#[test]
fn prop_chaos_responses_are_exact_degraded_or_typed() {
    let prop_cfg = Config { cases: 40, max_size: 6, ..Config::default() };
    check("chaos-response-trichotomy", &prop_cfg, gen_case, run_case);
}

/// Determinism evidence: the same seed drives the same schedule to the
/// same outcomes — hit ids, score bits, degradation flags, missing
/// lists and error kinds all replay.
#[test]
fn chaos_outcomes_replay_from_the_seed() {
    let (dep, pool) = fixture();
    let requests: Vec<SearchRequest> = pool
        .iter()
        .take(4)
        .map(|q| SearchRequest::new(q.clone()).allow_partial(true))
        .collect();
    for seed in [1u64, 42, 0xBAD_5EED] {
        let mut runs: Vec<Vec<String>> = Vec::new();
        for _ in 0..2 {
            let mut sys = GapsSystem::from_deployment(cfg(), Arc::clone(dep)).unwrap();
            sys.set_fault_injector(ChaosPlan::from_seed(seed, &dep.active));
            let outcomes = sys
                .search_batch(&requests)
                .into_iter()
                .map(|r| match r {
                    Ok(resp) => {
                        let ids: Vec<u64> = resp.hits.iter().map(|h| h.global_id).collect();
                        let bits: Vec<u64> =
                            resp.hits.iter().map(|h| h.score.to_bits()).collect();
                        format!(
                            "ok degraded={} missing={:?} ids={ids:?} bits={bits:?}",
                            resp.degraded, resp.missing_sources
                        )
                    }
                    Err(e) => format!("err {}", e.kind()),
                })
                .collect();
            runs.push(outcomes);
        }
        assert_eq!(runs[0], runs[1], "seed {seed} did not replay");
    }
}
