//! Cached-vs-cold parity for the serving layer's plan + result caches.
//!
//! The caching tentpole's contract: a response served from the result
//! cache (or planned through the plan cache) must be **bit-identical**
//! to what cold execution produces — same hit ids, same score bits,
//! same counters, same explain payload, same error kinds — and a query
//! submitted after an index-epoch bump must never see a pre-epoch
//! cached result. Both are pinned here against a cache-disabled oracle
//! system (`cache.enabled = false`) over the same deployment, with
//! ingest/seal/merge rounds interleaved between identical queries.
//!
//! The composed critical-path timeline is the one field excluded from
//! comparison: its work component is *measured*, so even two cold
//! executions of the same query differ in it (prop_serve_parity makes
//! the same exclusion). Everything result-shaped is compared exactly.

use std::sync::{Arc, OnceLock};
use std::time::Duration;

use gaps::config::GapsConfig;
use gaps::coordinator::{Deployment, GapsSystem, SearchResponse};
use gaps::corpus::Publication;
use gaps::metrics::sample_queries;
use gaps::search::{SearchError, SearchRequest};
use gaps::serve::{QueueConfig, SearchServer};
use gaps::util::prop::{check, Config};
use gaps::util::rng::Rng;

fn cfg() -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 600;
    cfg.workload.sub_shards = 8;
    cfg.search.use_xla = false;
    cfg
}

/// One deployment + query pool shared across every case.
fn fixture() -> &'static (Arc<Deployment>, Vec<String>) {
    static FIXTURE: OnceLock<(Arc<Deployment>, Vec<String>)> = OnceLock::new();
    FIXTURE.get_or_init(|| {
        let dep = Arc::new(Deployment::build(&cfg(), 4).unwrap());
        let queries = sample_queries(&dep, 10, 0xCAC4E_1);
        (dep, queries)
    })
}

#[derive(Debug, Clone)]
enum Op {
    Query(SearchRequest),
    Ingest(Vec<Publication>),
}

#[derive(Debug, Clone)]
struct CacheCase {
    ops: Vec<Op>,
    /// Mutable-buffer seal threshold: 1 makes every ingest bump the
    /// epoch, larger values let queries race a buffering tail.
    seal_docs: usize,
}

/// Reverse the whitespace tokens of a plain conjunction: logically the
/// same query, textually different — must share the canonical AST, the
/// fingerprint, and therefore the cache entry.
fn reverse_tokens(raw: &str) -> String {
    let mut tokens: Vec<&str> = raw.split_whitespace().collect();
    tokens.reverse();
    tokens.join(" ")
}

fn gen_request(rng: &mut Rng, pool: &[String]) -> SearchRequest {
    let mut raw = pool[rng.range(0, pool.len())].clone();
    if rng.chance(0.35) {
        // The pool is operator-free conjunctions (+ optional year atom),
        // so token order is semantics-free.
        raw = reverse_tokens(&raw);
    }
    if rng.chance(0.1) {
        // Errors must ferry through the cached path identically too
        // (and must never be cached).
        raw = ["", "the of and", "bogus:grid"][rng.range(0, 3)].to_string();
    }
    let mut req = SearchRequest::new(raw);
    if rng.chance(0.4) {
        req = req.top_k(rng.range(1, 12));
    }
    if rng.chance(0.2) {
        req = req.explain(true);
    }
    req
}

fn gen_doc(rng: &mut Rng, n: usize) -> Publication {
    Publication {
        id: 0, // reassigned by ingestion
        title: format!("ingested probe {n} grid computing"),
        abstract_text: "live ingestion interleaved with cached serving".into(),
        authors: "A. Author".into(),
        venue: "TEST".into(),
        year: 2000 + rng.below(20) as u32,
    }
}

fn gen_case(rng: &mut Rng, size: usize) -> CacheCase {
    let (_, pool) = fixture();
    let n_ops = rng.range(4, size.clamp(5, 14));
    let mut ops = Vec::with_capacity(n_ops);
    for i in 0..n_ops {
        if rng.chance(0.3) {
            let docs = (0..rng.range(1, 3)).map(|k| gen_doc(rng, i * 8 + k)).collect();
            ops.push(Op::Ingest(docs));
        } else {
            ops.push(Op::Query(gen_request(rng, pool)));
        }
    }
    CacheCase { ops, seal_docs: [1, 2, 4][rng.range(0, 3)] }
}

/// Everything result-shaped, compared exactly (scores by bits); the
/// measured timeline is excluded (module docs).
fn assert_bit_identical(
    i: usize,
    query: &str,
    served: &Result<SearchResponse, SearchError>,
    serial: &Result<SearchResponse, SearchError>,
) -> Result<(), String> {
    match (served, serial) {
        (Err(qe), Err(se)) => {
            if qe.kind() != se.kind() {
                return Err(format!(
                    "op {i} {query:?}: served error {} vs cold error {}",
                    qe.kind(),
                    se.kind()
                ));
            }
        }
        (Ok(_), Err(se)) => {
            return Err(format!("op {i} {query:?}: cold failed ({se}), served ok"));
        }
        (Err(qe), Ok(_)) => {
            return Err(format!("op {i} {query:?}: served failed ({qe}), cold ok"));
        }
        (Ok(q), Ok(s)) => {
            if q.query != s.query {
                return Err(format!(
                    "op {i}: served echoed {:?}, cold echoed {:?}",
                    q.query, s.query
                ));
            }
            let ids_q: Vec<(u64, u32, &str)> =
                q.hits.iter().map(|h| (h.global_id, h.score.to_bits(), h.title.as_str())).collect();
            let ids_s: Vec<(u64, u32, &str)> =
                s.hits.iter().map(|h| (h.global_id, h.score.to_bits(), h.title.as_str())).collect();
            if ids_q != ids_s {
                return Err(format!("op {i} {query:?}: hits {ids_q:?} != {ids_s:?}"));
            }
            if (q.jobs, q.candidates, q.docs_scanned) != (s.jobs, s.candidates, s.docs_scanned) {
                return Err(format!(
                    "op {i} {query:?}: counters ({}, {}, {}) != ({}, {}, {})",
                    q.jobs, q.candidates, q.docs_scanned, s.jobs, s.candidates, s.docs_scanned
                ));
            }
            if (q.degraded, &q.missing_sources) != (s.degraded, &s.missing_sources) {
                return Err(format!("op {i} {query:?}: degradation flags diverged"));
            }
            if q.explain != s.explain {
                return Err(format!(
                    "op {i} {query:?}: explain diverged: {:?} != {:?}",
                    q.explain, s.explain
                ));
            }
        }
    }
    Ok(())
}

fn run_case(case: &CacheCase) -> Result<(), String> {
    let (dep, _) = fixture();

    // Cached side: the full serving stack (plan cache, result cache,
    // epoch invalidation) over the shared deployment.
    let mut serve_cfg = cfg();
    serve_cfg.storage.seal_docs = case.seal_docs;
    let mut oracle_cfg = serve_cfg.clone();
    oracle_cfg.cache.enabled = false;
    let dep_for_server = Arc::clone(dep);
    let server = SearchServer::start(
        QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
        move || GapsSystem::from_deployment(serve_cfg, dep_for_server),
    )
    .map_err(|e| e.to_string())?;
    let queue = server.queue();

    // Cold oracle: an identical system that never consults a cache.
    let mut oracle =
        GapsSystem::from_deployment(oracle_cfg, Arc::clone(dep)).map_err(|e| e.to_string())?;

    for (i, op) in case.ops.iter().enumerate() {
        match op {
            Op::Query(req) => {
                let served = queue.submit(req.clone());
                let cold = oracle.search_request(req);
                assert_bit_identical(i, &req.query, &served, &cold)?;
            }
            Op::Ingest(docs) => {
                let served = queue
                    .submit_ingest(docs.clone())
                    .map_err(|e| format!("op {i}: serve ingest failed: {e}"))?;
                let cold = oracle.ingest(docs.clone());
                if served != cold {
                    return Err(format!(
                        "op {i}: ingest reports diverged: {served:?} != {cold:?}"
                    ));
                }
            }
        }
    }

    // Both sides must have walked the same epoch history.
    let served_health = server.index_health().ok_or("no published health")?;
    let cold_health = oracle.index_health();
    if served_health != cold_health {
        return Err(format!("index health diverged: {served_health:?} != {cold_health:?}"));
    }
    server.shutdown();
    Ok(())
}

#[test]
fn prop_cached_serving_is_bit_identical_to_cold_execution() {
    let prop_cfg = Config { cases: 25, max_size: 14, ..Config::default() };
    check("cache-parity", &prop_cfg, gen_case, run_case);
}

/// Deterministic stale-read pin: warm the cache, bump the epoch with a
/// matching doc, and require the post-epoch response to surface it —
/// byte-for-byte equal to the cache-disabled oracle throughout.
#[test]
fn post_epoch_queries_never_see_pre_epoch_results() {
    let (dep, _) = fixture();
    let mut serve_cfg = cfg();
    serve_cfg.storage.seal_docs = 1; // every ingest seals -> epoch bump
    let mut oracle_cfg = serve_cfg.clone();
    oracle_cfg.cache.enabled = false;
    let dep_for_server = Arc::clone(dep);
    let server = SearchServer::start(
        QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() },
        move || GapsSystem::from_deployment(serve_cfg, dep_for_server),
    )
    .unwrap();
    let queue = server.queue();
    let mut oracle = GapsSystem::from_deployment(oracle_cfg, Arc::clone(dep)).unwrap();

    let probe = SearchRequest::new("zyzzogeton");
    let mut doc = gen_doc(&mut Rng::new(7), 0);
    doc.title = "zyzzogeton retrieval".into();
    doc.abstract_text = "a freshly ingested publication about zyzzogeton".into();

    for round in 0..3 {
        // Identical queries before and after each ingest: the repeat
        // hits the cache, the post-ingest one must not.
        for rep in 0..2 {
            let served = queue.submit(probe.clone());
            let cold = oracle.search_request(&probe);
            assert_bit_identical(round * 10 + rep, &probe.query, &served, &cold)
                .unwrap_or_else(|e| panic!("{e}"));
        }
        let mut d = doc.clone();
        d.title = format!("zyzzogeton retrieval round {round}");
        let served = queue.submit_ingest(vec![d.clone()]).unwrap();
        let cold = oracle.ingest(vec![d]);
        assert_eq!(served, cold, "ingest reports diverged in round {round}");
        assert!(served.epoch > round as u64, "seal_docs=1 must move the epoch every round");
    }
    let last = queue.submit(probe.clone()).unwrap();
    assert!(
        last.hits.iter().any(|h| h.title.contains("round 2")),
        "the doc sealed by the final bump must be visible — a stale hit would hide it"
    );
    let stats = server.stats();
    assert!(stats.result_hits >= 1, "repeats before a bump must hit: {stats:?}");
    assert!(stats.result_invalidated >= 1, "bumps must invalidate: {stats:?}");
    server.shutdown();
}

/// Regression (commutative canonicalization): `b AND a` and `a AND b`
/// must share one fingerprint *and* produce bit-identical results, so
/// they share one cache entry.
#[test]
fn reordered_conjunctions_share_fingerprint_and_results() {
    let (dep, _) = fixture();
    let mut sys = GapsSystem::from_deployment(cfg(), Arc::clone(dep)).unwrap();
    let ab = SearchRequest::new("storage AND replication");
    let ba = SearchRequest::new("replication AND storage");
    let fp_ab = sys.compile_request(&ab).unwrap().fingerprint;
    let fp_ba = sys.compile_request(&ba).unwrap().fingerprint;
    assert_eq!(fp_ab, fp_ba, "reordered commutative operands must share a fingerprint");

    let r_ab = sys.search_request(&ab).unwrap();
    let r_ba = sys.search_request(&ba).unwrap();
    let hits_ab: Vec<(u64, u32)> =
        r_ab.hits.iter().map(|h| (h.global_id, h.score.to_bits())).collect();
    let hits_ba: Vec<(u64, u32)> =
        r_ba.hits.iter().map(|h| (h.global_id, h.score.to_bits())).collect();
    assert_eq!(hits_ab, hits_ba, "reordered conjunction changed the results");
    assert_eq!(r_ab.candidates, r_ba.candidates);
    assert_eq!(r_ab.docs_scanned, r_ba.docs_scanned);
}
