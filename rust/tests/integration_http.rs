//! End-to-end HTTP front-end test: bind the full serving stack
//! (HTTP listener -> admission queue -> executor-owned system) on an
//! ephemeral port and round-trip real JSON over real sockets.
//!
//! CI runs this file as an explicit job step (see
//! `.github/workflows/ci.yml`) — the serving layer is a release
//! surface, not an implementation detail.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use gaps::config::GapsConfig;
use gaps::coordinator::{GapsSystem, SearchResponse};
use gaps::fault::{ChaosPlan, FaultKind};
use gaps::serve::{HttpConfig, HttpServer, QueueConfig, SearchServer, ShutdownHandle};
use gaps::util::json::Json;

fn small_cfg() -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 400;
    cfg.workload.sub_shards = 4;
    cfg.search.use_xla = false;
    cfg
}

/// A full serving stack on an ephemeral port, torn down on drop.
struct TestStack {
    addr: SocketAddr,
    stopper: ShutdownHandle,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    server: Option<SearchServer>,
}

impl TestStack {
    fn start(queue_cfg: QueueConfig) -> TestStack {
        let cfg = small_cfg();
        Self::start_with(queue_cfg, HttpConfig::default(), move || GapsSystem::deploy(cfg, 3))
    }

    fn start_with<F>(queue_cfg: QueueConfig, http_cfg: HttpConfig, deploy: F) -> TestStack
    where
        F: FnOnce() -> Result<GapsSystem, gaps::search::SearchError> + Send + 'static,
    {
        let server = SearchServer::start(queue_cfg, deploy).unwrap();
        let http = HttpServer::bind_with("127.0.0.1:0", server.router(), http_cfg).unwrap();
        let addr = http.local_addr().unwrap();
        let stopper = http.shutdown_handle().unwrap();
        let accept_thread = std::thread::spawn(move || {
            http.serve().unwrap();
        });
        TestStack { addr, stopper, accept_thread: Some(accept_thread), server: Some(server) }
    }

    fn stats(&self) -> gaps::serve::QueueStats {
        self.server.as_ref().unwrap().stats()
    }
}

impl Drop for TestStack {
    fn drop(&mut self) {
        self.stopper.stop();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(s) = self.server.take() {
            s.shutdown();
        }
    }
}

/// Minimal HTTP/1.1 client: one request, one response, parsed status +
/// JSON body.
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: gaps-test\r\n");
    if let Some(body) = body {
        req.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            body.len()
        ));
    }
    req.push_str("Connection: close\r\n\r\n");
    if let Some(body) = body {
        req.push_str(body);
    }
    stream.write_all(req.as_bytes()).expect("send");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("receive");

    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    (status, Json::parse(body).unwrap_or_else(|e| panic!("bad body {body:?}: {e}")))
}

#[test]
fn healthz_reports_queue_counters() {
    let stack = TestStack::start(QueueConfig::default());
    let (status, body) = http(stack.addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert_eq!(body.get("status").unwrap().as_str(), Some("ok"));
    let queue = body.get("queue").expect("queue counters");
    for key in ["submitted", "executed", "batches", "coalesced", "largest_batch", "shed", "expired"]
    {
        assert!(queue.get(key).is_some(), "missing {key}");
    }
    // Sharded serving surfaces per-shard admission counters and the
    // HTTP front's connection counters next to the aggregate.
    let shards = body.get("shards").expect("per-shard counters").as_arr().unwrap();
    assert_eq!(shards.len(), 1, "single-shard stack");
    assert!(shards[0].get("submitted").is_some());
    let http_counters = body.get("http").expect("connection counters");
    for key in ["accepted", "active", "shed", "requests", "reused"] {
        assert!(http_counters.get(key).is_some(), "missing http.{key}");
    }
}

/// Send raw bytes and read whatever response comes back (for requests
/// the well-formed [`http`] helper cannot express).
fn http_raw(addr: SocketAddr, raw: &[u8]) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(raw).expect("send");
    let mut text = String::new();
    stream.read_to_string(&mut text).expect("receive");
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {text:?}"));
    (status, text)
}

#[test]
fn oversized_body_is_413_over_the_wire() {
    let stack = TestStack::start(QueueConfig::default());
    // The server must reject on the declared length alone — no body
    // bytes are ever sent, so a 413 here proves it did not try to
    // buffer 2 MB first.
    let (status, text) = http_raw(
        stack.addr,
        b"POST /search HTTP/1.1\r\nHost: gaps-test\r\nContent-Length: 2097152\r\n\r\n",
    );
    assert_eq!(status, 413, "{text}");
    assert!(text.contains("bad-request"), "{text}");
}

#[test]
fn stalled_client_is_answered_408() {
    // A client that sends half a request and then goes quiet must get a
    // 408 once the socket read timeout fires — not pin its handler
    // thread forever.
    let cfg = small_cfg();
    let http_cfg = HttpConfig {
        read_timeout: Duration::from_millis(150),
        write_timeout: Duration::from_millis(1000),
        ..HttpConfig::default()
    };
    let stack = TestStack::start_with(QueueConfig::default(), http_cfg, move || {
        GapsSystem::deploy(cfg, 3)
    });

    let mut stream = TcpStream::connect(stack.addr).expect("connect");
    // Declared 20-byte body, 4 bytes delivered, then silence.
    stream
        .write_all(b"POST /search HTTP/1.1\r\nHost: gaps-test\r\nContent-Length: 20\r\n\r\n{\"qu")
        .expect("send partial");
    let mut text = String::new();
    stream.read_to_string(&mut text).expect("receive");
    assert!(text.starts_with("HTTP/1.1 408 "), "{text}");
    assert!(text.contains("\"timeout\""), "{text}");
}

#[test]
fn downed_node_recovers_behind_the_http_front() {
    // A flaky node fails its first job (mid-flight failover keeps the
    // response complete), sits out probation, recovers, and rejoins —
    // all invisible to HTTP clients except in the failover counters.
    let mut cfg = small_cfg();
    cfg.grid.probe_after_ticks = 1;
    let stack = TestStack::start_with(QueueConfig::default(), HttpConfig::default(), move || {
        let mut sys = GapsSystem::deploy(cfg, 3)?;
        let victim = sys.deployment().active[1];
        sys.set_fault_injector(
            ChaosPlan::new().with_fault(victim, FaultKind::FlakyThenRecover { failures: 1 }),
        );
        Ok(sys)
    });
    for _ in 0..2 {
        let (status, body) =
            http(stack.addr, "POST", "/search", Some(r#"{"query": "grid computing"}"#));
        assert_eq!(status, 200, "{body:?}");
        let resp = SearchResponse::from_json(&body).unwrap();
        assert!(!resp.degraded, "failover must keep full coverage");
        assert_eq!(resp.docs_scanned, 400);
    }
}

#[test]
fn search_roundtrips_the_shared_wire_forms() {
    let stack = TestStack::start(QueueConfig {
        max_batch: 8,
        max_linger: Duration::from_millis(1),
        ..QueueConfig::default()
    });
    let (status, body) = http(
        stack.addr,
        "POST",
        "/search",
        Some(r#"{"query": "grid computing", "top_k": 5, "explain": true}"#),
    );
    assert_eq!(status, 200, "{body:?}");
    // The response is the *existing* SearchResponse wire form.
    let resp = SearchResponse::from_json(&body).expect("SearchResponse JSON");
    assert_eq!(resp.query, "grid computing");
    assert!(resp.hits.len() <= 5);
    assert!(resp.jobs >= 1);
    assert!(resp.explain.is_some(), "explain requested over the wire");
}

#[test]
fn search_errors_map_to_statuses() {
    let stack = TestStack::start(QueueConfig::default());
    // Parse failure -> 400 with the typed error envelope.
    let (status, body) =
        http(stack.addr, "POST", "/search", Some(r#"{"query": "the of and"}"#));
    assert_eq!(status, 400);
    assert_eq!(body.get("kind").unwrap().as_str(), Some("parse"));
    assert!(body.get("message").is_some());

    // Malformed protocol bodies.
    assert_eq!(http(stack.addr, "POST", "/search", Some("not json")).0, 400);
    assert_eq!(http(stack.addr, "POST", "/search", Some("{\"q\": 1}")).0, 400);

    // Routing errors.
    assert_eq!(http(stack.addr, "GET", "/nope", None).0, 404);
    assert_eq!(http(stack.addr, "DELETE", "/search", None).0, 405);
}

#[test]
fn search_batch_settles_every_request() {
    let stack = TestStack::start(QueueConfig::default());
    let body = r#"{"requests": [
        {"query": "grid computing"},
        {"query": "the of and"},
        {"query": "data retrieval", "top_k": 2}
    ]}"#;
    let (status, body) = http(stack.addr, "POST", "/search_batch", Some(body));
    assert_eq!(status, 200);
    let results = body.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 3);
    assert!(results[0].get("ok").is_some(), "{:?}", results[0]);
    let err = results[1].get("error").expect("parse error mid-batch");
    assert_eq!(err.get("kind").unwrap().as_str(), Some("parse"));
    let third = SearchResponse::from_json(results[2].get("ok").unwrap()).unwrap();
    assert!(third.hits.len() <= 2);
}

#[test]
fn concurrent_http_clients_are_coalesced() {
    // Generous linger so concurrently arriving HTTP requests land in
    // shared rounds; the /healthz counters make that observable.
    let stack = TestStack::start(QueueConfig {
        max_batch: 16,
        max_linger: Duration::from_millis(300),
        ..QueueConfig::default()
    });
    let users = 6;
    let addr = stack.addr;
    let barrier = Arc::new(Barrier::new(users));
    std::thread::scope(|s| {
        for i in 0..users {
            let barrier = Arc::clone(&barrier);
            s.spawn(move || {
                barrier.wait();
                let (status, body) = http(
                    addr,
                    "POST",
                    "/search",
                    Some(&format!(r#"{{"query": "grid data search {i}"}}"#)),
                );
                assert_eq!(status, 200, "{body:?}");
            });
        }
    });
    let stats = stack.stats();
    assert_eq!(stats.submitted, users as u64);
    assert_eq!(stats.executed, users as u64);
    assert!(stats.batches < users as u64, "no coalescing: {stats:?}");
    assert!(stats.largest_batch >= 2, "no multi-request round: {stats:?}");

    // The counters are also visible over the wire.
    let (_, health) = http(addr, "GET", "/healthz", None);
    let batches = health.get("queue").unwrap().get("batches").unwrap().as_i64().unwrap();
    assert!(batches >= 1);
}
