//! Grid + coordinator integration (artifact-free: rust scorer backend).
//! Exercises the full deploy -> plan -> dispatch -> search -> merge flow
//! across module boundaries, including the paper's qualitative claims.

use std::sync::Arc;

use gaps::baseline::TraditionalSearch;
use gaps::config::{GapsConfig, SchedulePolicy};
use gaps::coordinator::{Deployment, GapsSystem};
use gaps::metrics::{run_node_sweep, sample_queries, System};

fn cfg(docs: u64) -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = docs;
    cfg.workload.num_queries = 6;
    cfg.workload.sub_shards = 8;
    cfg.search.use_xla = false;
    cfg
}

#[test]
fn recall_is_complete_across_shards() {
    // Searching for each document's own title must find it, wherever its
    // shard landed — proves plan coverage + merge correctness end to end.
    let mut sys = GapsSystem::deploy(cfg(900), 6).unwrap();
    for id in [0u64, 123, 456, 789, 899] {
        let title = sys.deployment().publication(id).unwrap().title.clone();
        let resp = sys.search(&title).unwrap();
        assert!(
            resp.hits.iter().any(|h| h.global_id == id),
            "doc {id} not found by its own title"
        );
    }
}

#[test]
fn gaps_and_traditional_agree_on_results() {
    let c = cfg(800);
    let dep = Arc::new(Deployment::build(&c, 5).unwrap());
    let mut gaps_sys = GapsSystem::from_deployment(c.clone(), Arc::clone(&dep)).unwrap();
    let mut trad = TraditionalSearch::from_deployment(c.clone(), Arc::clone(&dep)).unwrap();
    for q in sample_queries(&dep, 8, 99) {
        let g = gaps_sys.search(&q).unwrap();
        let t = trad.search(&q).unwrap();
        assert_eq!(
            g.hits.iter().map(|h| h.global_id).collect::<Vec<_>>(),
            t.hits.iter().map(|h| h.global_id).collect::<Vec<_>>(),
            "result divergence on {q:?}"
        );
    }
}

#[test]
fn perf_history_improves_balance_over_queries() {
    // After warmup the LPT planner should beat round-robin's critical
    // path on heterogeneous nodes (same deployment, same queries).
    let mut c = cfg(1200);
    c.grid.speed_min = 0.4;
    c.grid.speed_max = 1.6;
    let dep = Arc::new(Deployment::build(&c, 6).unwrap());
    let queries = sample_queries(&dep, 10, 1234);

    let mut gaps_sys = GapsSystem::from_deployment(c.clone(), Arc::clone(&dep)).unwrap();
    for q in &queries {
        gaps_sys.search(q).unwrap(); // builds history
    }
    let mut adapted_work = 0.0;
    for q in &queries {
        adapted_work += gaps_sys.search(q).unwrap().timeline.work_s;
    }

    let mut rr = c.clone();
    rr.search.policy = SchedulePolicy::RoundRobin;
    let mut rr_sys = GapsSystem::from_deployment(rr, Arc::clone(&dep)).unwrap();
    let mut rr_work = 0.0;
    for q in &queries {
        rr_work += rr_sys.search(q).unwrap().timeline.work_s;
    }
    assert!(
        adapted_work < rr_work,
        "perf-history critical-path work {adapted_work} !< round-robin {rr_work}"
    );
}

#[test]
fn failure_mid_experiment_preserves_recall() {
    let mut sys = GapsSystem::deploy(cfg(600), 6).unwrap();
    let victim = sys.deployment().active[2];
    let title = sys.deployment().publication(300).unwrap().title.clone();
    // Before failure.
    assert!(sys.search(&title).unwrap().hits.iter().any(|h| h.global_id == 300));
    // Fail a node; replica coverage must preserve recall.
    sys.fail_node(victim);
    let resp = sys.search(&title).unwrap();
    assert!(
        resp.hits.iter().any(|h| h.global_id == 300),
        "recall lost after failing {victim}"
    );
    assert_eq!(resp.docs_scanned, 600, "some sources were skipped");
}

#[test]
fn sweep_reproduces_robust_directional_claims() {
    // At integration-test scale (small corpus, rust scorer) the fabric
    // constants dominate real work, so we assert only the claims that are
    // scale-independent; the full Fig 3/4/5 shapes (speedup/efficiency
    // crossovers) are validated by the benches at realistic workloads.
    let c = cfg(1000);
    let sweep = run_node_sweep(&c, &[1, 2, 4, 6]).unwrap();
    let serial_g = sweep.serial_response_s(System::Gaps);
    // 1. GAPS responds faster than traditional at every point (Fig 3).
    for p in &sweep.points {
        assert!(
            p.gaps.response_s < p.traditional.response_s,
            "n={}: gaps {} !< trad {}",
            p.nodes,
            p.gaps.response_s,
            p.traditional.response_s
        );
    }
    // 2. The container-resident SS design removes the per-job cold start
    //    the traditional system pays (paper §III.3): traditional overhead
    //    carries >= one cold start at every n, GAPS stays well under it.
    let cold_s = c.grid.cold_start_ms * 1e-3;
    let last = sweep.points.last().unwrap();
    for p in &sweep.points {
        assert!(
            p.traditional.overhead_s >= cold_s,
            "n={}: trad overhead {} lost its cold start",
            p.nodes,
            p.traditional.overhead_s
        );
        assert!(
            p.gaps.overhead_s < cold_s,
            "n={}: gaps overhead {} should stay under one cold start",
            p.nodes,
            p.gaps.overhead_s
        );
    }
    // 3. Efficiency decreases with node count (Fig 5, both systems).
    let e2 = sweep.points[1].efficiency(serial_g, System::Gaps);
    let e6 = last.efficiency(serial_g, System::Gaps);
    assert!(e6 < e2, "gaps efficiency should fall with n: {e2} -> {e6}");
}

#[test]
fn multivariate_queries_work_end_to_end() {
    let mut sys = GapsSystem::deploy(cfg(700), 4).unwrap();
    let p = sys.deployment().publication(99).unwrap().clone();
    let word = p.title.split_whitespace().next().unwrap();
    let q = format!("{word} year:{}..{}", p.year, p.year);
    let resp = sys.search(&q).unwrap();
    for h in &resp.hits {
        let hit_pub = sys.deployment().publication(h.global_id).unwrap();
        assert_eq!(hit_pub.year, p.year, "year filter leaked {}", h.global_id);
    }
}

#[test]
fn jsonl_export_reimports_identically() {
    // corpus subcommand path: save shards, reload, same analyzed docs.
    let c = cfg(300);
    let dep = Deployment::build(&c, 2).unwrap();
    let dir = std::env::temp_dir().join("gaps_it_export");
    std::fs::create_dir_all(&dir).unwrap();
    for src in dep.locator.sources().iter().take(2) {
        let shard = dep.shard(src.id).unwrap();
        let path = dir.join(format!("s{}.jsonl", src.id));
        shard.save_jsonl(&path).unwrap();
        let loaded = gaps::index::Shard::load_jsonl(src.id, &path, 512).unwrap();
        assert_eq!(loaded.pubs, shard.pubs);
        std::fs::remove_file(&path).unwrap();
    }
}
