//! End-to-end integration over the REAL production path: GAPS with the
//! PJRT/XLA scoring backend (the AOT artifacts), compared against the
//! rust-scorer configuration. Requires `make artifacts`.

use std::path::Path;
use std::sync::Arc;

use gaps::config::GapsConfig;
use gaps::coordinator::{Deployment, GapsSystem};
use gaps::metrics::sample_queries;

fn artifact_cfg(docs: u64) -> Option<GapsConfig> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = docs;
    cfg.workload.num_queries = 4;
    cfg.workload.sub_shards = 8;
    cfg.search.use_xla = true;
    Some(cfg)
}

#[test]
fn xla_backend_answers_queries() {
    let Some(cfg) = artifact_cfg(600) else { return };
    let mut sys = GapsSystem::deploy(cfg, 4).unwrap();
    let title = sys.deployment().publication(42).unwrap().title.clone();
    let resp = sys.search(&title).unwrap();
    assert!(resp.hits.iter().any(|h| h.global_id == 42));
    assert!(resp.response_s() > 0.0);
}

#[test]
fn xla_and_rust_backends_return_identical_rankings() {
    let Some(cfg) = artifact_cfg(800) else { return };
    let dep = Arc::new(Deployment::build(&cfg, 4).unwrap());

    let mut xla_sys = GapsSystem::from_deployment(cfg.clone(), Arc::clone(&dep)).unwrap();
    let mut rust_cfg = cfg.clone();
    rust_cfg.search.use_xla = false;
    let mut rust_sys = GapsSystem::from_deployment(rust_cfg, Arc::clone(&dep)).unwrap();

    for q in sample_queries(&dep, 6, 2024) {
        let x = xla_sys.search(&q).unwrap();
        let r = rust_sys.search(&q).unwrap();
        assert_eq!(
            x.hits.iter().map(|h| h.global_id).collect::<Vec<_>>(),
            r.hits.iter().map(|h| h.global_id).collect::<Vec<_>>(),
            "backend divergence on {q:?}"
        );
        for (hx, hr) in x.hits.iter().zip(&r.hits) {
            assert!(
                (hx.score - hr.score).abs() < 1e-3 * hr.score.abs().max(1.0),
                "score drift on {q:?}: {} vs {}",
                hx.score,
                hr.score
            );
        }
    }
}

#[test]
fn failure_recovery_works_on_xla_path() {
    let Some(cfg) = artifact_cfg(600) else { return };
    let mut sys = GapsSystem::deploy(cfg, 6).unwrap();
    let victim = sys.deployment().active[1];
    sys.fail_node(victim);
    let title = sys.deployment().publication(100).unwrap().title.clone();
    let resp = sys.search(&title).unwrap();
    assert!(resp.hits.iter().any(|h| h.global_id == 100));
    assert_eq!(resp.docs_scanned, 600);
}

#[test]
fn usi_one_shot_over_xla() {
    let Some(cfg) = artifact_cfg(500) else { return };
    let mut sys = GapsSystem::deploy(cfg, 3).unwrap();
    let (rendered, timing) = gaps::usi::one_shot(&mut sys, "grid distributed search").unwrap();
    assert!(rendered.contains("response time"));
    // Paper §III.4: USI overhead is very small vs response time.
    assert!(
        timing.interface_fraction() < 0.2,
        "USI overhead {:.1}% too large",
        timing.interface_fraction() * 100.0
    );
}
