//! Property-based tests over coordinator/search invariants, using the
//! in-repo prop harness (util::prop — proptest is not in the offline
//! vendored crate set).

use gaps::config::SchedulePolicy;
use gaps::coordinator::{merge_topk, DataSource, PerfDb, QueryExecutionEngine};
use gaps::grid::{NodeId, NodeInfo, VoId};
use gaps::search::LocalHit;
use gaps::text::{term_feature, terms};
use gaps::util::prop::{check, gen_text, Config};
use gaps::util::rng::Rng;

fn prop_cfg(cases: usize) -> Config {
    Config { cases, ..Config::default() }
}

// ---------------------------------------------------------------- tokenizer

#[test]
fn prop_tokenizer_terms_are_normalized() {
    check(
        "tokenizer-normalized",
        &prop_cfg(200),
        |rng, size| gen_text(rng, size),
        |text| {
            terms(text).iter().all(|t| {
                !t.is_empty()
                    && *t == t.to_lowercase()
                    && !gaps::text::STOPWORDS.contains(&t.as_str())
            })
        },
    );
}

#[test]
fn prop_tokenizer_idempotent() {
    // Tokenizing the joined terms yields the same terms (stemming is a
    // projection: stem(stem(x)) == stem(x) for our suffix rules).
    check(
        "tokenizer-idempotent",
        &prop_cfg(200),
        |rng, size| gen_text(rng, size),
        |text| {
            let once = terms(text);
            let twice = terms(&once.join(" "));
            if once == twice {
                Ok(())
            } else {
                Err(format!("{once:?} != {twice:?}"))
            }
        },
    );
}

#[test]
fn prop_term_features_in_range() {
    check(
        "feature-range",
        &prop_cfg(100),
        |rng, size| {
            let f = 1 << rng.range(4, 11);
            (gen_text(rng, size), f)
        },
        |(text, f)| terms(text).iter().all(|t| term_feature(t, *f) < *f),
    );
}

// -------------------------------------------------------------------- merge

fn gen_sorted_lists(rng: &mut Rng, size: usize) -> Vec<Vec<LocalHit>> {
    let nlists = rng.range(0, 6);
    (0..nlists)
        .map(|li| {
            let n = rng.range(0, size + 1);
            let mut l: Vec<LocalHit> = (0..n)
                .map(|i| LocalHit {
                    global_id: (li * 1000 + i) as u64,
                    score: (rng.below(100) as f32) / 7.0,
                })
                .collect();
            l.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
            l
        })
        .collect()
}

#[test]
fn prop_merge_output_sorted_and_bounded() {
    check(
        "merge-sorted-bounded",
        &prop_cfg(300),
        |rng, size| (gen_sorted_lists(rng, size), rng.range(1, 20)),
        |(lists, k)| {
            let merged = merge_topk(lists, *k);
            let total: usize = lists.iter().map(|l| l.len()).sum();
            merged.len() <= (*k).min(total)
                && merged.windows(2).all(|w| w[0].score >= w[1].score)
        },
    );
}

#[test]
fn prop_merge_contains_global_max() {
    check(
        "merge-has-max",
        &prop_cfg(300),
        |rng, size| gen_sorted_lists(rng, size),
        |lists| {
            let all: Vec<&LocalHit> = lists.iter().flatten().collect();
            if all.is_empty() {
                return true;
            }
            let max = all
                .iter()
                .map(|h| h.score)
                .fold(f32::NEG_INFINITY, f32::max);
            let merged = merge_topk(lists, 1);
            merged[0].score == max
        },
    );
}

// ---------------------------------------------------------------- retrieval

/// Differential oracle: the block-max WAND retrieval must return
/// identical (doc, score) sets *and order* to the naive HashMap +
/// full-sort reference (`retrieve_reference`) — pruning may only skip
/// work, never change results. One scratch is reused across every case
/// so stale-state bugs (unclean cursor/heap reuse) surface too.
#[test]
fn prop_blockmax_retrieval_matches_naive_reference() {
    use gaps::corpus::{CorpusGenerator, CorpusSpec};
    use gaps::index::{RetrievalScratch, Shard};

    const FEATURES: usize = 256;
    let gen = CorpusGenerator::new(CorpusSpec {
        num_docs: 400,
        vocab_size: 500,
        ..CorpusSpec::default()
    });
    let shard = Shard::build(0, gen.generate_range(0, 400), FEATURES);
    let scratch = std::cell::RefCell::new(RetrievalScratch::new());

    check(
        "blockmax-retrieval-differential",
        &prop_cfg(400),
        |rng, size| {
            let n = rng.range(1, size.max(2));
            // Duplicates + out-of-range buckets allowed on purpose.
            let buckets: Vec<u32> =
                (0..n).map(|_| rng.below(FEATURES as u64 + 8) as u32).collect();
            let k = rng.range(1, 80);
            (buckets, k)
        },
        |(buckets, k)| {
            let mut s = scratch.borrow_mut();
            shard.inverted.retrieve_into(buckets, *k, &mut s);
            let want = shard.inverted.retrieve_reference(buckets, *k);
            if s.hits() != want.as_slice() {
                return Err(format!(
                    "blockmax returned {} hits, naive {} (k={k}); first diff at {:?}",
                    s.hits().len(),
                    want.len(),
                    s.hits().iter().zip(&want).position(|(a, b)| a != b),
                ));
            }
            let c = s.counters();
            if c.postings_touched > c.postings_total {
                return Err(format!("counters overcount: {c:?}"));
            }
            Ok(())
        },
    );
}

/// Satellite: block-max top-k results (ids and scores) pinned identical
/// to `retrieve_reference` across random corpora, block sizes, and k
/// values. Small block sizes force block boundaries into the middle of
/// every posting list, exercising the seek/jump edges.
#[test]
fn prop_blockmax_identical_across_corpora_block_sizes_and_k() {
    use gaps::corpus::{CorpusGenerator, CorpusSpec};
    use gaps::index::{InvertedIndex, RetrievalScratch, Shard};

    const FEATURES: usize = 256;
    const BLOCK_SIZES: [usize; 4] = [1, 3, 17, 128];
    // Corpora of different shapes (docs, vocab, seed).
    let corpora = [(350u64, 300usize, 11u64), (120, 900, 23), (500, 200, 5)];
    let variants: Vec<(Shard, Vec<InvertedIndex>)> = corpora
        .iter()
        .map(|&(n, vocab, seed)| {
            let gen = CorpusGenerator::new(CorpusSpec {
                num_docs: n,
                vocab_size: vocab,
                seed,
                ..CorpusSpec::default()
            });
            let shard = Shard::build(0, gen.generate_range(0, n), FEATURES);
            let indexes = BLOCK_SIZES
                .iter()
                .map(|&bs| InvertedIndex::build_with_block_size(&shard.docs, FEATURES, bs))
                .collect();
            (shard, indexes)
        })
        .collect();
    let scratch = std::cell::RefCell::new(RetrievalScratch::new());

    check(
        "blockmax-block-size-differential",
        &prop_cfg(200),
        |rng, size| {
            let corpus = rng.range(0, corpora.len());
            let n = rng.range(1, size.max(2).min(10));
            let buckets: Vec<u32> =
                (0..n).map(|_| rng.below(FEATURES as u64) as u32).collect();
            let k = rng.range(1, 600);
            (corpus, buckets, k)
        },
        |(corpus, buckets, k)| {
            let (shard, indexes) = &variants[*corpus];
            let want = shard.inverted.retrieve_reference(buckets, *k);
            let mut s = scratch.borrow_mut();
            for (bs, ix) in BLOCK_SIZES.iter().zip(indexes) {
                ix.retrieve_into(buckets, *k, &mut s);
                if s.hits() != want.as_slice() {
                    return Err(format!(
                        "corpus {corpus} bs={bs} k={k}: {} hits != reference {}",
                        s.hits().len(),
                        want.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// AND-retrieval differential: the block-skipping leapfrog intersection
/// must equal a straightforward retain/binary-search intersection, and
/// respect its candidate limit.
#[test]
fn prop_galloping_intersection_matches_naive() {
    use gaps::corpus::{CorpusGenerator, CorpusSpec};
    use gaps::index::Shard;

    const FEATURES: usize = 128;
    let gen = CorpusGenerator::new(CorpusSpec {
        num_docs: 300,
        vocab_size: 400,
        ..CorpusSpec::default()
    });
    let shard = Shard::build(0, gen.generate_range(0, 300), FEATURES);

    check(
        "galloping-intersection-differential",
        &prop_cfg(300),
        |rng, size| {
            let n = rng.range(1, size.max(2).min(6));
            let buckets: Vec<u32> =
                (0..n).map(|_| rng.below(FEATURES as u64) as u32).collect();
            let limit = rng.range(1, 400);
            (buckets, limit)
        },
        |(buckets, limit)| {
            let got = shard.inverted.retrieve_all(buckets, *limit);
            // Naive: intersect via per-element binary search.
            let mut uniq = buckets.clone();
            uniq.sort_unstable();
            uniq.dedup();
            let mut want: Vec<u32> = shard.inverted.postings(uniq[0]).to_vec();
            for b in &uniq[1..] {
                let list = shard.inverted.postings(*b);
                want.retain(|d| list.binary_search(d).is_ok());
            }
            want.sort_unstable();
            want.truncate(*limit);
            if got == want {
                Ok(())
            } else {
                Err(format!(
                    "leapfrog {} docs != naive {} docs (limit {limit})",
                    got.len(),
                    want.len()
                ))
            }
        },
    );
}

/// Cross-replica dedup: when several nodes return the same document (the
/// replica placement guarantees identical scores), the merged top-k must
/// contain it exactly once and still fill up from the remaining lists.
#[test]
fn prop_merge_dedups_replica_lists() {
    check(
        "merge-replica-dedup",
        &prop_cfg(300),
        |rng, size| {
            let lists = gen_sorted_lists(rng, size);
            // Duplicate one list wholesale (a replica answering the same
            // sources) and permute the pair's position.
            let mut with_replica = lists.clone();
            if let Some(l) = lists.first() {
                with_replica.push(l.clone());
            }
            (lists, with_replica, rng.range(1, 16))
        },
        |(lists, with_replica, k)| {
            let base = merge_topk(lists, *k);
            let dedup = merge_topk(with_replica, *k);
            // Identical output: the replica contributes nothing new.
            if base.len() != dedup.len() {
                return Err(format!("replica changed len {} -> {}", base.len(), dedup.len()));
            }
            for (a, b) in base.iter().zip(&dedup) {
                if a.global_id != b.global_id || a.score != b.score {
                    return Err(format!("replica changed hit {a:?} -> {b:?}"));
                }
            }
            // And no id appears twice.
            let mut ids: Vec<u64> = dedup.iter().map(|h| h.global_id).collect();
            ids.sort_unstable();
            ids.dedup();
            if ids.len() != dedup.len() {
                return Err("duplicate global_id in merged top-k".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- scheduler

struct PlanCase {
    sources: Vec<DataSource>,
    nodes: Vec<NodeInfo>,
    perf_samples: Vec<(u32, u64, f64)>,
    policy: SchedulePolicy,
}

impl std::fmt::Debug for PlanCase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PlanCase({} sources, {} nodes, {:?})",
            self.sources.len(),
            self.nodes.len(),
            self.policy
        )
    }
}

fn gen_plan_case(rng: &mut Rng, size: usize) -> PlanCase {
    let n_nodes = rng.range(1, 9);
    let nodes: Vec<NodeInfo> = (0..n_nodes)
        .map(|i| NodeInfo {
            id: NodeId(i as u32),
            vo: VoId((i % 3) as u32),
            speed_factor: rng.range_f64(0.3, 2.0),
            is_broker: i < 3,
        })
        .collect();
    let n_sources = rng.range(1, size.max(2));
    let sources: Vec<DataSource> = (0..n_sources)
        .map(|i| {
            let primary = rng.range(0, n_nodes);
            let secondary = rng.range(0, n_nodes);
            let mut replicas = vec![NodeId(primary as u32)];
            if secondary != primary {
                replicas.push(NodeId(secondary as u32));
            }
            DataSource {
                id: i as u32,
                doc_start: i as u64 * 100,
                doc_count: rng.range(10, 500) as u64,
                replicas,
            }
        })
        .collect();
    let perf_samples = (0..rng.range(0, 10))
        .map(|_| {
            (
                rng.range(0, n_nodes) as u32,
                rng.range(100, 5000) as u64,
                rng.range_f64(0.05, 2.0),
            )
        })
        .collect();
    let policy = if rng.chance(0.5) {
        SchedulePolicy::PerfHistory
    } else {
        SchedulePolicy::RoundRobin
    };
    PlanCase { sources, nodes, perf_samples, policy }
}

#[test]
fn prop_plan_covers_every_source_exactly_once() {
    check(
        "plan-coverage",
        &prop_cfg(300),
        gen_plan_case,
        |case| {
            let mut perf = PerfDb::default();
            for &(node, docs, secs) in &case.perf_samples {
                perf.record(NodeId(node), docs, secs);
            }
            let refs: Vec<&DataSource> = case.sources.iter().collect();
            let plan = QueryExecutionEngine
                .plan(
                    &refs,
                    &case.nodes,
                    &perf,
                    case.policy,
                    gaps::search::ReplicaPref::Any,
                    None,
                )
                .expect("all replicas live");
            let mut assigned: Vec<u32> =
                plan.assignments.values().flatten().copied().collect();
            assigned.sort_unstable();
            let want: Vec<u32> = (0..case.sources.len() as u32).collect();
            if assigned == want {
                Ok(())
            } else {
                Err(format!("assigned {assigned:?} != {want:?}"))
            }
        },
    );
}

#[test]
fn prop_plan_respects_replica_placement() {
    check(
        "plan-placement",
        &prop_cfg(300),
        gen_plan_case,
        |case| {
            let refs: Vec<&DataSource> = case.sources.iter().collect();
            let plan = QueryExecutionEngine
                .plan(
                    &refs,
                    &case.nodes,
                    &PerfDb::default(),
                    case.policy,
                    gaps::search::ReplicaPref::Any,
                    None,
                )
                .unwrap();
            for (node, sids) in &plan.assignments {
                for sid in sids {
                    if !case.sources[*sid as usize].replicas.contains(node) {
                        return Err(format!("source {sid} assigned off-replica to {node}"));
                    }
                }
            }
            Ok(())
        },
    );
}

// -------------------------------------------------------------------- stats

#[test]
fn prop_summary_percentiles_monotone() {
    check(
        "percentiles-monotone",
        &prop_cfg(200),
        |rng, size| {
            let n = rng.range(1, size.max(2));
            (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect::<Vec<f64>>()
        },
        |xs| {
            let mut s = gaps::util::stats::Summary::new();
            for &x in xs {
                s.add(x);
            }
            let (p10, p50, p90) = (s.percentile(10.0), s.percentile(50.0), s.percentile(90.0));
            p10 <= p50 && p50 <= p90 && s.min() <= p10 && p90 <= s.max()
        },
    );
}

// --------------------------------------------------------------------- json

#[test]
fn prop_json_roundtrip_publications() {
    use gaps::corpus::{CorpusGenerator, CorpusSpec};
    let gen = CorpusGenerator::new(CorpusSpec {
        num_docs: 500,
        vocab_size: 300,
        ..CorpusSpec::default()
    });
    check(
        "publication-json-roundtrip",
        &prop_cfg(100),
        |rng, _| gen.generate(rng.below(500)),
        |p| {
            let json = p.to_json().to_string_pretty();
            let parsed = gaps::util::json::Json::parse(&json).unwrap();
            match gaps::corpus::Publication::from_json(&parsed) {
                Some(q) if q == *p => Ok(()),
                other => Err(format!("roundtrip failed: {other:?}")),
            }
        },
    );
}
