//! Runtime integration: the AOT HLO artifacts must load, compile, execute
//! and agree with the pure-rust scorer (which mirrors the python oracle).
//!
//! Requires `make artifacts` (skips with a clear message otherwise — CI
//! runs the Makefile `test` target, which builds them first).

use std::path::Path;

use gaps::corpus::{CorpusGenerator, CorpusSpec};
use gaps::index::{build_query_weights, pack_block, GlobalStats, Shard, ShardStats};
use gaps::runtime::{Executor, Manifest};
use gaps::search::score_block_rust;
use gaps::text::NUM_FIELDS;

const FIELD_W: [f32; NUM_FIELDS] = [2.0, 1.0, 1.5, 0.5];
const K1: f32 = 1.2;

fn artifact_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn setup(n: u64, features: usize) -> (Shard, GlobalStats) {
    let spec = CorpusSpec { num_docs: n, vocab_size: 600, ..CorpusSpec::default() };
    let gen = CorpusGenerator::new(spec);
    let shard = Shard::build(0, gen.generate_range(0, n), features);
    let mut acc = ShardStats::empty(features);
    acc.merge(&shard.stats);
    (shard, acc.finalize())
}

#[test]
fn manifest_lists_expected_variants() {
    let Some(dir) = artifact_dir() else { return };
    let m = Manifest::load(dir).unwrap();
    assert!(m.artifacts.len() >= 4, "expected >=4 variants");
    assert!((m.k1 - 1.2).abs() < 1e-9);
    // The standard shapes exist.
    assert!(m.select(1, 200, 512).is_some());
    assert!(m.select(8, 1000, 512).is_some());
}

#[test]
fn executor_compiles_all_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let exec = Executor::new(dir).unwrap();
    assert!(!exec.platform().is_empty());
    assert_eq!(exec.executions(), 0);
}

#[test]
fn xla_scores_match_rust_scorer() {
    let Some(dir) = artifact_dir() else { return };
    let mut exec = Executor::new(dir).unwrap();
    let (shard, stats) = setup(300, 512);

    // Query from document 12's title: real overlap guaranteed.
    let q = gaps::search::Query::parse(&shard.pubs[12].title, 512).unwrap();
    let candidates: Vec<u32> = (0..256).collect();
    let block = pack_block(&shard, &stats, &candidates, 256, 0.75);
    let qw = build_query_weights(&[q.buckets.clone()], &stats, 512, 1);

    let xla = exec.rank(&block, &qw, 1, &FIELD_W).unwrap();
    assert_eq!(exec.executions(), 1);
    let rust_scores = score_block_rust(&block, &qw, 1, &FIELD_W, K1);

    // Every XLA hit must carry the same score the rust scorer computes.
    assert!(!xla[0].is_empty(), "no hits for a guaranteed-overlap query");
    for &(idx, score) in &xla[0] {
        let want = rust_scores[idx as usize];
        assert!(
            (score - want).abs() < 1e-3 * want.abs().max(1.0),
            "idx {idx}: xla {score} vs rust {want}"
        );
    }
    // And the top XLA hit is the rust argmax.
    let rust_top = rust_scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(xla[0][0].0 as usize, rust_top);
}

#[test]
fn padding_never_appears_in_results() {
    let Some(dir) = artifact_dir() else { return };
    let mut exec = Executor::new(dir).unwrap();
    let (shard, stats) = setup(80, 512);
    // Only 5 real candidates in a 256-capacity block.
    let candidates: Vec<u32> = (0..5).collect();
    let block = pack_block(&shard, &stats, &candidates, 256, 0.75);
    let q = gaps::search::Query::parse(&shard.pubs[2].title, 512).unwrap();
    let qw = build_query_weights(&[q.buckets.clone()], &stats, 512, 1);
    let ranked = exec.rank(&block, &qw, 1, &FIELD_W).unwrap();
    for &(idx, _) in &ranked[0] {
        assert!((idx as usize) < 5, "padding index {idx} leaked");
    }
}

#[test]
fn batched_queries_match_single_queries() {
    let Some(dir) = artifact_dir() else { return };
    let mut exec = Executor::new(dir).unwrap();
    let (shard, stats) = setup(300, 512);
    let candidates: Vec<u32> = (0..256).collect();
    let block = pack_block(&shard, &stats, &candidates, 256, 0.75);

    let queries: Vec<Vec<u32>> = (0..4)
        .map(|i| {
            gaps::search::Query::parse(&shard.pubs[i * 7].title, 512)
                .unwrap()
                .buckets
        })
        .collect();

    // Batched execution (q8 artifact).
    let qw_batch = build_query_weights(&queries, &stats, 512, 8);
    let batch = exec.rank(&block, &qw_batch, 4, &FIELD_W).unwrap();
    assert_eq!(batch.len(), 4);

    // Each query alone (q1 artifact).
    for (qi, qbuckets) in queries.iter().enumerate() {
        let qw1 = build_query_weights(&[qbuckets.clone()], &stats, 512, 1);
        let solo = exec.rank(&block, &qw1, 1, &FIELD_W).unwrap();
        assert_eq!(
            batch[qi].iter().map(|h| h.0).collect::<Vec<_>>(),
            solo[0].iter().map(|h| h.0).collect::<Vec<_>>(),
            "query {qi} ranking differs between batch and solo"
        );
    }
}

#[test]
fn large_block_variant_works() {
    let Some(dir) = artifact_dir() else { return };
    let mut exec = Executor::new(dir).unwrap();
    let (shard, stats) = setup(1100, 512);
    let candidates: Vec<u32> = (0..1024).collect();
    let block = pack_block(&shard, &stats, &candidates, 1024, 0.75);
    let q = gaps::search::Query::parse(&shard.pubs[900].title, 512).unwrap();
    let qw = build_query_weights(&[q.buckets.clone()], &stats, 512, 1);
    let ranked = exec.rank(&block, &qw, 1, &FIELD_W).unwrap();
    // Doc 900 is in the block and should surface.
    assert!(
        ranked[0].iter().any(|&(i, _)| i == 900),
        "{:?}",
        &ranked[0][..5.min(ranked[0].len())]
    );
}

#[test]
fn mismatched_block_is_rejected() {
    let Some(dir) = artifact_dir() else { return };
    let mut exec = Executor::new(dir).unwrap();
    let (shard, stats) = setup(40, 512);
    // Pack to a non-artifact D: executor must refuse, not mis-execute.
    let block = pack_block(&shard, &stats, &[0, 1, 2], 100, 0.75);
    let qw = vec![0.0f32; 512];
    assert!(exec.rank(&block, &qw, 1, &FIELD_W).is_err());
}
