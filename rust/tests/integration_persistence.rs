//! Persistence acceptance tests: a node deployed from an on-disk
//! snapshot must be **bit-identical** — hit ids, score bits, work
//! counters, index epoch — to the node that wrote it, and documents
//! ingested while serving must become searchable after their seal with
//! no restart, observable end-to-end over HTTP (`POST /ingest`,
//! `GET /healthz`).
//!
//! CI runs this file as an explicit job step (see
//! `.github/workflows/ci.yml`) — the snapshot format is a deployment
//! surface, not an implementation detail.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use gaps::config::GapsConfig;
use gaps::coordinator::{GapsSystem, SearchResponse};
use gaps::corpus::{CorpusGenerator, CorpusSpec, Publication};
use gaps::serve::{HttpConfig, HttpServer, QueueConfig, SearchServer};
use gaps::util::json::Json;

fn small_cfg() -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 600;
    cfg.workload.sub_shards = 8;
    cfg.search.use_xla = false;
    cfg
}

/// Fresh publications drawn from the same generator family as the
/// deployed corpus, starting past its last id (generation is pure in
/// `(seed, i)`, so a wider generator extends the corpus seamlessly).
fn extra_pubs(sys: &GapsSystem, n: u64) -> Vec<Publication> {
    let base = sys.deployment().locator.total_docs();
    let spec = CorpusSpec {
        seed: sys.cfg.workload.seed,
        num_docs: base + n,
        ..CorpusSpec::default()
    };
    CorpusGenerator::new(spec).generate_range(base, n)
}

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const QUERIES: [&str; 5] = [
    "grid computing",
    "data distributed retrieval",
    "search AND grid",
    "publication OR archive",
    "academic massive search",
];

/// The headline acceptance criterion: deploy, ingest past several
/// seals, snapshot, boot a second node from the snapshot, and require
/// responses that are indistinguishable at the bit level.
#[test]
fn snapshot_deployed_node_is_bit_identical_to_generator_built() {
    let mut cfg = small_cfg();
    cfg.storage.seal_docs = 4;
    cfg.storage.merge_fanout = 2;
    let mut sys = GapsSystem::deploy(cfg.clone(), 3).unwrap();

    // Ingest enough to seal overlay segments on every source (and leave
    // a buffered remainder, which the snapshot must also carry).
    let fresh = extra_pubs(&sys, 70);
    let rep = sys.ingest(fresh);
    assert!(rep.sealed >= 1, "70 docs over 8 sources at seal_docs=4 must seal");
    assert!(rep.epoch >= 1);

    let dir = temp_dir("gaps_it_persistence_parity");
    let manifest = sys.write_snapshot(&dir).unwrap();
    assert_eq!(manifest.epoch, sys.index_epoch());

    let mut restored = GapsSystem::deploy_from_snapshot(cfg, 3, &dir).unwrap();

    // Same epoch, same health, same per-source segment layout.
    assert_eq!(restored.index_epoch(), sys.index_epoch());
    let (ha, hb) = (sys.index_health(), restored.index_health());
    assert_eq!(ha.searchable_docs, hb.searchable_docs);
    assert_eq!(ha.buffered_docs, hb.buffered_docs);
    assert_eq!(ha.segments, hb.segments);

    for q in QUERIES {
        let a = sys.search(q).unwrap();
        let b = restored.search(q).unwrap();
        assert_eq!(a.hits.len(), b.hits.len(), "hit count diverged for {q:?}");
        for (x, y) in a.hits.iter().zip(&b.hits) {
            assert_eq!(x.global_id, y.global_id, "hit ids diverged for {q:?}");
            assert_eq!(
                x.score.to_bits(),
                y.score.to_bits(),
                "score bits diverged for {q:?} on doc {}",
                x.global_id
            );
            assert_eq!(x.title, y.title);
        }
        assert_eq!(a.docs_scanned, b.docs_scanned, "coverage diverged for {q:?}");
        assert_eq!(a.candidates, b.candidates, "candidates diverged for {q:?}");
    }
}

/// A snapshot-booted node is a *live* node: it keeps ingesting on the
/// same epoch/id line the writer left off at, with no id collisions.
#[test]
fn snapshot_boot_continues_ingestion_where_the_writer_stopped() {
    let mut cfg = small_cfg();
    cfg.storage.seal_docs = 2;
    let mut sys = GapsSystem::deploy(cfg.clone(), 2).unwrap();
    let batch = extra_pubs(&sys, 40);
    let (first, second) = batch.split_at(16);
    sys.ingest(first.to_vec());
    let epoch_at_write = sys.index_epoch();

    let dir = temp_dir("gaps_it_persistence_resume");
    sys.write_snapshot(&dir).unwrap();
    let mut restored = GapsSystem::deploy_from_snapshot(cfg, 2, &dir).unwrap();

    let rep = restored.ingest(second.to_vec());
    assert_eq!(rep.accepted, 24);
    assert!(rep.epoch > epoch_at_write, "resumed ingestion must keep bumping the epoch");
    restored.flush_ingest();

    // Every ingested publication — the writer's and the resumed ones —
    // resolves to a distinct id with its own title.
    let total = restored.index_health().searchable_docs;
    assert_eq!(total, 600 + 40);
    for (i, p) in batch.iter().enumerate() {
        let got = restored.publication(600 + i as u64).unwrap_or_else(|| {
            panic!("ingested doc {} missing after snapshot resume", 600 + i as u64)
        });
        assert_eq!(got.title, p.title, "id collision at {}", 600 + i as u64);
    }
}

/// Minimal HTTP/1.1 client for the end-to-end lane below.
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: gaps-test\r\n");
    if let Some(body) = body {
        req.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            body.len()
        ));
    }
    req.push_str("Connection: close\r\n\r\n");
    if let Some(body) = body {
        req.push_str(body);
    }
    stream.write_all(req.as_bytes()).expect("send");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("receive");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    (status, Json::parse(body).unwrap_or_else(|e| panic!("bad body {body:?}: {e}")))
}

/// End-to-end over real sockets: ingest while serving, watch the epoch
/// move in `/healthz`, and retrieve the new document — all without the
/// server restarting or redeploying.
#[test]
fn ingest_over_http_is_searchable_and_reported_in_healthz() {
    let mut cfg = small_cfg();
    cfg.workload.num_docs = 400;
    cfg.workload.sub_shards = 4;
    cfg.storage.seal_docs = 1; // every ingest seals immediately
    let server = SearchServer::start(QueueConfig::default(), move || {
        GapsSystem::deploy(cfg, 3)
    })
    .unwrap();
    let http_srv =
        HttpServer::bind_with("127.0.0.1:0", server.queue(), HttpConfig::default()).unwrap();
    let addr = http_srv.local_addr().unwrap();
    let stopper = http_srv.shutdown_handle().unwrap();
    let accept = std::thread::spawn(move || http_srv.serve().unwrap());

    // Before any ingest: epoch 0, base corpus only.
    let (status, health) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    let index = health.get("index").expect("healthz must report the index object");
    assert_eq!(index.get("epoch").unwrap().as_i64(), Some(0));
    assert_eq!(index.get("searchable_docs").unwrap().as_i64(), Some(400));

    let body = r#"{"docs": [{
        "id": 0,
        "title": "zyzzogeton grid persistence",
        "abstract": "an http-ingested publication about zyzzogeton",
        "authors": "A. Author",
        "venue": "TEST",
        "year": 2026
    }]}"#;
    let (status, report) = http(addr, "POST", "/ingest", Some(body));
    assert_eq!(status, 200, "{report:?}");
    assert_eq!(report.get("accepted").unwrap().as_i64(), Some(1));
    assert!(report.get("sealed").unwrap().as_i64().unwrap() >= 1);
    let epoch = report.get("epoch").unwrap().as_i64().unwrap();
    assert!(epoch >= 1);

    // Searchable on the very next request, same process, same sockets.
    let (status, body) =
        http(addr, "POST", "/search", Some(r#"{"query": "zyzzogeton"}"#));
    assert_eq!(status, 200, "{body:?}");
    let resp = SearchResponse::from_json(&body).unwrap();
    assert!(
        resp.hits.iter().any(|h| h.title.contains("zyzzogeton")),
        "ingested doc must be retrievable after its seal: {resp:?}"
    );

    // The epoch the client saw in the ingest report is now the epoch
    // /healthz serves, with the segment visible under its source.
    let (_, health) = http(addr, "GET", "/healthz", None);
    let index = health.get("index").unwrap();
    assert_eq!(index.get("epoch").unwrap().as_i64(), Some(epoch));
    assert_eq!(index.get("searchable_docs").unwrap().as_i64(), Some(401));
    assert_eq!(index.get("buffered_docs").unwrap().as_i64(), Some(0));
    let segments = index.get("segments").unwrap().as_arr().unwrap();
    assert!(!segments.is_empty(), "sealed segment must appear per-source");

    stopper.stop();
    accept.join().unwrap();
    server.shutdown();
}
