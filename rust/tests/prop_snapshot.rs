//! Snapshot-format property tests (referenced from `gaps::storage`'s
//! module docs):
//!
//! * round-trip — a decoded snapshot reproduces the in-memory shard
//!   exactly: byte-identical CSR arena (offsets, postings, quantized
//!   impacts, block metadata), equal publications/docs/stats, and
//!   bit-identical retrieval (ids *and* scores) across random queries
//!   and block sizes;
//! * hostile input — flipping any bit or truncating at any offset of a
//!   real snapshot yields a typed `SearchError` (`io` for corruption,
//!   `invalid-config` for not-a-snapshot), never a panic and never a
//!   silently-loaded wrong index.

use gaps::corpus::{CorpusGenerator, CorpusSpec};
use gaps::index::{InvertedIndex, Shard};
use gaps::storage::snapshot::encode_shard_snapshot;
use gaps::storage::{read_shard_snapshot, write_shard_snapshot, SnapshotManifest, MANIFEST_NAME};
use gaps::util::prop::{check, Config};

fn prop_cfg(cases: usize) -> Config {
    Config { cases, ..Config::default() }
}

/// A shard over a generated corpus, re-indexed at a chosen block size
/// (small blocks force block boundaries into the middle of every
/// posting list, exercising the INDX section's geometry paths).
fn corpus_shard(n: u64, vocab: usize, seed: u64, features: usize, block_size: usize) -> Shard {
    let spec = CorpusSpec { num_docs: n, vocab_size: vocab, seed, ..CorpusSpec::default() };
    let gen = CorpusGenerator::new(spec);
    let base = Shard::build(3, gen.generate_range(0, n), features);
    let inverted = InvertedIndex::build_with_block_size(&base.docs, features, block_size);
    Shard { inverted, ..base }
}

#[test]
fn prop_snapshot_roundtrip_is_bit_identical() {
    const FEATURES: usize = 128;
    let dir = std::env::temp_dir().join("gaps_prop_snapshot_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();

    // (docs, vocab, seed, block size) — shapes chosen so arenas differ
    // in every dimension the INDX section encodes.
    let shapes: [(u64, usize, u64, usize); 3] =
        [(300, 400, 11, 1), (150, 250, 23, 7), (420, 600, 5, 128)];
    let variants: Vec<(Shard, Shard)> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(n, vocab, seed, bs))| {
            let shard = corpus_shard(n, vocab, seed, FEATURES, bs);
            let path = dir.join(format!("v{i}.gsnap"));
            write_shard_snapshot(&shard, &path).unwrap();
            let loaded = read_shard_snapshot(&path).unwrap();
            (shard, loaded)
        })
        .collect();

    for (shard, loaded) in &variants {
        assert_eq!(shard.id, loaded.id);
        assert_eq!(shard.features, loaded.features);
        assert_eq!(shard.pubs, loaded.pubs);
        assert_eq!(shard.docs, loaded.docs);
        assert_eq!(shard.stats, loaded.stats);
        // The arena is byte-identical, not just equivalent.
        let a = shard.inverted.raw_parts();
        let b = loaded.inverted.raw_parts();
        assert_eq!(a.offsets, b.offsets);
        assert_eq!(a.docs, b.docs);
        assert_eq!(a.impacts, b.impacts);
        assert_eq!(a.block_offsets, b.block_offsets);
        assert_eq!(a.blocks, b.blocks);
        assert_eq!(a.num_docs, b.num_docs);
        assert_eq!(a.block_size, b.block_size);
        // And re-encoding reproduces the container byte for byte.
        assert_eq!(encode_shard_snapshot(shard), encode_shard_snapshot(loaded));
    }

    // Retrieval through the loaded arena is bit-identical — ids and
    // scores — to the never-persisted original, across random queries.
    check(
        "snapshot-roundtrip-retrieval",
        &prop_cfg(200),
        |rng, size| {
            let variant = rng.range(0, variants.len());
            let n = rng.range(1, size.max(2).min(8));
            let buckets: Vec<u32> =
                (0..n).map(|_| rng.below(FEATURES as u64 + 4) as u32).collect();
            let k = rng.range(1, 120);
            (variant, buckets, k)
        },
        |(variant, buckets, k)| {
            let (shard, loaded) = &variants[*variant];
            let want = shard.inverted.retrieve(buckets, *k);
            let got = loaded.inverted.retrieve(buckets, *k);
            if got == want {
                Ok(())
            } else {
                Err(format!(
                    "variant {variant} k={k}: loaded returned {} hits, original {}",
                    got.len(),
                    want.len()
                ))
            }
        },
    );
}

#[test]
fn prop_corrupt_snapshots_fail_typed_never_panic() {
    let dir = std::env::temp_dir().join("gaps_prop_snapshot_hostile");
    std::fs::create_dir_all(&dir).unwrap();
    let shard = corpus_shard(200, 300, 7, 64, 16);
    let path = dir.join("base.gsnap");
    write_shard_snapshot(&shard, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let case_path = dir.join("case.gsnap");

    check(
        "snapshot-hostile-input",
        &prop_cfg(250),
        |rng, _| {
            // Either flip one bit anywhere or truncate strictly shorter
            // — every offset class (magic, version, section headers,
            // checksums, payloads) gets hit across the cases.
            let flip = rng.chance(0.5);
            let off = rng.below(bytes.len() as u64) as usize;
            let bit = rng.below(8) as u32;
            (flip, off, bit)
        },
        |(flip, off, bit)| {
            let mut mutated = bytes.clone();
            if *flip {
                mutated[*off] ^= 1u8 << *bit;
            } else {
                mutated.truncate(*off);
            }
            std::fs::write(&case_path, &mutated).unwrap();
            match read_shard_snapshot(&case_path) {
                Err(e) if e.kind() == "io" || e.kind() == "invalid-config" => Ok(()),
                Err(e) => Err(format!(
                    "flip={flip} off={off} bit={bit}: untyped error kind {:?}",
                    e.kind()
                )),
                Ok(_) => Err(format!(
                    "flip={flip} off={off} bit={bit}: corrupted snapshot loaded cleanly"
                )),
            }
        },
    );
}

#[test]
fn garbage_manifest_is_a_typed_config_error() {
    let dir = std::env::temp_dir().join("gaps_prop_snapshot_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    for garbage in ["", "not json at all", "{\"format\": \"something-else\"}", "[1, 2, 3]"] {
        std::fs::write(dir.join(MANIFEST_NAME), garbage).unwrap();
        let err = SnapshotManifest::read(&dir).expect_err("garbage manifest must not parse");
        assert_eq!(err.kind(), "invalid-config", "manifest {garbage:?}");
    }
    // A missing manifest is an I/O failure, not a format failure.
    std::fs::remove_file(dir.join(MANIFEST_NAME)).unwrap();
    assert_eq!(SnapshotManifest::read(&dir).expect_err("missing file").kind(), "io");
}
