//! Coalesced-vs-serial parity for the serving layer: requests submitted
//! *concurrently* through the admission queue — and therefore executed
//! in whatever coalesced rounds the queue forms — must return
//! bit-identical hits, scores and counters to sequential
//! `search_request` calls on an identical deployment, and error kinds
//! must match for invalid requests.
//!
//! This extends `prop_batch_parity.rs` one layer up: that test pins
//! `search_batch == serial`, this one pins `admission queue ==
//! serial` *including* the queue's timing-dependent round formation —
//! whatever rounds the linger window happens to form, results must not
//! depend on them.

use std::sync::{Arc, Barrier, OnceLock};
use std::time::Duration;

use gaps::config::GapsConfig;
use gaps::coordinator::{Deployment, GapsSystem, SearchResponse};
use gaps::metrics::sample_queries;
use gaps::search::{Field, SearchError, SearchRequest};
use gaps::serve::{QueueConfig, SearchServer};
use gaps::util::prop::{check, Config};
use gaps::util::rng::Rng;

fn cfg() -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 600;
    cfg.workload.sub_shards = 8;
    cfg.search.use_xla = false;
    cfg
}

/// One deployment + query pool shared across every case.
fn fixture() -> &'static (Arc<Deployment>, Vec<String>) {
    static FIXTURE: OnceLock<(Arc<Deployment>, Vec<String>)> = OnceLock::new();
    FIXTURE.get_or_init(|| {
        let dep = Arc::new(Deployment::build(&cfg(), 4).unwrap());
        let queries = sample_queries(&dep, 24, 0x5E7E_1);
        (dep, queries)
    })
}

#[derive(Debug, Clone)]
struct ServeCase {
    requests: Vec<SearchRequest>,
    max_batch: usize,
    linger_ms: u64,
}

fn gen_request(rng: &mut Rng, pool: &[String]) -> SearchRequest {
    let mut query = pool[rng.range(0, pool.len())].clone();
    if rng.chance(0.15) {
        query.push_str(" -zzzyqx");
    }
    if rng.chance(0.1) {
        // Invalid inputs: the queue must ferry error parity too.
        query = ["", "the of and", "bogus:grid"][rng.range(0, 3)].to_string();
    }
    let mut req = SearchRequest::new(query);
    if rng.chance(0.4) {
        req = req.top_k(rng.range(1, 12));
    }
    if rng.chance(0.2) {
        let lo = 1998 + rng.below(10) as u32;
        req = req.year(lo..=lo + 6);
    }
    if rng.chance(0.1) {
        req = req.require(Field::Title, "grid");
    }
    if rng.chance(0.15) {
        req = req.explain(true);
    }
    req
}

fn gen_case(rng: &mut Rng, size: usize) -> ServeCase {
    let (_, pool) = fixture();
    let n = rng.range(2, size.clamp(3, 9));
    ServeCase {
        requests: (0..n).map(|_| gen_request(rng, pool)).collect(),
        // Sweep the coalescing shapes: singleton rounds, tight rounds,
        // everything-in-one-round.
        max_batch: [1, 2, 3, 16][rng.range(0, 4)],
        linger_ms: [0, 1, 20][rng.range(0, 3)],
    }
}

fn assert_same(
    i: usize,
    query: &str,
    served: &Result<SearchResponse, SearchError>,
    serial: Result<SearchResponse, SearchError>,
) -> Result<(), String> {
    match (served, serial) {
        (Err(qe), Err(se)) => {
            if qe.kind() != se.kind() {
                return Err(format!(
                    "request {i} {query:?}: served error {} vs serial error {}",
                    qe.kind(),
                    se.kind()
                ));
            }
        }
        (Ok(_), Err(se)) => {
            return Err(format!("request {i} {query:?}: serial failed ({se}), served ok"));
        }
        (Err(qe), Ok(_)) => {
            return Err(format!("request {i} {query:?}: served failed ({qe}), serial ok"));
        }
        (Ok(q), Ok(s)) => {
            let ids_q: Vec<u64> = q.hits.iter().map(|h| h.global_id).collect();
            let ids_s: Vec<u64> = s.hits.iter().map(|h| h.global_id).collect();
            if ids_q != ids_s {
                return Err(format!("request {i} {query:?}: hits {ids_q:?} != {ids_s:?}"));
            }
            for (hq, hs) in q.hits.iter().zip(&s.hits) {
                if hq.score.to_bits() != hs.score.to_bits() {
                    return Err(format!(
                        "request {i} {query:?}: score {} != {} for doc {}",
                        hq.score, hs.score, hq.global_id
                    ));
                }
            }
            if q.candidates != s.candidates {
                return Err(format!(
                    "request {i} {query:?}: candidates {} != {}",
                    q.candidates, s.candidates
                ));
            }
            if q.docs_scanned != s.docs_scanned {
                return Err(format!(
                    "request {i} {query:?}: docs {} != {}",
                    q.docs_scanned, s.docs_scanned
                ));
            }
        }
    }
    Ok(())
}

fn run_case(case: &ServeCase) -> Result<(), String> {
    let (dep, _) = fixture();

    // Serving side: executor-owned system over the shared deployment.
    let dep_for_server = Arc::clone(dep);
    let server = SearchServer::start(
        QueueConfig {
            max_batch: case.max_batch,
            max_linger: Duration::from_millis(case.linger_ms),
            ..QueueConfig::default()
        },
        move || GapsSystem::from_deployment(cfg(), dep_for_server),
    )
    .map_err(|e| e.to_string())?;

    // Submit every request concurrently: all submitters release together
    // so the linger window genuinely coalesces co-arrivals.
    let queue = server.queue();
    let barrier = Barrier::new(case.requests.len());
    let mut served: Vec<Option<Result<SearchResponse, SearchError>>> =
        (0..case.requests.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        for (req, slot) in case.requests.iter().zip(served.iter_mut()) {
            let queue = &queue;
            let barrier = &barrier;
            s.spawn(move || {
                barrier.wait();
                *slot = Some(queue.submit(req.clone()));
            });
        }
    });
    let stats = server.stats();
    server.shutdown();

    // Serial oracle on an identical fresh system.
    let mut serial_sys =
        GapsSystem::from_deployment(cfg(), Arc::clone(dep)).map_err(|e| e.to_string())?;
    for (i, (req, served)) in case.requests.iter().zip(&served).enumerate() {
        let served = served.as_ref().expect("every submitter settled");
        assert_same(i, &req.query, served, serial_sys.search_request(req))?;
    }

    // Accounting invariants (round shapes are timing-dependent, totals
    // are not).
    if stats.submitted != case.requests.len() as u64 {
        return Err(format!(
            "submitted {} != {} requests",
            stats.submitted,
            case.requests.len()
        ));
    }
    if stats.executed != stats.submitted {
        return Err(format!("executed {} != submitted {}", stats.executed, stats.submitted));
    }
    if stats.largest_batch > case.max_batch as u64 {
        return Err(format!(
            "round of {} exceeded max_batch {}",
            stats.largest_batch, case.max_batch
        ));
    }
    if case.max_batch == 1 && stats.coalesced != 0 {
        return Err(format!("max_batch=1 coalesced {} requests", stats.coalesced));
    }
    Ok(())
}

#[test]
fn prop_coalesced_serving_matches_serial_execution() {
    let prop_cfg = Config { cases: 30, max_size: 9, ..Config::default() };
    check("serve-serial-parity", &prop_cfg, gen_case, run_case);
}

/// Deterministic coalescing evidence: with a generous linger window and
/// concurrent submitters, the queue must actually form multi-request
/// rounds (the admission counters are the observable), and the results
/// must still match serial execution.
#[test]
fn concurrent_users_are_observably_coalesced() {
    let (dep, pool) = fixture();
    let dep_for_server = Arc::clone(dep);
    let server = SearchServer::start(
        QueueConfig {
            max_batch: 16,
            max_linger: Duration::from_millis(300),
            ..QueueConfig::default()
        },
        move || GapsSystem::from_deployment(cfg(), dep_for_server),
    )
    .unwrap();

    let requests: Vec<SearchRequest> =
        pool.iter().take(6).map(|q| SearchRequest::new(q.clone())).collect();
    let queue = server.queue();
    let barrier = Barrier::new(requests.len());
    let mut served: Vec<Option<Result<SearchResponse, SearchError>>> =
        (0..requests.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        for (req, slot) in requests.iter().zip(served.iter_mut()) {
            let queue = &queue;
            let barrier = &barrier;
            s.spawn(move || {
                barrier.wait();
                *slot = Some(queue.submit(req.clone()));
            });
        }
    });
    let stats = server.stats();
    server.shutdown();

    // All six arrived inside one 300ms window: strictly fewer rounds
    // than requests, and at least one round held >= 2 requests.
    assert_eq!(stats.submitted, 6);
    assert!(stats.batches < 6, "no coalescing happened: {stats:?}");
    assert!(stats.coalesced >= 2, "no multi-request round: {stats:?}");
    assert!(stats.largest_batch >= 2, "{stats:?}");

    let mut serial_sys = GapsSystem::from_deployment(cfg(), Arc::clone(dep)).unwrap();
    for (i, (req, served)) in requests.iter().zip(&served).enumerate() {
        let served = served.as_ref().expect("settled");
        assert_same(i, &req.query, served, serial_sys.search_request(req))
            .unwrap_or_else(|e| panic!("{e}"));
    }
}
