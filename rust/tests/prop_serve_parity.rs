//! Serving-vs-serial parity, now through the full sharded stack:
//! requests submitted *concurrently* over real HTTP — routed
//! round-robin across 1/2/4 executor shards, with keep-alive on or off,
//! coalesced into whatever rounds each shard's admission queue forms —
//! must return bit-identical hits, scores and counters to sequential
//! `search_request` calls on an identical deployment, and error kinds
//! must match for invalid requests.
//!
//! This extends `prop_batch_parity.rs` two layers up: that test pins
//! `search_batch == serial`; this one pins `sharded + pipelined
//! serving == serial` *including* the queues' timing-dependent round
//! formation and the router's shard assignment — whatever rounds form
//! on whichever shard, results must not depend on them.
//!
//! Every case runs with observability fully engaged (a shared
//! [`ServeObs`] with `slow_query_ms = 0`, so *all* requests take the
//! tracing + slow-log path): metrics and tracing must be invisible in
//! results. A deterministic companion test pins the `explain.stages`
//! tree shape and `/metrics` counter totals against the oracle.
//!
//! CI runs this file as an explicit job step (see
//! `.github/workflows/ci.yml`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier, OnceLock};
use std::time::Duration;

use gaps::config::GapsConfig;
use gaps::coordinator::{Deployment, GapsSystem, SearchResponse};
use gaps::metrics::sample_queries;
use gaps::obs::TraceSpan;
use gaps::search::{Field, SearchError, SearchRequest};
use gaps::serve::{HttpConfig, HttpServer, QueueConfig, SearchServer, ServeObs};
use gaps::util::json::Json;
use gaps::util::prop::{check, Config};
use gaps::util::rng::Rng;

fn cfg() -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 600;
    cfg.workload.sub_shards = 8;
    cfg.search.use_xla = false;
    cfg
}

/// One deployment + query pool shared across every case.
fn fixture() -> &'static (Arc<Deployment>, Vec<String>) {
    static FIXTURE: OnceLock<(Arc<Deployment>, Vec<String>)> = OnceLock::new();
    FIXTURE.get_or_init(|| {
        let dep = Arc::new(Deployment::build(&cfg(), 4).unwrap());
        let queries = sample_queries(&dep, 24, 0x5E7E_1);
        (dep, queries)
    })
}

#[derive(Debug, Clone)]
struct ServeCase {
    requests: Vec<SearchRequest>,
    max_batch: usize,
    linger_ms: u64,
    shards: usize,
    keep_alive: bool,
}

fn gen_request(rng: &mut Rng, pool: &[String]) -> SearchRequest {
    let mut query = pool[rng.range(0, pool.len())].clone();
    if rng.chance(0.15) {
        query.push_str(" -zzzyqx");
    }
    if rng.chance(0.1) {
        // Invalid inputs: the stack must ferry error parity too.
        query = ["", "the of and", "bogus:grid"][rng.range(0, 3)].to_string();
    }
    let mut req = SearchRequest::new(query);
    if rng.chance(0.4) {
        req = req.top_k(rng.range(1, 12));
    }
    if rng.chance(0.2) {
        let lo = 1998 + rng.below(10) as u32;
        req = req.year(lo..=lo + 6);
    }
    if rng.chance(0.1) {
        req = req.require(Field::Title, "grid");
    }
    if rng.chance(0.15) {
        req = req.explain(true);
    }
    req
}

fn gen_case(rng: &mut Rng, size: usize) -> ServeCase {
    let (_, pool) = fixture();
    let n = rng.range(2, size.clamp(3, 9));
    ServeCase {
        requests: (0..n).map(|_| gen_request(rng, pool)).collect(),
        // Sweep the coalescing shapes: singleton rounds, tight rounds,
        // everything-in-one-round.
        max_batch: [1, 2, 3, 16][rng.range(0, 4)],
        linger_ms: [0, 1, 20][rng.range(0, 3)],
        // Sweep the serving shapes too: shard counts and the connection
        // model are not allowed to be observable in results.
        shards: [1, 2, 4][rng.range(0, 3)],
        keep_alive: rng.chance(0.5),
    }
}

/// Read one framed response (status + `Content-Length` body) off a
/// persistent connection without consuming the stream to EOF.
fn read_framed_raw(reader: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {line:?}"));
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).expect("header line");
        if header.trim_end().is_empty() {
            break;
        }
        if let Some((name, value)) = header.trim_end().split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().expect("numeric content-length");
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("body");
    (status, String::from_utf8(body).expect("utf-8"))
}

fn read_framed(reader: &mut BufReader<TcpStream>) -> (u16, Json) {
    let (status, body) = read_framed_raw(reader);
    (status, Json::parse(&body).expect("json body"))
}

fn post_wire(req: &SearchRequest) -> String {
    let body = req.to_json().to_string_compact();
    format!(
        "POST /search HTTP/1.1\r\nHost: gaps-test\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
}

/// One request over a fresh socket: no `Connection` header, so the
/// server's keep-alive setting decides the connection's fate; the
/// framed read works either way. Errors come back as the typed
/// envelope's `kind`, comparable against [`SearchError::kind`].
fn http_search(addr: SocketAddr, req: &SearchRequest) -> Result<SearchResponse, String> {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    writer.write_all(post_wire(req).as_bytes()).expect("send");
    let (status, json) = read_framed(&mut reader);
    if status == 200 {
        Ok(SearchResponse::from_json(&json).expect("SearchResponse wire form"))
    } else {
        Err(json
            .get("kind")
            .and_then(|k| k.as_str())
            .unwrap_or_else(|| panic!("untyped error body {json:?}"))
            .to_string())
    }
}

fn assert_same(
    i: usize,
    query: &str,
    served: &Result<SearchResponse, String>,
    serial: Result<SearchResponse, SearchError>,
) -> Result<(), String> {
    match (served, serial) {
        (Err(kind), Err(se)) => {
            if kind != se.kind() {
                return Err(format!(
                    "request {i} {query:?}: served error {kind} vs serial error {}",
                    se.kind()
                ));
            }
        }
        (Ok(_), Err(se)) => {
            return Err(format!("request {i} {query:?}: serial failed ({se}), served ok"));
        }
        (Err(kind), Ok(_)) => {
            return Err(format!("request {i} {query:?}: served failed ({kind}), serial ok"));
        }
        (Ok(q), Ok(s)) => {
            let ids_q: Vec<u64> = q.hits.iter().map(|h| h.global_id).collect();
            let ids_s: Vec<u64> = s.hits.iter().map(|h| h.global_id).collect();
            if ids_q != ids_s {
                return Err(format!("request {i} {query:?}: hits {ids_q:?} != {ids_s:?}"));
            }
            for (hq, hs) in q.hits.iter().zip(&s.hits) {
                if hq.score.to_bits() != hs.score.to_bits() {
                    return Err(format!(
                        "request {i} {query:?}: score {} != {} for doc {}",
                        hq.score, hs.score, hq.global_id
                    ));
                }
            }
            if q.candidates != s.candidates {
                return Err(format!(
                    "request {i} {query:?}: candidates {} != {}",
                    q.candidates, s.candidates
                ));
            }
            if q.docs_scanned != s.docs_scanned {
                return Err(format!(
                    "request {i} {query:?}: docs {} != {}",
                    q.docs_scanned, s.docs_scanned
                ));
            }
        }
    }
    Ok(())
}

fn run_case(case: &ServeCase) -> Result<(), String> {
    let (dep, _) = fixture();

    // Serving side: N executor shards over the shared deployment,
    // fronted by the real HTTP listener. Observability is fully on, at
    // its most invasive setting (`slow_query_ms = 0` traces and
    // slow-logs every request): none of it may show up in results.
    let obs = ServeObs { slow_query_ms: 0, ..ServeObs::default() };
    let dep_for_server = Arc::clone(dep);
    let server = SearchServer::start_sharded_with_obs(
        QueueConfig {
            max_batch: case.max_batch,
            max_linger: Duration::from_millis(case.linger_ms),
            ..QueueConfig::default()
        },
        case.shards,
        obs.clone(),
        move |_shard| GapsSystem::from_deployment(cfg(), Arc::clone(&dep_for_server)),
    )
    .map_err(|e| e.to_string())?;
    let http = HttpServer::bind_with(
        "127.0.0.1:0",
        server.router(),
        HttpConfig { keep_alive: case.keep_alive, ..HttpConfig::default() },
    )
    .map_err(|e| e.to_string())?;
    let addr = http.local_addr().map_err(|e| e.to_string())?;
    let stopper = http.shutdown_handle().map_err(|e| e.to_string())?;
    let accept_thread = std::thread::spawn(move || http.serve().unwrap());

    // One real socket per concurrent user: all release together so the
    // linger windows genuinely coalesce co-arrivals.
    let barrier = Barrier::new(case.requests.len());
    let mut served: Vec<Option<Result<SearchResponse, String>>> =
        (0..case.requests.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        for (req, slot) in case.requests.iter().zip(served.iter_mut()) {
            let barrier = &barrier;
            s.spawn(move || {
                barrier.wait();
                *slot = Some(http_search(addr, req));
            });
        }
    });
    let stats = server.stats();
    let per_shard = server.router().per_shard_stats();
    let conns = server.router().http().stats();
    let snap = server.router().snapshot();
    stopper.stop();
    accept_thread.join().map_err(|_| "accept thread panicked".to_string())?;
    server.shutdown();

    // Serial oracle on an identical fresh single system.
    let mut serial_sys =
        GapsSystem::from_deployment(cfg(), Arc::clone(dep)).map_err(|e| e.to_string())?;
    for (i, (req, served)) in case.requests.iter().zip(&served).enumerate() {
        let served = served.as_ref().expect("every client settled");
        assert_same(i, &req.query, served, serial_sys.search_request(req))?;
    }

    // Accounting invariants (round shapes and shard assignment are
    // timing-dependent, totals are not). `stats` is the absorbed
    // cross-shard aggregate.
    let n = case.requests.len() as u64;
    if stats.submitted != n {
        return Err(format!("submitted {} != {} requests", stats.submitted, n));
    }
    if stats.executed != stats.submitted {
        return Err(format!("executed {} != submitted {}", stats.executed, stats.submitted));
    }
    if stats.shed != 0 || stats.expired != 0 {
        return Err(format!("unexpected shed/expired under light load: {stats:?}"));
    }
    let split: u64 = per_shard.iter().map(|s| s.submitted).sum();
    if split != n {
        return Err(format!("per-shard submitted sums to {split}, not {n}"));
    }
    for (shard, s) in per_shard.iter().enumerate() {
        if s.largest_batch > case.max_batch as u64 {
            return Err(format!(
                "shard {shard}: round of {} exceeded max_batch {}",
                s.largest_batch, case.max_batch
            ));
        }
    }
    if case.max_batch == 1 && stats.coalesced != 0 {
        return Err(format!("max_batch=1 coalesced {} requests", stats.coalesced));
    }
    // Result-cache probes happen once per round member (single-flight
    // attachments are answered without probing): the published counters
    // can never exceed the executed total.
    if stats.result_hits + stats.result_misses > stats.executed {
        return Err(format!("cache probes exceed executions: {stats:?}"));
    }
    // Connection accounting: one connection and one request per user,
    // nothing shed, nothing reused.
    if conns.accepted != n || conns.requests != n || conns.reused != 0 || conns.shed != 0 {
        return Err(format!("connection counters off for {n} one-shot users: {conns:?}"));
    }
    // The frozen registry snapshot must agree with the live counter
    // reads above — `/healthz` and `/metrics` render the same cells.
    if snap.http.requests != n {
        return Err(format!("frozen http.requests {} != {n}", snap.http.requests));
    }
    let frozen_split: u64 = snap.per_shard.iter().map(|s| s.submitted).sum();
    if frozen_split != n {
        return Err(format!("frozen per-shard submitted sums to {frozen_split}, not {n}"));
    }
    // `slow_query_ms = 0` slow-logs every executed round slot: one
    // entry per unique request (single-flight attachments share their
    // primary's entry), errors included.
    let slots = n - stats.singleflight;
    if obs.slow.len() as u64 != slots {
        return Err(format!("slow ring holds {} entries, expected {slots}", obs.slow.len()));
    }
    Ok(())
}

#[test]
fn prop_sharded_serving_matches_serial_execution() {
    let prop_cfg = Config { cases: 30, max_size: 9, ..Config::default() };
    check("serve-serial-parity", &prop_cfg, gen_case, run_case);
}

/// Deterministic shard-routing evidence: with strictly sequential
/// round-trips on one keep-alive socket, the round-robin assignment is
/// pinned (request `i` lands on shard `i % shards`), so each shard's
/// *entire* counter block — admission totals, round shapes, plan-cache
/// and result-cache counters — must be bit-identical to a single-shard
/// oracle server fed exactly that shard's subsequence the same way.
#[test]
fn sequential_sharded_serving_pins_per_shard_counters() {
    let (dep, pool) = fixture();
    let shards = 2;
    // Deliberate repeats so the shard-private result caches see hits:
    // shard 0 serves pool[0], pool[2], pool[0], pool[4], pool[2] (two
    // hits), shard 1 serves pool[1], pool[3], pool[1], pool[4], pool[5]
    // (one hit — its pool[4] is a miss because the earlier pool[4]
    // landed on shard 0's private cache).
    let order = [0usize, 1, 2, 3, 0, 1, 4, 4, 2, 5];
    let requests: Vec<SearchRequest> =
        order.iter().map(|&i| SearchRequest::new(pool[i].clone())).collect();
    let queue_cfg =
        QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() };

    let dep_for_server = Arc::clone(dep);
    let server = SearchServer::start_sharded(queue_cfg, shards, move |_shard| {
        GapsSystem::from_deployment(cfg(), Arc::clone(&dep_for_server))
    })
    .unwrap();
    let http =
        HttpServer::bind_with("127.0.0.1:0", server.router(), HttpConfig::default()).unwrap();
    let addr = http.local_addr().unwrap();
    let stopper = http.shutdown_handle().unwrap();
    let accept_thread = std::thread::spawn(move || http.serve().unwrap());

    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    let mut served = Vec::new();
    for req in &requests {
        writer.write_all(post_wire(req).as_bytes()).expect("send");
        let (status, json) = read_framed(&mut reader);
        assert_eq!(status, 200, "{json:?}");
        served.push(SearchResponse::from_json(&json).expect("wire form"));
    }
    drop((writer, reader));

    let per_shard = server.router().per_shard_stats();
    stopper.stop();
    accept_thread.join().unwrap();
    server.shutdown();

    for shard in 0..shards {
        let dep_oracle = Arc::clone(dep);
        let oracle = SearchServer::start(queue_cfg, move || {
            GapsSystem::from_deployment(cfg(), dep_oracle)
        })
        .unwrap();
        let queue = oracle.queue();
        for (i, req) in requests.iter().enumerate() {
            if i % shards != shard {
                continue;
            }
            let want = queue.submit(req.clone()).expect("oracle success");
            let got = &served[i];
            let ids_got: Vec<u64> = got.hits.iter().map(|h| h.global_id).collect();
            let ids_want: Vec<u64> = want.hits.iter().map(|h| h.global_id).collect();
            assert_eq!(ids_got, ids_want, "request {i}");
            for (hg, hw) in got.hits.iter().zip(&want.hits) {
                assert_eq!(hg.score.to_bits(), hw.score.to_bits(), "request {i}");
            }
            assert_eq!(got.candidates, want.candidates, "request {i}");
            assert_eq!(got.docs_scanned, want.docs_scanned, "request {i}");
        }
        let oracle_stats = oracle.stats();
        oracle.shutdown();
        assert_eq!(
            per_shard[shard], oracle_stats,
            "shard {shard}: counters diverged from the single-shard oracle"
        );
        assert!(oracle_stats.result_hits > 0, "repeats must hit the shard-private cache");
    }
}

/// Walk a stage tree: every timing is finite and non-negative, and the
/// children of each span sum to no more than the parent's wall time —
/// they are disjoint phases of it — except under `execute`, whose
/// children are per-node jobs that overlap in wall time.
fn assert_monotone(span: &TraceSpan) {
    assert!(
        span.seconds.is_finite() && span.seconds >= 0.0,
        "span {:?} has bad timing {}",
        span.name,
        span.seconds
    );
    if span.name != "execute" {
        let child_sum: f64 = span.children.iter().map(|c| c.seconds).sum();
        assert!(
            child_sum <= span.seconds * 1.0001 + 1e-6,
            "children of {:?} sum to {child_sum}s > parent {}s",
            span.name,
            span.seconds
        );
    }
    for child in &span.children {
        assert_monotone(child);
    }
}

/// Pull one sample's value out of Prometheus text exposition.
fn metric_value(text: &str, sample: &str) -> f64 {
    text.lines()
        .find_map(|line| {
            let (name, value) = line.rsplit_once(' ')?;
            (name == sample).then(|| value.parse().expect("numeric sample"))
        })
        .unwrap_or_else(|| panic!("sample {sample:?} not exposed:\n{text}"))
}

/// Observability evidence on a pinned workload: sequential keep-alive
/// round-trips across 2 shards (request `i` lands on shard `i % 2`),
/// every request with explain on. Pins three things at once:
///
/// * results stay bit-identical to the serial oracle with tracing,
///   metrics, and the slow log all engaged;
/// * `explain.stages` is present with the documented tree shape —
///   `request` root carrying the shard label, `queued`/`probe`/`store`
///   phases, a `search` subtree (compile → plan → execute → merge)
///   for executed requests, a `result_cache=hit` marker instead for
///   repeats — and child timings nest monotonically;
/// * the `/metrics` scrape agrees with the workload's oracle totals:
///   10 submitted/executed split 5/5 across shards, exactly the two
///   repeat-hits in shard 1's private result cache, and `+Inf`-bucket
///   counts equal to each shard's request count.
#[test]
fn traced_serving_pins_stage_trees_and_metric_totals() {
    let (dep, pool) = fixture();
    let shards = 2;
    // Shard 0 serves pool[0], pool[2], pool[3], pool[4], pool[5] (all
    // distinct → 5 result-cache misses); shard 1 serves pool[1],
    // pool[0], pool[1], pool[2], pool[0] (repeats at i=5 and i=9 → 2
    // hits, 3 misses).
    let order = [0usize, 1, 2, 0, 3, 1, 4, 2, 5, 0];
    let requests: Vec<SearchRequest> =
        order.iter().map(|&i| SearchRequest::new(pool[i].clone()).explain(true)).collect();
    let queue_cfg =
        QueueConfig { max_batch: 4, max_linger: Duration::ZERO, ..QueueConfig::default() };

    let obs = ServeObs::default();
    let dep_for_server = Arc::clone(dep);
    let server =
        SearchServer::start_sharded_with_obs(queue_cfg, shards, obs.clone(), move |_shard| {
            GapsSystem::from_deployment(cfg(), Arc::clone(&dep_for_server))
        })
        .unwrap();
    let http =
        HttpServer::bind_with("127.0.0.1:0", server.router(), HttpConfig::default()).unwrap();
    let addr = http.local_addr().unwrap();
    let stopper = http.shutdown_handle().unwrap();
    let accept_thread = std::thread::spawn(move || http.serve().unwrap());

    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    let mut served = Vec::new();
    for req in &requests {
        writer.write_all(post_wire(req).as_bytes()).expect("send");
        let (status, json) = read_framed(&mut reader);
        assert_eq!(status, 200, "{json:?}");
        served.push(SearchResponse::from_json(&json).expect("wire form"));
    }
    // Scrape `/metrics` over the same socket: the scrape is this
    // connection's 11th request, counted before the text renders.
    writer
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: gaps-test\r\n\r\n")
        .expect("send scrape");
    let (status, text) = read_framed_raw(&mut reader);
    assert_eq!(status, 200);
    drop((writer, reader));
    stopper.stop();
    accept_thread.join().unwrap();
    server.shutdown();

    // (a) Bit-identical results, observability notwithstanding.
    let mut serial_sys = GapsSystem::from_deployment(cfg(), Arc::clone(dep)).unwrap();
    for (i, (req, resp)) in requests.iter().zip(&served).enumerate() {
        assert_same(i, &req.query, &Ok(resp.clone()), serial_sys.search_request(req))
            .unwrap_or_else(|e| panic!("{e}"));
    }

    // (b) Stage trees: shape, shard attribution, monotone timings.
    let cache_hits = [5usize, 9];
    for (i, resp) in served.iter().enumerate() {
        let stages = resp
            .explain
            .as_ref()
            .expect("explain requested")
            .stages
            .as_ref()
            .unwrap_or_else(|| panic!("request {i}: explain.stages missing"));
        assert_eq!(stages.name, "request", "request {i}");
        let shard_meta = stages
            .meta
            .iter()
            .find(|(k, _)| k == "shard")
            .map(|(_, v)| v.clone())
            .unwrap_or_else(|| panic!("request {i}: no shard meta"));
        assert_eq!(shard_meta, (i % shards).to_string(), "request {i}");
        assert!(stages.find("queued").is_some(), "request {i}");
        assert!(stages.find("probe").is_some(), "request {i}");
        assert!(stages.find("store").is_some(), "request {i}");
        if cache_hits.contains(&i) {
            assert!(stages.find("search").is_none(), "request {i}: hit ran the grid");
            assert!(
                stages.meta.iter().any(|(k, v)| k == "result_cache" && v == "hit"),
                "request {i}: hit not marked on the root"
            );
        } else {
            let search = stages
                .find("search")
                .unwrap_or_else(|| panic!("request {i}: no search subtree"));
            for stage in ["compile", "plan", "execute", "merge"] {
                assert!(search.find(stage).is_some(), "request {i}: no {stage} span");
            }
            let execute = search.find("execute").unwrap();
            assert!(!execute.children.is_empty(), "request {i}: execute has no job spans");
        }
        assert_monotone(stages);
    }

    // (c) `/metrics` totals match the oracle workload arithmetic.
    assert!(text.contains("# TYPE gaps_queue_submitted_total counter"), "{text}");
    assert!(text.contains("# TYPE gaps_request_seconds histogram"), "{text}");
    assert_eq!(metric_value(&text, "gaps_http_requests_total"), 11.0);
    assert_eq!(metric_value(&text, "gaps_http_accepted_total"), 1.0);
    assert_eq!(metric_value(&text, "gaps_http_reused_total"), 10.0);
    for shard in 0..shards {
        let m = |name: &str| metric_value(&text, &format!("{name}{{shard=\"{shard}\"}}"));
        assert_eq!(m("gaps_queue_submitted_total"), 5.0, "shard {shard}");
        assert_eq!(m("gaps_queue_executed_total"), 5.0, "shard {shard}");
        assert_eq!(m("gaps_queue_shed_total"), 0.0, "shard {shard}");
        assert_eq!(m("gaps_request_seconds_count"), 5.0, "shard {shard}");
        assert_eq!(
            metric_value(
                &text,
                &format!("gaps_request_seconds_bucket{{shard=\"{shard}\",le=\"+Inf\"}}")
            ),
            5.0,
            "shard {shard}: +Inf bucket must equal the count"
        );
    }
    assert_eq!(metric_value(&text, "gaps_cache_result_hits_total{shard=\"0\"}"), 0.0);
    assert_eq!(metric_value(&text, "gaps_cache_result_misses_total{shard=\"0\"}"), 5.0);
    assert_eq!(metric_value(&text, "gaps_cache_result_hits_total{shard=\"1\"}"), 2.0);
    assert_eq!(metric_value(&text, "gaps_cache_result_misses_total{shard=\"1\"}"), 3.0);
}

/// Deterministic coalescing evidence: with a generous linger window and
/// concurrent submitters, the queue must actually form multi-request
/// rounds (the admission counters are the observable), and the results
/// must still match serial execution.
#[test]
fn concurrent_users_are_observably_coalesced() {
    let (dep, pool) = fixture();
    let dep_for_server = Arc::clone(dep);
    let server = SearchServer::start(
        QueueConfig {
            max_batch: 16,
            max_linger: Duration::from_millis(300),
            ..QueueConfig::default()
        },
        move || GapsSystem::from_deployment(cfg(), dep_for_server),
    )
    .unwrap();

    let requests: Vec<SearchRequest> =
        pool.iter().take(6).map(|q| SearchRequest::new(q.clone())).collect();
    let queue = server.queue();
    let barrier = Barrier::new(requests.len());
    let mut served: Vec<Option<Result<SearchResponse, String>>> =
        (0..requests.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        for (req, slot) in requests.iter().zip(served.iter_mut()) {
            let queue = &queue;
            let barrier = &barrier;
            s.spawn(move || {
                barrier.wait();
                *slot = Some(queue.submit(req.clone()).map_err(|e| e.kind().to_string()));
            });
        }
    });
    let stats = server.stats();
    server.shutdown();

    // All six arrived inside one 300ms window: strictly fewer rounds
    // than requests, and at least one round held >= 2 requests.
    assert_eq!(stats.submitted, 6);
    assert!(stats.batches < 6, "no coalescing happened: {stats:?}");
    assert!(stats.coalesced >= 2, "no multi-request round: {stats:?}");
    assert!(stats.largest_batch >= 2, "{stats:?}");

    let mut serial_sys = GapsSystem::from_deployment(cfg(), Arc::clone(dep)).unwrap();
    for (i, (req, served)) in requests.iter().zip(&served).enumerate() {
        let served = served.as_ref().expect("settled");
        assert_same(i, &req.query, served, serial_sys.search_request(req))
            .unwrap_or_else(|e| panic!("{e}"));
    }
}
