//! HTTP/1.1 keep-alive protocol conformance, asserted over real
//! sockets: pipelined back-to-back requests on one connection,
//! `Connection: close` negotiation, dribbled header reads, malformed
//! and oversized `Content-Length`, reuse-after-error semantics, and the
//! shutdown drain-settle path for in-flight pipelined requests.
//!
//! CI runs this file as an explicit job step (see
//! `.github/workflows/ci.yml`) together with the saturation and parity
//! suites — the connection model is a release surface.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use gaps::config::GapsConfig;
use gaps::coordinator::GapsSystem;
use gaps::serve::{HttpConfig, HttpServer, QueueConfig, SearchServer, ShutdownHandle};
use gaps::util::json::Json;

fn small_cfg() -> GapsConfig {
    let mut cfg = GapsConfig::default();
    cfg.workload.num_docs = 400;
    cfg.workload.sub_shards = 4;
    cfg.search.use_xla = false;
    cfg
}

/// A full serving stack on an ephemeral port, torn down on drop.
struct TestStack {
    addr: SocketAddr,
    stopper: ShutdownHandle,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    server: Option<SearchServer>,
}

impl TestStack {
    fn start() -> TestStack {
        Self::start_with(HttpConfig::default())
    }

    fn start_with(http_cfg: HttpConfig) -> TestStack {
        let cfg = small_cfg();
        let queue_cfg = QueueConfig {
            max_batch: 4,
            max_linger: Duration::ZERO,
            ..QueueConfig::default()
        };
        let server = SearchServer::start(queue_cfg, move || GapsSystem::deploy(cfg, 3)).unwrap();
        let http = HttpServer::bind_with("127.0.0.1:0", server.router(), http_cfg).unwrap();
        let addr = http.local_addr().unwrap();
        let stopper = http.shutdown_handle().unwrap();
        let accept_thread = std::thread::spawn(move || {
            http.serve().unwrap();
        });
        TestStack { addr, stopper, accept_thread: Some(accept_thread), server: Some(server) }
    }

    fn router(&self) -> std::sync::Arc<gaps::serve::ShardRouter> {
        self.server.as_ref().unwrap().router()
    }
}

impl Drop for TestStack {
    fn drop(&mut self) {
        self.stopper.stop();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(s) = self.server.take() {
            s.shutdown();
        }
    }
}

/// One parsed response off a persistent connection's buffered reader.
struct Response {
    status: u16,
    /// Value of the `Connection` header ("keep-alive" or "close").
    connection: String,
    body: Json,
}

/// Read exactly one framed response (status line + headers +
/// `Content-Length` body) and leave the reader positioned at the next
/// one — the client half of pipelining.
fn read_response(reader: &mut BufReader<TcpStream>) -> Response {
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {line:?}"));
    let mut content_length = 0usize;
    let mut connection = String::new();
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).expect("header line");
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.parse().expect("numeric content-length");
            } else if name.eq_ignore_ascii_case("connection") {
                connection = value.to_string();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("body");
    let body = Json::parse(std::str::from_utf8(&body).expect("utf-8 body")).expect("json body");
    Response { status, connection, body }
}

/// A POST with no `Connection` header — HTTP/1.1 defaults to
/// keep-alive.
fn post(path: &str, body: &str) -> String {
    format!(
        "POST {path} HTTP/1.1\r\nHost: gaps-test\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
}

/// Assert the connection yields EOF (clean close) with no extra bytes.
fn expect_eof(reader: &mut BufReader<TcpStream>) {
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).expect("clean close, not a reset");
    assert!(rest.is_empty(), "unexpected trailing bytes: {:?}", String::from_utf8_lossy(&rest));
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    let stack = TestStack::start();
    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    // Three requests written back-to-back before reading any response.
    let queries = ["grid computing", "data retrieval", "academic publications"];
    let mut wire = String::new();
    for q in queries {
        wire.push_str(&post("/search", &format!(r#"{{"query": "{q}"}}"#)));
    }
    writer.write_all(wire.as_bytes()).expect("pipelined send");

    // Responses come back in request order, each on the same socket.
    for q in queries {
        let resp = read_response(&mut reader);
        assert_eq!(resp.status, 200, "{:?}", resp.body);
        assert_eq!(resp.connection, "keep-alive");
        assert_eq!(resp.body.get("query").unwrap().as_str(), Some(q), "answered out of order");
    }
}

#[test]
fn connection_close_is_honored() {
    let stack = TestStack::start();
    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    writer
        .write_all(
            b"GET /healthz HTTP/1.1\r\nHost: gaps-test\r\nConnection: close\r\n\r\n",
        )
        .expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.connection, "close", "the response must echo the client's close");
    expect_eof(&mut reader);
}

#[test]
fn keep_alive_reuses_one_socket() {
    let stack = TestStack::start();
    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    // Two sequential request/response round-trips on one socket.
    for q in ["grid computing", "data retrieval"] {
        writer
            .write_all(post("/search", &format!(r#"{{"query": "{q}"}}"#)).as_bytes())
            .expect("send");
        let resp = read_response(&mut reader);
        assert_eq!(resp.status, 200, "{:?}", resp.body);
        assert_eq!(resp.connection, "keep-alive");
    }

    // The healthz counters (request 3 on the same socket) make the
    // reuse observable: one accepted connection, three requests, two of
    // them on an already-used socket.
    writer
        .write_all(b"GET /healthz HTTP/1.1\r\nHost: gaps-test\r\n\r\n")
        .expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 200);
    let http = resp.body.get("http").expect("connection counters");
    assert_eq!(http.get("accepted").unwrap().as_i64(), Some(1));
    assert_eq!(http.get("requests").unwrap().as_i64(), Some(3));
    assert_eq!(http.get("reused").unwrap().as_i64(), Some(2));
}

#[test]
fn dribbled_request_bytes_are_assembled() {
    // A slow client delivering its request a few bytes at a time (well
    // within the read timeout) must still be served — partial header
    // reads may not be treated as malformed.
    let stack = TestStack::start();
    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    let wire = post("/search", r#"{"query": "grid computing"}"#);
    for chunk in wire.as_bytes().chunks(7) {
        writer.write_all(chunk).expect("dribble");
        writer.flush().expect("flush");
        std::thread::sleep(Duration::from_millis(2));
    }
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 200, "{:?}", resp.body);
    assert_eq!(resp.body.get("query").unwrap().as_str(), Some("grid computing"));
}

#[test]
fn malformed_content_length_is_400_and_closes() {
    let stack = TestStack::start();
    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    writer
        .write_all(b"POST /search HTTP/1.1\r\nHost: gaps-test\r\nContent-Length: soon\r\n\r\n")
        .expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 400);
    assert_eq!(resp.body.get("kind").unwrap().as_str(), Some("bad-request"));
    assert_eq!(
        resp.connection, "close",
        "a framing error leaves the stream position unknown — must close"
    );
    expect_eof(&mut reader);
}

#[test]
fn oversized_content_length_is_413_and_closes() {
    let stack = TestStack::start();
    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    // Rejected on the declared length alone — no body bytes are sent.
    writer
        .write_all(b"POST /search HTTP/1.1\r\nHost: gaps-test\r\nContent-Length: 2097152\r\n\r\n")
        .expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 413);
    assert_eq!(resp.connection, "close");
    expect_eof(&mut reader);
}

#[test]
fn application_errors_keep_the_connection_usable() {
    // Framing errors close; *application* errors (unparseable JSON,
    // unroutable path, a query the engine rejects) are complete framed
    // responses — the socket stays usable for the next request.
    let stack = TestStack::start();
    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    writer.write_all(post("/search", "not json").as_bytes()).expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 400);
    assert_eq!(resp.connection, "keep-alive", "a body-level 400 must not close");

    writer.write_all(post("/nope", "{}").as_bytes()).expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 404);
    assert_eq!(resp.connection, "keep-alive");

    writer.write_all(post("/search", r#"{"query": "the of and"}"#).as_bytes()).expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 400, "typed parse error");
    assert_eq!(resp.body.get("kind").unwrap().as_str(), Some("parse"));
    assert_eq!(resp.connection, "keep-alive");

    // After three errors, a good request on the same socket still works.
    writer
        .write_all(post("/search", r#"{"query": "grid computing"}"#).as_bytes())
        .expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 200, "{:?}", resp.body);
}

#[test]
fn keep_alive_off_closes_every_connection() {
    let stack = TestStack::start_with(HttpConfig { keep_alive: false, ..HttpConfig::default() });
    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    writer
        .write_all(post("/search", r#"{"query": "grid computing"}"#).as_bytes())
        .expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.connection, "close", "keep-alive off means one request per connection");
    expect_eof(&mut reader);
}

#[test]
fn shutdown_settles_pipelined_requests_typed() {
    // Regression (admission shutdown vs live keep-alive connections):
    // requests a client already pipelined onto a connection when the
    // admission layer shuts down must each be *answered* — typed, as
    // the retryable 503 the closed queue produces — and the connection
    // must then close cleanly. Resetting the socket mid-pipeline would
    // lose responses the client is entitled to.
    let stack = TestStack::start();
    stack.router().shutdown();

    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    let mut wire = String::new();
    wire.push_str(&post("/search", r#"{"query": "grid computing"}"#));
    wire.push_str(&post("/search", r#"{"query": "data retrieval"}"#));
    writer.write_all(wire.as_bytes()).expect("pipelined send");

    let first = read_response(&mut reader);
    assert_eq!(first.status, 503);
    assert_eq!(first.body.get("kind").unwrap().as_str(), Some("unavailable"));
    assert_eq!(
        first.connection, "keep-alive",
        "the second pipelined request is still buffered — not yet time to close"
    );

    let second = read_response(&mut reader);
    assert_eq!(second.status, 503);
    assert_eq!(second.body.get("kind").unwrap().as_str(), Some("unavailable"));
    assert_eq!(
        second.connection, "close",
        "pipeline drained against a shut-down queue — the connection must settle and close"
    );
    expect_eof(&mut reader);
}

#[test]
fn idle_keep_alive_connection_closes_quietly_on_timeout() {
    // Between requests there is nothing to answer 408 to: an idle
    // keep-alive connection that outlives the read timeout is closed
    // with no response bytes at all.
    let stack = TestStack::start_with(HttpConfig {
        read_timeout: Duration::from_millis(150),
        ..HttpConfig::default()
    });
    let stream = TcpStream::connect(stack.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    writer
        .write_all(post("/search", r#"{"query": "grid computing"}"#).as_bytes())
        .expect("send");
    let resp = read_response(&mut reader);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.connection, "keep-alive");

    // Now go idle past the timeout: quiet close, not a 408.
    expect_eof(&mut reader);
}
