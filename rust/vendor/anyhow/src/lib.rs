//! Offline stand-in for the `anyhow` crate.
//!
//! The vendored crate set this repository builds against has no registry
//! access, so this tiny crate provides the exact `anyhow` API subset GAPS
//! uses: [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Context chains are flattened
//! eagerly into a single `"context: source"` string, which matches how
//! the binaries render errors (`{e:#}`).
//!
//! If the build environment ever gains the real `anyhow`, deleting this
//! crate and pointing `Cargo.toml` at the registry is a drop-in swap.

use std::fmt;

/// Flattened error value: the message already carries its context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?`, flattening its source chain. `Error`
// itself deliberately does not implement `std::error::Error` (same as
// real anyhow) so this blanket impl stays coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result` — `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error { msg: format!("{ctx}: {}", e.msg) }
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error { msg: format!("{}: {}", f(), e.msg) }
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("missing"));
    }

    #[test]
    fn context_chains_flatten() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad shard {}", 7);
        assert_eq!(e.to_string(), "bad shard 7");
        fn bails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(bails(3).unwrap(), 3);
        assert!(bails(5).is_err());
        assert!(bails(20).unwrap_err().to_string().contains("too big"));
    }
}
