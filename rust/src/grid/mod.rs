//! Simulated grid fabric.
//!
//! The paper's testbed is 12 physical hosts in 3 Virtual Organizations
//! running Globus 4.0.2 with a Certificate Authority on each broker. We
//! reproduce the *behaviourally relevant* parts in-process (ARCHITECTURE.md
//! §Substitutions):
//!
//! * heterogeneous node speeds ("the grid nodes have different
//!   specifications") — [`NodeInfo::speed_factor`];
//! * LAN/WAN structure and transfer costs — [`NetworkModel`];
//! * GSI-style credentials issued by a per-VO CA — [`CertificateAuthority`];
//! * the always-resident globus service container — [`ServiceContainer`];
//! * brokers: node 0 of each VO doubles as broker + compute node, exactly
//!   like the paper's testbed.
//!
//! Real compute (tokenize/retrieve/score) is *measured*; fabric overheads
//! (latency, bandwidth, cold starts) are *accounted* through
//! [`crate::util::clock::TaskTimeline`] so experiments expose both parts.

mod ca;
mod container;
mod fabric;
mod net;
mod node;

pub use ca::{CaError, Credential, CertificateAuthority};
pub use container::{ServiceContainer, ServiceHandle};
pub use fabric::{GridFabric, Vo};
pub use net::NetworkModel;
pub use node::{NodeId, NodeInfo, NodeStatus, VoId};
