//! Network cost model: LAN within a VO, WAN between VOs, finite bandwidth.
//!
//! The paper's search jobs and results move over the campus grid; on our
//! in-process fabric those transfers are *accounted* rather than incurred:
//! `transfer_s` returns the simulated seconds a message of `bytes` takes
//! between two nodes, which the coordinator adds to the job's
//! [`crate::util::clock::TaskTimeline`] as `net_s`.

use super::node::{NodeInfo, VoId};

/// Latency + bandwidth model.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// One-way latency within a VO (seconds).
    pub lan_latency_s: f64,
    /// One-way latency between VOs (seconds).
    pub wan_latency_s: f64,
    /// Link bandwidth (bytes/second).
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    pub fn new(lan_latency_us: u64, wan_latency_us: u64, bandwidth_mbps: f64) -> Self {
        assert!(bandwidth_mbps > 0.0);
        NetworkModel {
            lan_latency_s: lan_latency_us as f64 * 1e-6,
            wan_latency_s: wan_latency_us as f64 * 1e-6,
            bandwidth_bps: bandwidth_mbps * 1e6,
        }
    }

    /// Simulated one-way transfer time for `bytes` between VOs `a` and `b`
    /// (same node => 0; same VO => LAN; different VO => WAN).
    pub fn transfer_s(&self, a: VoId, b: VoId, same_node: bool, bytes: usize) -> f64 {
        if same_node {
            return 0.0;
        }
        let latency = if a == b { self.lan_latency_s } else { self.wan_latency_s };
        latency + bytes as f64 / self.bandwidth_bps
    }

    /// Transfer between two nodes using their registry entries.
    pub fn transfer_between_s(&self, a: &NodeInfo, b: &NodeInfo, bytes: usize) -> f64 {
        self.transfer_s(a.vo, b.vo, a.id == b.id, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::node::{NodeId, NodeInfo};

    fn net() -> NetworkModel {
        NetworkModel::new(200, 8_000, 40.0)
    }

    fn node(id: u32, vo: u32) -> NodeInfo {
        NodeInfo { id: NodeId(id), vo: VoId(vo), speed_factor: 1.0, is_broker: false }
    }

    #[test]
    fn same_node_is_free() {
        assert_eq!(net().transfer_between_s(&node(1, 0), &node(1, 0), 1 << 20), 0.0);
    }

    #[test]
    fn lan_cheaper_than_wan() {
        let n = net();
        let lan = n.transfer_between_s(&node(1, 0), &node(2, 0), 1024);
        let wan = n.transfer_between_s(&node(1, 0), &node(5, 1), 1024);
        assert!(lan < wan);
        assert!(lan > 0.0);
    }

    #[test]
    fn bandwidth_term_scales_with_size() {
        let n = net();
        let small = n.transfer_s(VoId(0), VoId(1), false, 1024);
        let big = n.transfer_s(VoId(0), VoId(1), false, 40_000_000);
        // 40 MB at 40 MB/s ~ 1 s of serialization.
        assert!(big - small > 0.9, "big={big} small={small}");
    }

    #[test]
    fn latency_matches_config() {
        let n = net();
        assert!((n.transfer_s(VoId(0), VoId(0), false, 0) - 200e-6).abs() < 1e-12);
        assert!((n.transfer_s(VoId(0), VoId(1), false, 0) - 8e-3).abs() < 1e-12);
    }
}
