//! Node and VO identities + static node facts.

use std::fmt;

/// Grid-wide node identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// Virtual Organization identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VoId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node{}", self.0)
    }
}

impl fmt::Display for VoId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "vo{}", self.0)
    }
}

/// Liveness as tracked by the Resource Manager.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    Up,
    /// Node dropped out (grid dynamicity: "organizations resources ...
    /// join or leaves the system at any time").
    Down,
}

/// Static facts about a node (the Resource Manager's registry entry).
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub id: NodeId,
    pub vo: VoId,
    /// Relative CPU speed (1.0 = nominal). Real measured work on this node
    /// is accounted as `measured / speed_factor`.
    pub speed_factor: f64,
    /// Whether this node doubles as its VO's broker (+CA host).
    pub is_broker: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_forms() {
        assert_eq!(NodeId(3).to_string(), "node3");
        assert_eq!(VoId(1).to_string(), "vo1");
    }

    #[test]
    fn ids_are_ordered_and_hashable() {
        let mut set = std::collections::HashSet::new();
        set.insert(NodeId(1));
        set.insert(NodeId(1));
        set.insert(NodeId(2));
        assert_eq!(set.len(), 2);
        assert!(NodeId(1) < NodeId(2));
    }
}
