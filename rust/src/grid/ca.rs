//! Certificate Authority: GSI-style credentials for grid services.
//!
//! The paper installs a CA server on every broker ("one of four nodes has
//! two roles as grid broker equipped with Certificate Authority server").
//! Our in-process equivalent issues signed tokens (FNV-MAC over subject +
//! issuer secret — NOT cryptography, a behavioural stand-in) that the
//! Search Services verify before accepting a job. This keeps the paper's
//! *handshake structure* (issue once per node at deploy time, verify per
//! job) visible and testable without an X.509 stack.

/// Keyed FNV-1a token MAC (behavioural stand-in, not cryptography).
fn mac(subject: &str, secret: u64) -> u64 {
    // FNV-1a over subject bytes, keyed by folding in the secret.
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ secret;
    for b in subject.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// A credential issued by a CA for one subject (node or service).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Credential {
    pub subject: String,
    pub issuer_vo: u32,
    token: u64,
}

/// Verification failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CaError {
    BadToken,
    WrongIssuer { expected: u32, got: u32 },
}

impl std::fmt::Display for CaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CaError::BadToken => write!(f, "credential token invalid"),
            CaError::WrongIssuer { expected, got } => {
                write!(f, "credential issued by vo{got}, expected vo{expected}")
            }
        }
    }
}

impl std::error::Error for CaError {}

/// Per-VO certificate authority (lives on the broker node).
#[derive(Debug)]
pub struct CertificateAuthority {
    vo: u32,
    secret: u64,
}

impl CertificateAuthority {
    /// Create a CA for a VO; `secret` derives from the fabric seed.
    pub fn new(vo: u32, secret: u64) -> Self {
        CertificateAuthority { vo, secret }
    }

    /// Issue a credential for `subject`.
    pub fn issue(&self, subject: &str) -> Credential {
        Credential { subject: subject.to_string(), issuer_vo: self.vo, token: mac(subject, self.secret) }
    }

    /// Verify a credential this CA issued.
    pub fn verify(&self, cred: &Credential) -> Result<(), CaError> {
        if cred.issuer_vo != self.vo {
            return Err(CaError::WrongIssuer { expected: self.vo, got: cred.issuer_vo });
        }
        if cred.token != mac(&cred.subject, self.secret) {
            return Err(CaError::BadToken);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_verify_roundtrip() {
        let ca = CertificateAuthority::new(0, 1234);
        let cred = ca.issue("node3/search-service");
        assert!(ca.verify(&cred).is_ok());
    }

    #[test]
    fn tampered_subject_rejected() {
        let ca = CertificateAuthority::new(0, 1234);
        let mut cred = ca.issue("node3");
        cred.subject = "node4".into();
        assert_eq!(ca.verify(&cred), Err(CaError::BadToken));
    }

    #[test]
    fn cross_vo_rejected() {
        let ca0 = CertificateAuthority::new(0, 111);
        let ca1 = CertificateAuthority::new(1, 222);
        let cred = ca0.issue("node1");
        assert!(matches!(ca1.verify(&cred), Err(CaError::WrongIssuer { .. })));
    }

    #[test]
    fn different_secrets_different_tokens() {
        let a = CertificateAuthority::new(0, 1).issue("n");
        let b = CertificateAuthority::new(0, 2).issue("n");
        assert_ne!(a, b);
    }
}
