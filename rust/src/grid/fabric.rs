//! Fabric assembly: VOs, nodes, brokers, CAs, containers, network.

use super::ca::CertificateAuthority;
use super::container::ServiceContainer;
use super::net::NetworkModel;
use super::node::{NodeId, NodeInfo, VoId};
use crate::config::GridConfig;
use crate::util::rng::Rng;

/// One Virtual Organization: a broker (node 0 of the VO) plus members.
#[derive(Debug)]
pub struct Vo {
    pub id: VoId,
    pub broker: NodeId,
    pub members: Vec<NodeId>,
    pub ca: CertificateAuthority,
}

/// The assembled grid fabric.
#[derive(Debug)]
pub struct GridFabric {
    pub vos: Vec<Vo>,
    pub nodes: Vec<NodeInfo>,
    pub net: NetworkModel,
    /// Per-node service containers, indexed by NodeId.0.
    pub containers: Vec<ServiceContainer>,
}

impl GridFabric {
    /// Build a fabric per config: `num_vos` VOs of `nodes_per_vo` nodes,
    /// node 0 of each VO doubling as broker + CA host (the paper's
    /// layout), speed factors drawn uniform in [speed_min, speed_max].
    pub fn build(cfg: &GridConfig) -> GridFabric {
        assert!(cfg.num_vos >= 1 && cfg.nodes_per_vo >= 1, "empty fabric");
        assert!(cfg.speed_min > 0.0 && cfg.speed_max >= cfg.speed_min);
        let mut rng = Rng::new(cfg.seed);
        let mut vos = Vec::with_capacity(cfg.num_vos);
        let mut nodes = Vec::with_capacity(cfg.total_nodes());
        let mut containers = Vec::with_capacity(cfg.total_nodes());

        for vo_idx in 0..cfg.num_vos {
            let vo_id = VoId(vo_idx as u32);
            let mut members = Vec::with_capacity(cfg.nodes_per_vo);
            for n in 0..cfg.nodes_per_vo {
                let id = NodeId((vo_idx * cfg.nodes_per_vo + n) as u32);
                let speed_factor = rng.range_f64(cfg.speed_min, cfg.speed_max);
                nodes.push(NodeInfo { id, vo: vo_id, speed_factor, is_broker: n == 0 });
                let mut container = ServiceContainer::new(
                    id.to_string(),
                    cfg.resident_services,
                    cfg.cold_start_ms * 1e-3,
                );
                container.deploy("search-service");
                containers.push(container);
                members.push(id);
            }
            let ca = CertificateAuthority::new(vo_id.0, cfg.seed ^ (vo_idx as u64) << 17);
            vos.push(Vo { id: vo_id, broker: members[0], members, ca });
        }

        GridFabric {
            vos,
            nodes,
            net: NetworkModel::new(cfg.lan_latency_us, cfg.wan_latency_us, cfg.bandwidth_mbps),
            containers,
        }
    }

    pub fn node(&self, id: NodeId) -> &NodeInfo {
        &self.nodes[id.0 as usize]
    }

    pub fn vo_of(&self, id: NodeId) -> &Vo {
        &self.vos[self.node(id).vo.0 as usize]
    }

    /// The first `n` nodes of the fabric in a VO-round-robin order, so a
    /// k-node experiment spreads across VOs the way the paper's testbed
    /// sweeps did (2 nodes => 2 VOs, 6 nodes => all 3 VOs).
    pub fn first_nodes_balanced(&self, n: usize) -> Vec<NodeId> {
        let mut out = Vec::with_capacity(n);
        let mut idx = 0usize;
        while out.len() < n {
            let vo = &self.vos[idx % self.vos.len()];
            let within = idx / self.vos.len();
            if within < vo.members.len() {
                out.push(vo.members[within]);
            }
            idx += 1;
            if idx > self.vos.len() * self.nodes.len() {
                break; // n exceeds fabric size
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;

    #[test]
    fn build_matches_paper_layout() {
        let f = GridFabric::build(&GridConfig::default());
        assert_eq!(f.vos.len(), 3);
        assert_eq!(f.nodes.len(), 12);
        assert_eq!(f.containers.len(), 12);
        for vo in &f.vos {
            assert_eq!(vo.members.len(), 4);
            assert_eq!(vo.broker, vo.members[0]);
            assert!(f.node(vo.broker).is_broker);
        }
    }

    #[test]
    fn speeds_heterogeneous_and_in_range() {
        let cfg = GridConfig::default();
        let f = GridFabric::build(&cfg);
        let speeds: Vec<f64> = f.nodes.iter().map(|n| n.speed_factor).collect();
        assert!(speeds.iter().all(|&s| (cfg.speed_min..=cfg.speed_max).contains(&s)));
        let min = speeds.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = speeds.iter().cloned().fold(0.0, f64::max);
        assert!(max - min > 0.1, "speeds should differ (min={min} max={max})");
    }

    #[test]
    fn build_is_deterministic() {
        let a = GridFabric::build(&GridConfig::default());
        let b = GridFabric::build(&GridConfig::default());
        for (x, y) in a.nodes.iter().zip(&b.nodes) {
            assert_eq!(x.speed_factor, y.speed_factor);
        }
    }

    #[test]
    fn ca_per_vo_issues_for_members() {
        let f = GridFabric::build(&GridConfig::default());
        let vo = &f.vos[1];
        let cred = vo.ca.issue(&vo.members[2].to_string());
        assert!(vo.ca.verify(&cred).is_ok());
        assert!(f.vos[0].ca.verify(&cred).is_err());
    }

    #[test]
    fn containers_have_search_service() {
        let mut f = GridFabric::build(&GridConfig::default());
        for c in &mut f.containers {
            assert!(c.acquire("search-service").is_some());
        }
    }

    #[test]
    fn balanced_selection_spreads_over_vos() {
        let f = GridFabric::build(&GridConfig::default());
        let three = f.first_nodes_balanced(3);
        let vos: std::collections::HashSet<u32> =
            three.iter().map(|&id| f.node(id).vo.0).collect();
        assert_eq!(vos.len(), 3, "3 nodes should span 3 VOs: {three:?}");
        let all = f.first_nodes_balanced(12);
        assert_eq!(all.len(), 12);
        let uniq: std::collections::HashSet<NodeId> = all.iter().copied().collect();
        assert_eq!(uniq.len(), 12);
    }

    #[test]
    fn oversized_selection_capped() {
        let f = GridFabric::build(&GridConfig::default());
        assert_eq!(f.first_nodes_balanced(40).len(), 12);
    }
}
