//! Service container: the globus-container analogue.
//!
//! Paper: "The SS is implemented as a grid service and is installed to be
//! run with the globus container. The globus container is run once the
//! node starts ... the SS does not need to wait time to load on the memory
//! when the node receives search job request."
//!
//! [`ServiceContainer`] models exactly that: services register once at
//! node start; `acquire` returns a handle plus the *accounted* startup
//! cost — zero for resident services, `cold_start_s` when the container is
//! configured non-resident (the ablation in `benches/ablations.rs`).

use std::collections::HashMap;

/// Handle to an acquired service: name + the accounted acquisition cost.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceHandle {
    pub service: String,
    /// Accounted startup overhead in seconds (0 when resident).
    pub startup_s: f64,
}

/// Per-node service registry.
#[derive(Debug)]
pub struct ServiceContainer {
    node: String,
    resident: bool,
    cold_start_s: f64,
    services: HashMap<String, u64 /* acquisition count */>,
}

impl ServiceContainer {
    pub fn new(node: impl Into<String>, resident: bool, cold_start_s: f64) -> Self {
        ServiceContainer {
            node: node.into(),
            resident,
            cold_start_s,
            services: HashMap::new(),
        }
    }

    /// Register a service at node start (idempotent).
    pub fn deploy(&mut self, service: &str) {
        self.services.entry(service.to_string()).or_insert(0);
    }

    /// Acquire a deployed service for one job. Returns `None` when the
    /// service was never deployed on this node.
    pub fn acquire(&mut self, service: &str) -> Option<ServiceHandle> {
        let count = self.services.get_mut(service)?;
        *count += 1;
        let startup_s = if self.resident {
            0.0
        } else {
            // Non-resident: every acquisition loads the service anew.
            self.cold_start_s
        };
        Some(ServiceHandle { service: service.to_string(), startup_s })
    }

    /// How many times a service has been acquired (metrics).
    pub fn acquisitions(&self, service: &str) -> u64 {
        self.services.get(service).copied().unwrap_or(0)
    }

    pub fn node(&self) -> &str {
        &self.node
    }

    pub fn is_resident(&self) -> bool {
        self.resident
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resident_services_have_zero_startup() {
        let mut c = ServiceContainer::new("node0", true, 0.35);
        c.deploy("search-service");
        let h = c.acquire("search-service").unwrap();
        assert_eq!(h.startup_s, 0.0);
        assert_eq!(c.acquisitions("search-service"), 1);
    }

    #[test]
    fn cold_start_accounted_when_not_resident() {
        let mut c = ServiceContainer::new("node0", false, 0.35);
        c.deploy("search-service");
        for _ in 0..3 {
            let h = c.acquire("search-service").unwrap();
            assert_eq!(h.startup_s, 0.35);
        }
        assert_eq!(c.acquisitions("search-service"), 3);
    }

    #[test]
    fn unknown_service_is_none() {
        let mut c = ServiceContainer::new("node0", true, 0.0);
        assert!(c.acquire("nope").is_none());
    }

    #[test]
    fn deploy_is_idempotent() {
        let mut c = ServiceContainer::new("node0", true, 0.0);
        c.deploy("ss");
        c.acquire("ss").unwrap();
        c.deploy("ss"); // must not reset the counter
        assert_eq!(c.acquisitions("ss"), 1);
    }
}
