//! Inverted index over hashed features: the retrieval half of the Search
//! Service. Postings are per feature bucket (any field), sorted by local
//! doc id; retrieval is a counting OR-merge that returns candidates
//! ordered by match count (docs matching more distinct query terms first).

use super::store::ShardDoc;

/// Immutable inverted index for one shard.
#[derive(Debug, Clone)]
pub struct InvertedIndex {
    /// postings[bucket] = sorted local doc ids containing that bucket.
    postings: Vec<Vec<u32>>,
}

impl InvertedIndex {
    /// Build from analyzed docs (each doc indexed once per bucket even if
    /// the bucket occurs in several fields).
    pub fn build(docs: &[ShardDoc], features: usize) -> InvertedIndex {
        let mut postings: Vec<Vec<u32>> = vec![Vec::new(); features];
        for (local_id, doc) in docs.iter().enumerate() {
            let lid = local_id as u32;
            for tf in &doc.field_tf {
                for (bucket, _) in tf {
                    let list = &mut postings[*bucket as usize];
                    if list.last() != Some(&lid) {
                        list.push(lid);
                    }
                }
            }
        }
        InvertedIndex { postings }
    }

    /// Posting list for a bucket (empty slice if absent).
    pub fn postings(&self, bucket: u32) -> &[u32] {
        self.postings.get(bucket as usize).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Total number of postings (index size metric).
    pub fn num_postings(&self) -> usize {
        self.postings.iter().map(|p| p.len()).sum()
    }

    /// OR-retrieve candidates for the given query buckets: returns
    /// (local_id, distinct-terms-matched) sorted by match count descending
    /// then local id, truncated to `max_candidates`.
    pub fn retrieve(&self, buckets: &[u32], max_candidates: usize) -> Vec<(u32, u16)> {
        let mut counts: std::collections::HashMap<u32, u16> = std::collections::HashMap::new();
        // Dedup buckets so a repeated query term doesn't double-count.
        let mut uniq: Vec<u32> = buckets.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        for b in uniq {
            for &doc in self.postings(b) {
                *counts.entry(doc).or_insert(0) += 1;
            }
        }
        let mut out: Vec<(u32, u16)> = counts.into_iter().collect();
        out.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        out.truncate(max_candidates);
        out
    }

    /// AND-retrieve: docs containing *all* buckets (used by the
    /// multivariate field filters). Returns sorted local ids.
    pub fn retrieve_all(&self, buckets: &[u32]) -> Vec<u32> {
        if buckets.is_empty() {
            return Vec::new();
        }
        let mut uniq: Vec<u32> = buckets.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        // Start from the shortest posting list, intersect the rest.
        uniq.sort_by_key(|b| self.postings(*b).len());
        let mut acc: Vec<u32> = self.postings(uniq[0]).to_vec();
        for b in &uniq[1..] {
            let list = self.postings(*b);
            acc.retain(|d| list.binary_search(d).is_ok());
            if acc.is_empty() {
                break;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::NUM_FIELDS;

    /// Build a ShardDoc from (bucket, tf) pairs in field 0.
    fn doc(global_id: u64, buckets: &[u32]) -> ShardDoc {
        let mut field_tf: [Vec<(u32, f32)>; NUM_FIELDS] = Default::default();
        field_tf[0] = buckets.iter().map(|&b| (b, 1.0)).collect();
        ShardDoc { global_id, field_tf, field_len: [buckets.len() as f32, 0.0, 0.0, 0.0] }
    }

    fn index() -> InvertedIndex {
        InvertedIndex::build(
            &[
                doc(0, &[1, 2, 3]),
                doc(1, &[2, 3]),
                doc(2, &[3]),
                doc(3, &[4]),
            ],
            8,
        )
    }

    #[test]
    fn postings_sorted_and_correct() {
        let ix = index();
        assert_eq!(ix.postings(1), &[0]);
        assert_eq!(ix.postings(2), &[0, 1]);
        assert_eq!(ix.postings(3), &[0, 1, 2]);
        assert_eq!(ix.postings(7), &[] as &[u32]);
        assert_eq!(ix.num_postings(), 7);
    }

    #[test]
    fn or_retrieval_orders_by_match_count() {
        let ix = index();
        let got = ix.retrieve(&[1, 2, 3], 10);
        assert_eq!(got, vec![(0, 3), (1, 2), (2, 1)]);
    }

    #[test]
    fn or_retrieval_truncates() {
        let ix = index();
        let got = ix.retrieve(&[3], 2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 0);
    }

    #[test]
    fn duplicate_query_buckets_count_once() {
        let ix = index();
        let got = ix.retrieve(&[2, 2, 2], 10);
        assert_eq!(got, vec![(0, 1), (1, 1)]);
    }

    #[test]
    fn and_retrieval_intersects() {
        let ix = index();
        assert_eq!(ix.retrieve_all(&[2, 3]), vec![0, 1]);
        assert_eq!(ix.retrieve_all(&[1, 4]), Vec::<u32>::new());
        assert_eq!(ix.retrieve_all(&[]), Vec::<u32>::new());
    }

    #[test]
    fn multifield_doc_indexed_once_per_bucket() {
        let mut field_tf: [Vec<(u32, f32)>; NUM_FIELDS] = Default::default();
        field_tf[0] = vec![(5, 1.0)];
        field_tf[1] = vec![(5, 3.0)];
        let d = ShardDoc { global_id: 0, field_tf, field_len: [1.0, 3.0, 0.0, 0.0] };
        let ix = InvertedIndex::build(&[d], 8);
        assert_eq!(ix.postings(5), &[0]);
    }

    #[test]
    fn out_of_range_bucket_is_empty() {
        let ix = index();
        assert_eq!(ix.postings(100), &[] as &[u32]);
        assert!(ix.retrieve(&[100], 5).is_empty());
    }
}
