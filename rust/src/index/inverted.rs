//! Inverted index over hashed features: the retrieval half of the Search
//! Service. Postings are per feature bucket (any field), sorted by local
//! doc id; retrieval is a counting OR-merge that returns candidates
//! ordered by match count (docs matching more distinct query terms first).
//!
//! Layout: postings live in one flattened CSR arena (`offsets` + `data`)
//! instead of a `Vec<Vec<u32>>` — a single contiguous allocation whose
//! sequential probes stay cache-friendly at 100k+ docs per shard. The
//! counting OR-merge runs against a reusable [`RetrievalScratch`] (no
//! per-query `HashMap`), and top-`max_candidates` selection is a bounded
//! min-heap: O(postings + k log k) instead of sorting every candidate.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::store::ShardDoc;

/// Immutable inverted index for one shard, stored as a CSR arena.
#[derive(Debug, Clone)]
pub struct InvertedIndex {
    /// Bucket `b`'s postings live in `data[offsets[b]..offsets[b+1]]`.
    offsets: Vec<u32>,
    /// Flattened postings: per-bucket runs of sorted local doc ids.
    data: Vec<u32>,
    /// Documents in the shard this index covers (scratch sizing).
    num_docs: u32,
}

/// Reusable per-query retrieval state. Owning one of these (per thread)
/// makes `retrieve_into` allocation-free in steady state: the dense count
/// array is cleared sparsely via the touched list, never rebuilt.
#[derive(Debug, Default)]
pub struct RetrievalScratch {
    /// Dense per-doc distinct-term match counts (0 = untouched).
    counts: Vec<u16>,
    /// Docs whose count is nonzero this query (sparse-clear list).
    touched: Vec<u32>,
    /// Dedup buffer for query buckets.
    uniq: Vec<u32>,
    /// Bounded selection heap; `Reverse` makes the std max-heap a
    /// min-heap whose root is the worst candidate currently kept.
    heap: BinaryHeap<Reverse<(u16, Reverse<u32>)>>,
    /// Result buffer: (local_id, match count), best first.
    out: Vec<(u32, u16)>,
}

impl RetrievalScratch {
    pub fn new() -> RetrievalScratch {
        RetrievalScratch::default()
    }

    /// Hits produced by the last `retrieve_into` call.
    pub fn hits(&self) -> &[(u32, u16)] {
        &self.out
    }

    /// Take ownership of the last result (used by the one-shot wrapper).
    pub fn take_hits(&mut self) -> Vec<(u32, u16)> {
        std::mem::take(&mut self.out)
    }
}

impl InvertedIndex {
    /// Build from analyzed docs (each doc indexed once per bucket even if
    /// the bucket occurs in several fields). Two-pass CSR construction:
    /// count, prefix-sum, fill.
    pub fn build(docs: &[ShardDoc], features: usize) -> InvertedIndex {
        // Pass 1: posting count per bucket. `last[b]` is the last doc id
        // counted for bucket b — docs arrive in increasing local id, so
        // comparing against it dedups multi-field occurrences.
        let mut counts = vec![0u32; features];
        let mut last = vec![u32::MAX; features];
        for (local_id, doc) in docs.iter().enumerate() {
            let lid = local_id as u32;
            for tf in &doc.field_tf {
                for (bucket, _) in tf {
                    let b = *bucket as usize;
                    if last[b] != lid {
                        last[b] = lid;
                        counts[b] += 1;
                    }
                }
            }
        }

        let mut offsets = vec![0u32; features + 1];
        for b in 0..features {
            offsets[b + 1] = offsets[b] + counts[b];
        }

        // Pass 2: fill the arena through per-bucket write cursors.
        let mut data = vec![0u32; offsets[features] as usize];
        let mut cursor: Vec<u32> = offsets[..features].to_vec();
        last.fill(u32::MAX);
        for (local_id, doc) in docs.iter().enumerate() {
            let lid = local_id as u32;
            for tf in &doc.field_tf {
                for (bucket, _) in tf {
                    let b = *bucket as usize;
                    if last[b] != lid {
                        last[b] = lid;
                        data[cursor[b] as usize] = lid;
                        cursor[b] += 1;
                    }
                }
            }
        }
        InvertedIndex { offsets, data, num_docs: docs.len() as u32 }
    }

    /// Posting list for a bucket (empty slice if absent).
    pub fn postings(&self, bucket: u32) -> &[u32] {
        let b = bucket as usize;
        if b + 1 >= self.offsets.len() {
            return &[];
        }
        &self.data[self.offsets[b] as usize..self.offsets[b + 1] as usize]
    }

    /// Total number of postings (index size metric).
    pub fn num_postings(&self) -> usize {
        self.data.len()
    }

    /// Documents covered by this index.
    pub fn num_docs(&self) -> usize {
        self.num_docs as usize
    }

    /// OR-retrieve candidates for the given query buckets into `scratch`:
    /// `scratch.hits()` holds (local_id, distinct-terms-matched) sorted by
    /// match count descending then local id, truncated to
    /// `max_candidates`. Allocation-free once the scratch has warmed up.
    pub fn retrieve_into(
        &self,
        buckets: &[u32],
        max_candidates: usize,
        scratch: &mut RetrievalScratch,
    ) {
        scratch.out.clear();
        if max_candidates == 0 {
            return;
        }
        if scratch.counts.len() < self.num_docs as usize {
            scratch.counts.resize(self.num_docs as usize, 0);
        }
        debug_assert!(scratch.touched.is_empty(), "scratch not cleared");

        // Dedup buckets so a repeated query term doesn't double-count.
        scratch.uniq.clear();
        scratch.uniq.extend_from_slice(buckets);
        scratch.uniq.sort_unstable();
        scratch.uniq.dedup();

        // Counting OR-merge over the arena (disjoint-field borrows: the
        // bucket list is read while counts/touched are written).
        for &b in &scratch.uniq {
            for &doc in self.postings(b) {
                let c = &mut scratch.counts[doc as usize];
                if *c == 0 {
                    scratch.touched.push(doc);
                }
                *c = c.saturating_add(1);
            }
        }

        // Top-k selection. Ordering: higher count wins, ties go to the
        // smaller doc id — encoded as the tuple (count, Reverse(doc)) so
        // "greater" means "better".
        let k = max_candidates;
        if scratch.touched.len() <= k {
            for &d in &scratch.touched {
                scratch.out.push((d, scratch.counts[d as usize]));
            }
        } else {
            scratch.heap.clear();
            for &d in &scratch.touched {
                let key = Reverse((scratch.counts[d as usize], Reverse(d)));
                if scratch.heap.len() < k {
                    scratch.heap.push(key);
                } else if key < *scratch.heap.peek().expect("heap nonempty") {
                    // Better than the worst kept (Reverse flips the order).
                    scratch.heap.pop();
                    scratch.heap.push(key);
                }
            }
            scratch
                .out
                .extend(scratch.heap.drain().map(|Reverse((c, Reverse(d)))| (d, c)));
        }
        scratch.out.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));

        // Sparse clear for the next query.
        for &d in &scratch.touched {
            scratch.counts[d as usize] = 0;
        }
        scratch.touched.clear();
    }

    /// One-shot OR-retrieve (allocates a fresh scratch; hot paths hold a
    /// [`RetrievalScratch`] and call [`InvertedIndex::retrieve_into`]).
    pub fn retrieve(&self, buckets: &[u32], max_candidates: usize) -> Vec<(u32, u16)> {
        let mut scratch = RetrievalScratch::new();
        self.retrieve_into(buckets, max_candidates, &mut scratch);
        scratch.take_hits()
    }

    /// Naive reference OR-retrieve: per-query `HashMap` counts + full
    /// sort (the seed implementation). Kept as the differential-testing
    /// oracle (`tests/prop_invariants.rs`) and the micro-benchmark
    /// baseline — result semantics of the arena path must match this
    /// exactly.
    pub fn retrieve_reference(&self, buckets: &[u32], max_candidates: usize) -> Vec<(u32, u16)> {
        let mut counts: std::collections::HashMap<u32, u16> = std::collections::HashMap::new();
        let mut uniq: Vec<u32> = buckets.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        for b in uniq {
            for &doc in self.postings(b) {
                let c = counts.entry(doc).or_insert(0);
                *c = c.saturating_add(1);
            }
        }
        let mut out: Vec<(u32, u16)> = counts.into_iter().collect();
        out.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        out.truncate(max_candidates);
        out
    }

    /// AND-retrieve: docs containing *all* buckets (used by the
    /// multivariate field filters). Returns sorted local ids. Intersects
    /// smallest-list-first with galloping (exponential) search — probes
    /// for successive targets resume from the previous cursor, so runs of
    /// near-misses cost O(log gap) instead of O(log n) each.
    pub fn retrieve_all(&self, buckets: &[u32]) -> Vec<u32> {
        if buckets.is_empty() {
            return Vec::new();
        }
        let mut uniq: Vec<u32> = buckets.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        // Start from the shortest posting list, intersect the rest.
        uniq.sort_by_key(|b| self.postings(*b).len());
        let mut acc: Vec<u32> = self.postings(uniq[0]).to_vec();
        for b in &uniq[1..] {
            if acc.is_empty() {
                break;
            }
            let list = self.postings(*b);
            let mut cursor = 0usize;
            let mut w = 0usize;
            for i in 0..acc.len() {
                let d = acc[i];
                cursor = gallop_to(list, cursor, d);
                if cursor == list.len() {
                    break;
                }
                if list[cursor] == d {
                    acc[w] = d;
                    w += 1;
                }
            }
            acc.truncate(w);
        }
        acc
    }
}

/// First index `i >= lo` with `list[i] >= target` in a sorted list, found
/// by doubling steps from `lo` then binary-searching the final window.
fn gallop_to(list: &[u32], mut lo: usize, target: u32) -> usize {
    if lo >= list.len() || list[lo] >= target {
        return lo;
    }
    // Invariant: list[lo] < target.
    let mut step = 1usize;
    while lo + step < list.len() && list[lo + step] < target {
        lo += step;
        step <<= 1;
    }
    let hi = (lo + step).min(list.len());
    // Answer lies in (lo, hi]: every element before lo+1 is < target and
    // list[hi] >= target (or hi == len).
    lo + 1 + list[lo + 1..hi].partition_point(|&x| x < target)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::NUM_FIELDS;

    /// Build a ShardDoc from (bucket, tf) pairs in field 0.
    fn doc(global_id: u64, buckets: &[u32]) -> ShardDoc {
        let mut field_tf: [Vec<(u32, f32)>; NUM_FIELDS] = Default::default();
        field_tf[0] = buckets.iter().map(|&b| (b, 1.0)).collect();
        ShardDoc { global_id, field_tf, field_len: [buckets.len() as f32, 0.0, 0.0, 0.0] }
    }

    fn index() -> InvertedIndex {
        InvertedIndex::build(
            &[
                doc(0, &[1, 2, 3]),
                doc(1, &[2, 3]),
                doc(2, &[3]),
                doc(3, &[4]),
            ],
            8,
        )
    }

    #[test]
    fn postings_sorted_and_correct() {
        let ix = index();
        assert_eq!(ix.postings(1), &[0]);
        assert_eq!(ix.postings(2), &[0, 1]);
        assert_eq!(ix.postings(3), &[0, 1, 2]);
        assert_eq!(ix.postings(7), &[] as &[u32]);
        assert_eq!(ix.num_postings(), 7);
        assert_eq!(ix.num_docs(), 4);
    }

    #[test]
    fn or_retrieval_orders_by_match_count() {
        let ix = index();
        let got = ix.retrieve(&[1, 2, 3], 10);
        assert_eq!(got, vec![(0, 3), (1, 2), (2, 1)]);
    }

    #[test]
    fn or_retrieval_truncates() {
        let ix = index();
        let got = ix.retrieve(&[3], 2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 0);
    }

    #[test]
    fn duplicate_query_buckets_count_once() {
        let ix = index();
        let got = ix.retrieve(&[2, 2, 2], 10);
        assert_eq!(got, vec![(0, 1), (1, 1)]);
    }

    #[test]
    fn and_retrieval_intersects() {
        let ix = index();
        assert_eq!(ix.retrieve_all(&[2, 3]), vec![0, 1]);
        assert_eq!(ix.retrieve_all(&[1, 4]), Vec::<u32>::new());
        assert_eq!(ix.retrieve_all(&[]), Vec::<u32>::new());
    }

    #[test]
    fn multifield_doc_indexed_once_per_bucket() {
        let mut field_tf: [Vec<(u32, f32)>; NUM_FIELDS] = Default::default();
        field_tf[0] = vec![(5, 1.0)];
        field_tf[1] = vec![(5, 3.0)];
        let d = ShardDoc { global_id: 0, field_tf, field_len: [1.0, 3.0, 0.0, 0.0] };
        let ix = InvertedIndex::build(&[d], 8);
        assert_eq!(ix.postings(5), &[0]);
    }

    #[test]
    fn out_of_range_bucket_is_empty() {
        let ix = index();
        assert_eq!(ix.postings(100), &[] as &[u32]);
        assert!(ix.retrieve(&[100], 5).is_empty());
    }

    #[test]
    fn scratch_reuse_is_clean_across_queries() {
        let ix = index();
        let mut scratch = RetrievalScratch::new();
        ix.retrieve_into(&[1, 2, 3], 10, &mut scratch);
        assert_eq!(scratch.hits(), &[(0, 3), (1, 2), (2, 1)]);
        // A second, disjoint query must not see counts from the first.
        ix.retrieve_into(&[4], 10, &mut scratch);
        assert_eq!(scratch.hits(), &[(3, 1)]);
        ix.retrieve_into(&[100], 10, &mut scratch);
        assert!(scratch.hits().is_empty());
    }

    #[test]
    fn heap_selection_matches_reference() {
        // Enough docs that every truncation path (heap vs copy-all) runs.
        let docs: Vec<ShardDoc> = (0..200)
            .map(|i| {
                let buckets: Vec<u32> = (0..8).filter(|b| (i + b) % 3 != 0).map(|b| b as u32).collect();
                doc(i as u64, &buckets)
            })
            .collect();
        let ix = InvertedIndex::build(&docs, 8);
        let query = [0u32, 1, 2, 3, 4, 5, 6, 7];
        for k in [1usize, 3, 10, 50, 199, 200, 500] {
            assert_eq!(ix.retrieve(&query, k), ix.retrieve_reference(&query, k), "k={k}");
        }
    }

    #[test]
    fn match_count_saturates_instead_of_overflowing() {
        // One doc present in more buckets than u16 can count: the match
        // count must clamp at u16::MAX, not panic (debug) or wrap
        // (release).
        let n = (u16::MAX as usize) + 10;
        let buckets: Vec<u32> = (0..n as u32).collect();
        let d = doc(0, &buckets);
        let ix = InvertedIndex::build(&[d], n);
        let got = ix.retrieve(&buckets, 4);
        assert_eq!(got, vec![(0, u16::MAX)]);
        assert_eq!(ix.retrieve_reference(&buckets, 4), vec![(0, u16::MAX)]);
    }

    #[test]
    fn galloping_intersection_matches_linear() {
        // Structured gaps exercise the doubling probe: list A is dense,
        // list B hits every 7th element, C every 13th.
        let docs: Vec<ShardDoc> = (0..500)
            .map(|i| {
                let mut b = vec![0u32];
                if i % 7 == 0 {
                    b.push(1);
                }
                if i % 13 == 0 {
                    b.push(2);
                }
                doc(i as u64, &b)
            })
            .collect();
        let ix = InvertedIndex::build(&docs, 4);
        let expect: Vec<u32> = (0..500u32).filter(|i| i % 7 == 0 && i % 13 == 0).collect();
        assert_eq!(ix.retrieve_all(&[0, 1, 2]), expect);
        assert_eq!(ix.retrieve_all(&[2, 1, 0]), expect, "order-independent");
    }

    #[test]
    fn gallop_to_finds_lower_bound() {
        let list = [2u32, 4, 6, 8, 10, 12, 14];
        assert_eq!(gallop_to(&list, 0, 1), 0);
        assert_eq!(gallop_to(&list, 0, 2), 0);
        assert_eq!(gallop_to(&list, 0, 7), 3);
        assert_eq!(gallop_to(&list, 2, 7), 3);
        assert_eq!(gallop_to(&list, 0, 14), 6);
        assert_eq!(gallop_to(&list, 0, 15), 7);
        assert_eq!(gallop_to(&list, 7, 15), 7);
        assert_eq!(gallop_to(&[], 0, 3), 0);
    }
}
