//! Inverted index over hashed features: the retrieval half of the Search
//! Service. Postings carry a quantized impact alongside each doc id, and
//! every posting list is segmented into fixed-size blocks with per-block
//! metadata, so OR-retrieval can run WAND-style block-max pruning: the
//! top-k heap threshold proves whole blocks (and whole document ranges)
//! unable to place, and they are skipped without being accumulated.
//!
//! # Binary layout
//!
//! One flattened CSR arena per shard:
//!
//! ```text
//! offsets:       [features + 1] u32   bucket b's postings live at
//!                                     docs/impacts[offsets[b]..offsets[b+1]]
//! docs:          [num_postings] u32   local doc ids, sorted per bucket
//! impacts:       [num_postings] u8    quantized per-(doc,bucket) impact,
//!                                     parallel to `docs`
//! block_offsets: [features + 1] u32   bucket b's block metadata lives at
//!                                     blocks[block_offsets[b]..block_offsets[b+1]]
//! blocks:        [num_blocks] BlockMeta
//! ```
//!
//! Each block covers up to [`BLOCK_SIZE`] consecutive postings of one
//! bucket and records the largest doc id (`last_doc`, for galloping the
//! AND path and seeking at block granularity) and the largest impact
//! (`max_impact`, for the WAND upper bounds) inside it.
//!
//! # Impact quantization
//!
//! A posting's impact is the document's total term frequency for that
//! bucket summed across every field, rounded and saturated into
//! `1..=255` (`quantize_impact`). A document's retrieval score for a
//! query is `sum over matched terms of (TERM_UNIT + impact)`: the
//! [`TERM_UNIT`] = 256 step keeps the seed ordering — docs matching more
//! *distinct* query terms always rank first — while the impact refines
//! ties toward term-frequency-heavy documents, so the BM25F ranker
//! receives a pre-ranked candidate set.
//!
//! # Retrieval
//!
//! [`InvertedIndex::retrieve_into`] is a document-at-a-time WAND with
//! block-max refinement: term cursors are kept sorted by current doc id;
//! list-level upper bounds pick the pivot (documents before it cannot
//! reach the current heap threshold and their postings are skipped
//! without accumulation); at the pivot, per-block `max_impact` bounds can
//! prove the pivot range hopeless and jump every involved cursor to the
//! nearest block boundary. The result is bit-identical to the naive
//! [`InvertedIndex::retrieve_reference`] oracle (same scores, same
//! (score desc, doc asc) order), which is retained for differential
//! tests and benchmarks. [`RetrievalCounters`] reports how much work the
//! pruning avoided — deterministic integers, fit for CI gating where
//! wall-clock is noise.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::store::ShardDoc;

/// Postings per block. 128 keeps block metadata ~1.5% of posting bytes
/// while making a skipped block worth two cache lines of doc ids.
pub const BLOCK_SIZE: usize = 128;

/// Retrieval-score step per matched query term. Strictly larger than any
/// quantized impact (255), so distinct-term match count dominates the
/// ordering and impacts only break ties within a match count.
pub const TERM_UNIT: u32 = 256;

/// Quantize a summed-across-fields term frequency into a u8 impact.
/// Monotone, saturating: 1 at tf<=1, 255 at tf>=255.
pub fn quantize_impact(tf_total: f32) -> u8 {
    tf_total.round().clamp(1.0, 255.0) as u8
}

/// Per-block metadata over a run of up to [`BLOCK_SIZE`] postings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockMeta {
    /// Largest (= last) doc id in the block.
    pub last_doc: u32,
    /// Largest quantized impact in the block.
    pub max_impact: u8,
}

/// Deterministic work counters for one (or an accumulation of) retrieval
/// calls. Counting model: a posting is **touched** when it is
/// accumulated into a candidate score (the only per-posting work the
/// merge does); postings passed over by block jumps, in-block seeks, or
/// never reached before termination are **skipped**. The seed counting
/// OR-merge touches every posting of every queried bucket, so
/// `skipped_fraction()` is exactly the work the pruning saved.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetrievalCounters {
    /// Postings accumulated into candidate scores.
    pub postings_touched: u64,
    /// Total postings in the queried buckets (the no-pruning cost).
    pub postings_total: u64,
    /// Whole blocks bypassed via block metadata.
    pub blocks_skipped: u64,
    /// Total blocks in the queried buckets.
    pub blocks_total: u64,
    /// Documents fully scored (candidates offered to the heap).
    pub candidates_emitted: u64,
}

impl RetrievalCounters {
    /// Accumulate another call's counters into this one.
    pub fn merge(&mut self, o: &RetrievalCounters) {
        self.postings_touched += o.postings_touched;
        self.postings_total += o.postings_total;
        self.blocks_skipped += o.blocks_skipped;
        self.blocks_total += o.blocks_total;
        self.candidates_emitted += o.candidates_emitted;
    }

    /// Fraction of queried postings never accumulated (0 when no
    /// postings were queried). The CI perf gate holds the line on this.
    pub fn skipped_fraction(&self) -> f64 {
        if self.postings_total == 0 {
            0.0
        } else {
            1.0 - self.postings_touched as f64 / self.postings_total as f64
        }
    }
}

/// One term's read position inside the arena. Plain indices (no borrows)
/// so cursors can live in the reusable scratch.
#[derive(Debug, Clone, Copy)]
struct Cursor {
    /// Arena index of the list's first posting.
    start: u32,
    /// Posting count of the list.
    len: u32,
    /// Current position, relative to `start`.
    pos: u32,
    /// Index of the list's first block in `blocks`.
    block0: u32,
    /// List-level upper bound: TERM_UNIT + max impact over the list.
    ub: u32,
}

/// Reusable per-query retrieval state. Owning one of these (per thread)
/// makes `retrieve_into` allocation-free in steady state.
#[derive(Debug, Default)]
pub struct RetrievalScratch {
    /// Dedup buffer for query buckets.
    uniq: Vec<u32>,
    /// WAND term cursors for the current query.
    cursors: Vec<Cursor>,
    /// Bounded selection heap; `Reverse` makes the std max-heap a
    /// min-heap whose root is the worst candidate currently kept.
    heap: BinaryHeap<Reverse<(u32, Reverse<u32>)>>,
    /// Result buffer: (local_id, retrieval score), best first.
    out: Vec<(u32, u32)>,
    /// Work counters of the last `retrieve_into` call.
    counters: RetrievalCounters,
}

impl RetrievalScratch {
    pub fn new() -> RetrievalScratch {
        RetrievalScratch::default()
    }

    /// Hits produced by the last `retrieve_into` call.
    pub fn hits(&self) -> &[(u32, u32)] {
        &self.out
    }

    /// Take ownership of the last result (used by the one-shot wrapper).
    pub fn take_hits(&mut self) -> Vec<(u32, u32)> {
        std::mem::take(&mut self.out)
    }

    /// Work counters of the last `retrieve_into` call.
    pub fn counters(&self) -> &RetrievalCounters {
        &self.counters
    }
}

/// Immutable inverted index for one shard (layout in the module docs).
#[derive(Debug, Clone)]
pub struct InvertedIndex {
    offsets: Vec<u32>,
    docs: Vec<u32>,
    impacts: Vec<u8>,
    block_offsets: Vec<u32>,
    blocks: Vec<BlockMeta>,
    num_docs: u32,
    block_size: u32,
}

/// Borrowed view of the raw CSR arena, in the on-disk layout order
/// (see the module docs). Consumed by the snapshot codec and by the
/// byte-identity round-trip tests.
#[derive(Debug, Clone, Copy)]
pub struct ArenaView<'a> {
    pub offsets: &'a [u32],
    pub docs: &'a [u32],
    pub impacts: &'a [u8],
    pub block_offsets: &'a [u32],
    pub blocks: &'a [BlockMeta],
    pub num_docs: u32,
    pub block_size: u32,
}

impl InvertedIndex {
    /// Build from analyzed docs with the default [`BLOCK_SIZE`].
    pub fn build(docs: &[ShardDoc], features: usize) -> InvertedIndex {
        InvertedIndex::build_with_block_size(docs, features, BLOCK_SIZE)
    }

    /// Build with an explicit block size (tests sweep small sizes to
    /// exercise block boundaries; results must be identical across
    /// sizes). Three passes: count, prefix-sum, fill + accumulate
    /// impacts, then derive block metadata.
    pub fn build_with_block_size(
        docs: &[ShardDoc],
        features: usize,
        block_size: usize,
    ) -> InvertedIndex {
        assert!(block_size > 0, "block size must be positive");
        // Pass 1: posting count per bucket. `last[b]` is the last doc id
        // counted for bucket b — docs arrive in increasing local id, so
        // comparing against it dedups multi-field occurrences.
        let mut counts = vec![0u32; features];
        let mut last = vec![u32::MAX; features];
        for (local_id, doc) in docs.iter().enumerate() {
            let lid = local_id as u32;
            for (bucket, _) in doc.bucket_tf_iter() {
                let b = bucket as usize;
                if last[b] != lid {
                    last[b] = lid;
                    counts[b] += 1;
                }
            }
        }

        let mut offsets = vec![0u32; features + 1];
        for b in 0..features {
            offsets[b + 1] = offsets[b] + counts[b];
        }

        // Pass 2: fill doc ids through per-bucket write cursors and
        // accumulate the cross-field tf per posting (a bucket occurring
        // in several fields contributes the sum of its tfs). `slot[b]`
        // remembers where the current doc's posting went so later fields
        // accumulate instead of re-emitting.
        let n_postings = offsets[features] as usize;
        let mut ids = vec![0u32; n_postings];
        let mut tf_acc = vec![0f32; n_postings];
        let mut cursor: Vec<u32> = offsets[..features].to_vec();
        let mut slot = vec![0u32; features];
        last.fill(u32::MAX);
        for (local_id, doc) in docs.iter().enumerate() {
            let lid = local_id as u32;
            for (bucket, tf) in doc.bucket_tf_iter() {
                let b = bucket as usize;
                if last[b] != lid {
                    last[b] = lid;
                    slot[b] = cursor[b];
                    ids[cursor[b] as usize] = lid;
                    tf_acc[cursor[b] as usize] = tf;
                    cursor[b] += 1;
                } else {
                    tf_acc[slot[b] as usize] += tf;
                }
            }
        }
        let impacts: Vec<u8> = tf_acc.into_iter().map(quantize_impact).collect();

        // Block metadata: per bucket, chunk its run into block_size
        // pieces and record (last doc id, max impact) of each.
        let mut block_offsets = vec![0u32; features + 1];
        let mut blocks: Vec<BlockMeta> = Vec::new();
        for b in 0..features {
            let (lo, hi) = (offsets[b] as usize, offsets[b + 1] as usize);
            for chunk_lo in (lo..hi).step_by(block_size) {
                let chunk_hi = (chunk_lo + block_size).min(hi);
                let max_impact =
                    impacts[chunk_lo..chunk_hi].iter().copied().max().unwrap_or(0);
                blocks.push(BlockMeta { last_doc: ids[chunk_hi - 1], max_impact });
            }
            block_offsets[b + 1] = blocks.len() as u32;
        }

        InvertedIndex {
            offsets,
            docs: ids,
            impacts,
            block_offsets,
            blocks,
            num_docs: docs.len() as u32,
            block_size: block_size as u32,
        }
    }

    /// Raw arena view for serialization (and byte-identity assertions).
    pub fn raw_parts(&self) -> ArenaView<'_> {
        ArenaView {
            offsets: &self.offsets,
            docs: &self.docs,
            impacts: &self.impacts,
            block_offsets: &self.block_offsets,
            blocks: &self.blocks,
            num_docs: self.num_docs,
            block_size: self.block_size,
        }
    }

    /// Reassemble an index from raw arena arrays (the snapshot load
    /// path). Every structural invariant the retrieval code relies on is
    /// re-validated — a decoded-but-inconsistent arena (e.g. a snapshot
    /// from a buggy writer) is rejected with a description instead of
    /// producing out-of-bounds panics at query time.
    pub fn from_raw_parts(
        offsets: Vec<u32>,
        docs: Vec<u32>,
        impacts: Vec<u8>,
        block_offsets: Vec<u32>,
        blocks: Vec<BlockMeta>,
        num_docs: u32,
        block_size: u32,
    ) -> Result<InvertedIndex, String> {
        if block_size == 0 {
            return Err("block_size must be positive".into());
        }
        if offsets.is_empty() || block_offsets.len() != offsets.len() {
            return Err(format!(
                "offset arrays inconsistent: {} offsets vs {} block offsets",
                offsets.len(),
                block_offsets.len()
            ));
        }
        if offsets[0] != 0 || block_offsets[0] != 0 {
            return Err("offset arrays must start at 0".into());
        }
        if !offsets.windows(2).all(|w| w[0] <= w[1])
            || !block_offsets.windows(2).all(|w| w[0] <= w[1])
        {
            return Err("offset arrays must be monotone".into());
        }
        let n_postings = *offsets.last().expect("nonempty") as usize;
        if docs.len() != n_postings || impacts.len() != n_postings {
            return Err(format!(
                "posting arrays inconsistent: {} offsets-end vs {} docs vs {} impacts",
                n_postings,
                docs.len(),
                impacts.len()
            ));
        }
        if *block_offsets.last().expect("nonempty") as usize != blocks.len() {
            return Err(format!(
                "block arrays inconsistent: {} block-offsets-end vs {} blocks",
                block_offsets.last().unwrap(),
                blocks.len()
            ));
        }
        let bs = block_size as usize;
        let features = offsets.len() - 1;
        for b in 0..features {
            let (lo, hi) = (offsets[b] as usize, offsets[b + 1] as usize);
            let len = hi - lo;
            let nblocks = (block_offsets[b + 1] - block_offsets[b]) as usize;
            if nblocks != len.div_ceil(bs) {
                return Err(format!(
                    "bucket {b}: {len} postings need {} blocks, found {nblocks}",
                    len.div_ceil(bs)
                ));
            }
            let run = &docs[lo..hi];
            if !run.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("bucket {b}: doc ids not strictly increasing"));
            }
            if run.last().is_some_and(|&d| d >= num_docs) {
                return Err(format!("bucket {b}: doc id out of range"));
            }
            // Block metadata must describe the postings it covers — the
            // seek path trusts `last_doc` to skip entire blocks.
            let block0 = block_offsets[b] as usize;
            for (i, chunk_lo) in (lo..hi).step_by(bs).enumerate() {
                let chunk_hi = (chunk_lo + bs).min(hi);
                let meta = blocks[block0 + i];
                if meta.last_doc != docs[chunk_hi - 1] {
                    return Err(format!("bucket {b} block {i}: last_doc mismatch"));
                }
                let max = impacts[chunk_lo..chunk_hi].iter().copied().max().unwrap_or(0);
                if meta.max_impact != max {
                    return Err(format!("bucket {b} block {i}: max_impact mismatch"));
                }
            }
        }
        Ok(InvertedIndex { offsets, docs, impacts, block_offsets, blocks, num_docs, block_size })
    }

    /// Posting doc ids for a bucket (empty slice if absent).
    pub fn postings(&self, bucket: u32) -> &[u32] {
        let b = bucket as usize;
        if b + 1 >= self.offsets.len() {
            return &[];
        }
        &self.docs[self.offsets[b] as usize..self.offsets[b + 1] as usize]
    }

    /// Quantized impacts for a bucket, parallel to [`postings`](Self::postings).
    pub fn impacts(&self, bucket: u32) -> &[u8] {
        let b = bucket as usize;
        if b + 1 >= self.offsets.len() {
            return &[];
        }
        &self.impacts[self.offsets[b] as usize..self.offsets[b + 1] as usize]
    }

    /// Block metadata for a bucket's posting list.
    pub fn block_meta(&self, bucket: u32) -> &[BlockMeta] {
        let b = bucket as usize;
        if b + 1 >= self.block_offsets.len() {
            return &[];
        }
        &self.blocks[self.block_offsets[b] as usize..self.block_offsets[b + 1] as usize]
    }

    /// Total number of postings (index size metric).
    pub fn num_postings(&self) -> usize {
        self.docs.len()
    }

    /// Total number of posting blocks.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Documents covered by this index.
    pub fn num_docs(&self) -> usize {
        self.num_docs as usize
    }

    /// Postings per block this index was built with.
    pub fn block_size(&self) -> usize {
        self.block_size as usize
    }

    #[inline]
    fn cur_doc(&self, c: &Cursor) -> u32 {
        self.docs[(c.start + c.pos) as usize]
    }

    #[inline]
    fn cur_impact(&self, c: &Cursor) -> u8 {
        self.impacts[(c.start + c.pos) as usize]
    }

    /// Block metadata covering cursor `c`'s current position.
    #[inline]
    fn cur_block(&self, c: &Cursor) -> BlockMeta {
        self.blocks[(c.block0 + c.pos / self.block_size) as usize]
    }

    /// Advance `c` to the first position whose doc id >= `target`,
    /// skipping whole blocks via their `last_doc` and binary-searching
    /// only inside the final block. Postings passed over are *not*
    /// counted as touched (they were never accumulated).
    fn seek(&self, c: &mut Cursor, target: u32, counters: &mut RetrievalCounters) {
        if c.pos >= c.len || self.cur_doc(c) >= target {
            return;
        }
        let bs = self.block_size;
        let mut blk = c.pos / bs;
        let nblocks = c.len.div_ceil(bs);
        while blk < nblocks && self.blocks[(c.block0 + blk) as usize].last_doc < target {
            counters.blocks_skipped += 1;
            blk += 1;
            c.pos = blk * bs;
        }
        if c.pos >= c.len {
            c.pos = c.len;
            return;
        }
        let block_end = ((blk + 1) * bs).min(c.len);
        let lo = (c.start + c.pos) as usize;
        let hi = (c.start + block_end) as usize;
        c.pos += self.docs[lo..hi].partition_point(|&d| d < target) as u32;
    }

    /// OR-retrieve the top `max_candidates` candidates for the query
    /// buckets into `scratch`: `scratch.hits()` holds (local_id,
    /// retrieval score) sorted by score descending then local id —
    /// bit-identical to [`retrieve_reference`](Self::retrieve_reference)
    /// — and `scratch.counters()` reports the work skipped. Block-max
    /// WAND: allocation-free once the scratch has warmed up.
    pub fn retrieve_into(
        &self,
        buckets: &[u32],
        max_candidates: usize,
        scratch: &mut RetrievalScratch,
    ) {
        scratch.out.clear();
        scratch.counters = RetrievalCounters::default();
        if max_candidates == 0 {
            return;
        }
        let k = max_candidates;

        // Dedup buckets so a repeated query term doesn't double-count.
        scratch.uniq.clear();
        scratch.uniq.extend_from_slice(buckets);
        scratch.uniq.sort_unstable();
        scratch.uniq.dedup();

        scratch.cursors.clear();
        for &b in &scratch.uniq {
            let bu = b as usize;
            if bu + 1 >= self.offsets.len() {
                continue;
            }
            let (lo, hi) = (self.offsets[bu], self.offsets[bu + 1]);
            if lo == hi {
                continue;
            }
            let block0 = self.block_offsets[bu];
            let nblocks = self.block_offsets[bu + 1] - block0;
            let list_max = self.blocks[block0 as usize..(block0 + nblocks) as usize]
                .iter()
                .map(|m| m.max_impact)
                .max()
                .unwrap_or(0);
            scratch.cursors.push(Cursor {
                start: lo,
                len: hi - lo,
                pos: 0,
                block0,
                ub: TERM_UNIT + list_max as u32,
            });
            scratch.counters.postings_total += (hi - lo) as u64;
            scratch.counters.blocks_total += nblocks as u64;
        }

        scratch.heap.clear();
        let RetrievalScratch { cursors, heap, counters, out, .. } = scratch;

        loop {
            cursors.retain(|c| c.pos < c.len);
            if cursors.is_empty() {
                break;
            }
            // Keep cursors sorted by current doc id. Lists are short-ish
            // in number (one per distinct query term); insertion sort on
            // a mostly-sorted vec beats a heap here.
            cursors.sort_unstable_by_key(|c| self.cur_doc(c));

            // Heap threshold: score of the worst kept candidate once the
            // heap is full. Skips must be strict (ub < theta): a
            // candidate *tying* theta can still win its id tie-break.
            let theta: u32 = if heap.len() == k {
                heap.peek().expect("heap full").0 .0
            } else {
                0
            };

            // Pivot: first cursor where the cumulative list upper bound
            // could reach theta. No pivot => no remaining doc can place.
            let mut acc = 0u64;
            let mut pivot = None;
            for (i, c) in cursors.iter().enumerate() {
                acc += c.ub as u64;
                if acc >= theta as u64 {
                    pivot = Some(i);
                    break;
                }
            }
            let Some(pivot) = pivot else { break };
            let pivot_doc = self.cur_doc(&cursors[pivot]);

            if self.cur_doc(&cursors[0]) == pivot_doc {
                // Cursors are sorted, so cursors[0..=pivot] all sit on
                // pivot_doc; later cursors may too — extend the group.
                let mut p_end = pivot;
                while p_end + 1 < cursors.len()
                    && self.cur_doc(&cursors[p_end + 1]) == pivot_doc
                {
                    p_end += 1;
                }

                // Block-max refinement: tighter bound from the blocks
                // actually containing pivot_doc.
                let mut block_ub = 0u32;
                let mut min_boundary = u32::MAX;
                for c in &cursors[..=p_end] {
                    let m = self.cur_block(c);
                    block_ub += TERM_UNIT + m.max_impact as u32;
                    min_boundary = min_boundary.min(m.last_doc);
                }
                if block_ub < theta {
                    // No doc in [pivot_doc, jump) can beat theta: the
                    // range is covered by these same blocks, and every
                    // other list starts at or beyond `jump`.
                    let mut jump = min_boundary.saturating_add(1);
                    if p_end + 1 < cursors.len() {
                        jump = jump.min(self.cur_doc(&cursors[p_end + 1]));
                    }
                    let jump = jump.max(pivot_doc.saturating_add(1));
                    for c in cursors[..=p_end].iter_mut() {
                        self.seek(c, jump, counters);
                    }
                } else {
                    // Score pivot_doc exactly.
                    let mut score = 0u32;
                    for c in cursors[..=p_end].iter_mut() {
                        score += TERM_UNIT + self.cur_impact(c) as u32;
                        c.pos += 1;
                        counters.postings_touched += 1;
                    }
                    counters.candidates_emitted += 1;
                    let key = Reverse((score, Reverse(pivot_doc)));
                    if heap.len() < k {
                        heap.push(key);
                    } else if key < *heap.peek().expect("heap nonempty") {
                        // Better than the worst kept (Reverse flips).
                        heap.pop();
                        heap.push(key);
                    }
                }
            } else {
                // Docs before the pivot cannot reach theta: jump every
                // earlier cursor forward to the pivot doc.
                for c in cursors[..pivot].iter_mut() {
                    self.seek(c, pivot_doc, counters);
                }
            }
        }

        out.extend(heap.drain().map(|Reverse((s, Reverse(d)))| (d, s)));
        out.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    }

    /// One-shot OR-retrieve (allocates a fresh scratch; hot paths hold a
    /// [`RetrievalScratch`] and call [`InvertedIndex::retrieve_into`]).
    pub fn retrieve(&self, buckets: &[u32], max_candidates: usize) -> Vec<(u32, u32)> {
        let mut scratch = RetrievalScratch::new();
        self.retrieve_into(buckets, max_candidates, &mut scratch);
        scratch.take_hits()
    }

    /// Naive reference OR-retrieve: per-query `HashMap` accumulation of
    /// the same stored impacts + full sort. Kept as the differential
    /// oracle (`tests/prop_invariants.rs`) and the micro-benchmark
    /// baseline — result semantics of the block-max path must match this
    /// exactly.
    pub fn retrieve_reference(&self, buckets: &[u32], max_candidates: usize) -> Vec<(u32, u32)> {
        let mut scores: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
        let mut uniq: Vec<u32> = buckets.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        for b in uniq {
            for (&doc, &imp) in self.postings(b).iter().zip(self.impacts(b)) {
                *scores.entry(doc).or_insert(0) += TERM_UNIT + imp as u32;
            }
        }
        let mut out: Vec<(u32, u32)> = scores.into_iter().collect();
        out.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        out.truncate(max_candidates);
        out
    }

    /// AND-retrieve: up to `limit` docs containing *all* buckets (used
    /// by the multivariate field filters), in increasing local id.
    /// Leapfrog intersection seeded from the shortest posting list; the
    /// per-list seeks skip whole blocks via their `last_doc` metadata.
    /// The explicit `limit` caps the result allocation — a huge shard
    /// cannot make a term-free conjunction balloon the candidate buffer.
    pub fn retrieve_all(&self, buckets: &[u32], limit: usize) -> Vec<u32> {
        let mut counters = RetrievalCounters::default();
        self.retrieve_all_counted(buckets, limit, &mut counters)
    }

    /// [`retrieve_all`](Self::retrieve_all), reporting work counters.
    pub fn retrieve_all_counted(
        &self,
        buckets: &[u32],
        limit: usize,
        counters: &mut RetrievalCounters,
    ) -> Vec<u32> {
        *counters = RetrievalCounters::default();
        if buckets.is_empty() || limit == 0 {
            return Vec::new();
        }
        let mut uniq: Vec<u32> = buckets.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        // Drive from the shortest posting list.
        uniq.sort_by_key(|b| self.postings(*b).len());

        let mut cursors: Vec<Cursor> = Vec::with_capacity(uniq.len());
        for &b in &uniq {
            let bu = b as usize;
            if bu + 1 >= self.offsets.len() || self.offsets[bu] == self.offsets[bu + 1] {
                return Vec::new(); // empty list => empty intersection
            }
            let (lo, hi) = (self.offsets[bu], self.offsets[bu + 1]);
            cursors.push(Cursor {
                start: lo,
                len: hi - lo,
                pos: 0,
                block0: self.block_offsets[bu],
                ub: 0,
            });
            counters.postings_total += (hi - lo) as u64;
            counters.blocks_total +=
                (self.block_offsets[bu + 1] - self.block_offsets[bu]) as u64;
            // Every cursor's initial head gets examined.
            counters.postings_touched += 1;
        }

        let mut out = Vec::new();
        let mut target = self.cur_doc(&cursors[0]);
        'outer: loop {
            let mut agreed = true;
            for c in cursors.iter_mut() {
                let before = c.pos;
                self.seek(c, target, counters);
                if c.pos >= c.len {
                    break 'outer;
                }
                // A position is examined once, when first landed on.
                if c.pos != before {
                    counters.postings_touched += 1;
                }
                let d = self.cur_doc(c);
                if d > target {
                    target = d;
                    agreed = false;
                    break;
                }
            }
            if agreed {
                out.push(target);
                counters.candidates_emitted += 1;
                if out.len() >= limit {
                    break;
                }
                match target.checked_add(1) {
                    Some(t) => target = t,
                    None => break,
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::NUM_FIELDS;

    /// Build a ShardDoc from (bucket, tf) pairs in field 0.
    fn doc(global_id: u64, buckets: &[(u32, f32)]) -> ShardDoc {
        let mut field_tf: [Vec<(u32, f32)>; NUM_FIELDS] = Default::default();
        field_tf[0] = buckets.to_vec();
        let len: f32 = buckets.iter().map(|&(_, tf)| tf).sum();
        ShardDoc { global_id, field_tf, field_len: [len, 0.0, 0.0, 0.0] }
    }

    fn doc1(global_id: u64, buckets: &[u32]) -> ShardDoc {
        let pairs: Vec<(u32, f32)> = buckets.iter().map(|&b| (b, 1.0)).collect();
        doc(global_id, &pairs)
    }

    fn index() -> InvertedIndex {
        InvertedIndex::build(
            &[
                doc1(0, &[1, 2, 3]),
                doc1(1, &[2, 3]),
                doc1(2, &[3]),
                doc1(3, &[4]),
            ],
            8,
        )
    }

    const U: u32 = TERM_UNIT + 1; // unit-tf per-term score

    #[test]
    fn postings_sorted_and_correct() {
        let ix = index();
        assert_eq!(ix.postings(1), &[0]);
        assert_eq!(ix.postings(2), &[0, 1]);
        assert_eq!(ix.postings(3), &[0, 1, 2]);
        assert_eq!(ix.postings(7), &[] as &[u32]);
        assert_eq!(ix.impacts(3), &[1, 1, 1]);
        assert_eq!(ix.num_postings(), 7);
        assert_eq!(ix.num_docs(), 4);
    }

    #[test]
    fn block_meta_tracks_last_doc_and_max_impact() {
        let docs: Vec<ShardDoc> = (0..10)
            .map(|i| doc(i as u64, &[(0, (i + 1) as f32)]))
            .collect();
        let ix = InvertedIndex::build_with_block_size(&docs, 2, 4);
        let blocks = ix.block_meta(0);
        assert_eq!(blocks.len(), 3); // 10 postings / block size 4
        assert_eq!(blocks[0], BlockMeta { last_doc: 3, max_impact: 4 });
        assert_eq!(blocks[1], BlockMeta { last_doc: 7, max_impact: 8 });
        assert_eq!(blocks[2], BlockMeta { last_doc: 9, max_impact: 10 });
        assert_eq!(ix.num_blocks(), 3);
        assert_eq!(ix.block_size(), 4);
    }

    #[test]
    fn or_retrieval_orders_by_match_count_then_impact() {
        let ix = index();
        let got = ix.retrieve(&[1, 2, 3], 10);
        assert_eq!(got, vec![(0, 3 * U), (1, 2 * U), (2, U)]);
    }

    #[test]
    fn impact_breaks_ties_within_match_count() {
        // Same distinct-match count, different tf: heavier doc first.
        let ix = InvertedIndex::build(
            &[doc(0, &[(1, 1.0)]), doc(1, &[(1, 5.0)])],
            4,
        );
        let got = ix.retrieve(&[1], 10);
        assert_eq!(got, vec![(1, TERM_UNIT + 5), (0, TERM_UNIT + 1)]);
        // But any extra distinct match still dominates any tf.
        let ix2 = InvertedIndex::build(
            &[doc(0, &[(1, 200.0)]), doc(1, &[(1, 1.0), (2, 1.0)])],
            4,
        );
        let got2 = ix2.retrieve(&[1, 2], 10);
        assert_eq!(got2[0].0, 1, "two distinct matches beat one heavy match");
    }

    #[test]
    fn or_retrieval_truncates() {
        let ix = index();
        let got = ix.retrieve(&[3], 2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 0);
    }

    #[test]
    fn duplicate_query_buckets_count_once() {
        let ix = index();
        let got = ix.retrieve(&[2, 2, 2], 10);
        assert_eq!(got, vec![(0, U), (1, U)]);
    }

    #[test]
    fn and_retrieval_intersects_with_limit() {
        let ix = index();
        assert_eq!(ix.retrieve_all(&[2, 3], 100), vec![0, 1]);
        assert_eq!(ix.retrieve_all(&[2, 3], 1), vec![0]);
        assert_eq!(ix.retrieve_all(&[1, 4], 100), Vec::<u32>::new());
        assert_eq!(ix.retrieve_all(&[], 100), Vec::<u32>::new());
        assert_eq!(ix.retrieve_all(&[2, 3], 0), Vec::<u32>::new());
    }

    #[test]
    fn multifield_doc_accumulates_impact_across_fields() {
        let mut field_tf: [Vec<(u32, f32)>; NUM_FIELDS] = Default::default();
        field_tf[0] = vec![(5, 1.0)];
        field_tf[1] = vec![(5, 3.0)];
        let d = ShardDoc { global_id: 0, field_tf, field_len: [1.0, 3.0, 0.0, 0.0] };
        let ix = InvertedIndex::build(&[d], 8);
        assert_eq!(ix.postings(5), &[0]);
        assert_eq!(ix.impacts(5), &[4], "impact sums tf across fields");
    }

    #[test]
    fn impact_quantization_saturates() {
        assert_eq!(quantize_impact(0.0), 1);
        assert_eq!(quantize_impact(1.0), 1);
        assert_eq!(quantize_impact(2.4), 2);
        assert_eq!(quantize_impact(255.0), 255);
        assert_eq!(quantize_impact(1e9), 255);
        let ix = InvertedIndex::build(&[doc(0, &[(1, 1e6)])], 4);
        assert_eq!(ix.impacts(1), &[255]);
    }

    #[test]
    fn out_of_range_bucket_is_empty() {
        let ix = index();
        assert_eq!(ix.postings(100), &[] as &[u32]);
        assert!(ix.retrieve(&[100], 5).is_empty());
    }

    #[test]
    fn scratch_reuse_is_clean_across_queries() {
        let ix = index();
        let mut scratch = RetrievalScratch::new();
        ix.retrieve_into(&[1, 2, 3], 10, &mut scratch);
        assert_eq!(scratch.hits(), &[(0, 3 * U), (1, 2 * U), (2, U)]);
        // A second, disjoint query must not see state from the first.
        ix.retrieve_into(&[4], 10, &mut scratch);
        assert_eq!(scratch.hits(), &[(3, U)]);
        ix.retrieve_into(&[100], 10, &mut scratch);
        assert!(scratch.hits().is_empty());
    }

    #[test]
    fn wand_selection_matches_reference() {
        // Enough docs that truncation and pruning paths both run, with
        // varied tf so impacts differ.
        let docs: Vec<ShardDoc> = (0..200)
            .map(|i| {
                let pairs: Vec<(u32, f32)> = (0..8u32)
                    .filter(|b| (i + *b as usize) % 3 != 0)
                    .map(|b| (b, 1.0 + (i % 5) as f32))
                    .collect();
                doc(i as u64, &pairs)
            })
            .collect();
        for bs in [2usize, 7, 64, BLOCK_SIZE] {
            let ix = InvertedIndex::build_with_block_size(&docs, 8, bs);
            let query = [0u32, 1, 2, 3, 4, 5, 6, 7];
            for k in [1usize, 3, 10, 50, 199, 200, 500] {
                assert_eq!(
                    ix.retrieve(&query, k),
                    ix.retrieve_reference(&query, k),
                    "bs={bs} k={k}"
                );
            }
        }
    }

    #[test]
    fn counters_account_for_all_postings() {
        let docs: Vec<ShardDoc> = (0..300)
            .map(|i| {
                let mut pairs = vec![(0u32, 1.0f32)];
                if i % 3 == 0 {
                    pairs.push((1, 2.0));
                }
                if i % 11 == 0 {
                    pairs.push((2, 1.0));
                }
                doc(i as u64, &pairs)
            })
            .collect();
        let ix = InvertedIndex::build_with_block_size(&docs, 4, 16);
        let mut scratch = RetrievalScratch::new();
        ix.retrieve_into(&[0, 1, 2], 8, &mut scratch);
        let c = scratch.counters();
        assert_eq!(c.postings_total, ix.num_postings() as u64);
        assert_eq!(c.blocks_total, ix.num_blocks() as u64);
        assert!(c.postings_touched <= c.postings_total);
        assert!(c.candidates_emitted >= scratch.hits().len() as u64);
        // With k=8 over 300 matching docs the threshold must have pruned.
        assert!(
            c.postings_touched < c.postings_total,
            "no pruning happened: {c:?}"
        );
        assert!(c.skipped_fraction() > 0.0);
    }

    #[test]
    fn counters_merge_accumulates() {
        let mut a = RetrievalCounters {
            postings_touched: 10,
            postings_total: 100,
            blocks_skipped: 2,
            blocks_total: 8,
            candidates_emitted: 5,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.postings_total, 200);
        assert_eq!(a.postings_touched, 20);
        assert_eq!(a.blocks_skipped, 4);
        assert!((a.skipped_fraction() - 0.9).abs() < 1e-12);
        assert_eq!(RetrievalCounters::default().skipped_fraction(), 0.0);
    }

    #[test]
    fn and_retrieval_skips_blocks() {
        // List A dense, B hits every 50th doc: seeking A to B's docs
        // must bypass whole blocks.
        let docs: Vec<ShardDoc> = (0..2000)
            .map(|i| {
                let mut pairs = vec![(0u32, 1.0f32)];
                if i % 50 == 0 {
                    pairs.push((1, 1.0));
                }
                doc(i as u64, &pairs)
            })
            .collect();
        let ix = InvertedIndex::build_with_block_size(&docs, 4, 16);
        let mut counters = RetrievalCounters::default();
        let got = ix.retrieve_all_counted(&[0, 1], 1000, &mut counters);
        let expect: Vec<u32> = (0..2000u32).filter(|i| i % 50 == 0).collect();
        assert_eq!(got, expect);
        assert!(counters.blocks_skipped > 0, "{counters:?}");
        assert!(counters.postings_touched < counters.postings_total);
    }

    #[test]
    fn galloping_intersection_matches_linear() {
        // Structured gaps exercise the block skipping: list A is dense,
        // list B hits every 7th element, C every 13th.
        let docs: Vec<ShardDoc> = (0..500)
            .map(|i| {
                let mut b = vec![0u32];
                if i % 7 == 0 {
                    b.push(1);
                }
                if i % 13 == 0 {
                    b.push(2);
                }
                doc1(i as u64, &b)
            })
            .collect();
        for bs in [3usize, 32, BLOCK_SIZE] {
            let ix = InvertedIndex::build_with_block_size(&docs, 4, bs);
            let expect: Vec<u32> =
                (0..500u32).filter(|i| i % 7 == 0 && i % 13 == 0).collect();
            assert_eq!(ix.retrieve_all(&[0, 1, 2], 500), expect, "bs={bs}");
            assert_eq!(ix.retrieve_all(&[2, 1, 0], 500), expect, "order-independent");
        }
    }

    #[test]
    fn raw_parts_round_trip_and_validation() {
        let ix = index();
        let v = ix.raw_parts();
        let rebuilt = InvertedIndex::from_raw_parts(
            v.offsets.to_vec(),
            v.docs.to_vec(),
            v.impacts.to_vec(),
            v.block_offsets.to_vec(),
            v.blocks.to_vec(),
            v.num_docs,
            v.block_size,
        )
        .expect("identical arena must validate");
        assert_eq!(rebuilt.retrieve(&[1, 2, 3], 10), ix.retrieve(&[1, 2, 3], 10));
        assert_eq!(rebuilt.raw_parts().docs, ix.raw_parts().docs);

        // Inconsistent arenas are rejected, not panicked on.
        let bad = InvertedIndex::from_raw_parts(
            v.offsets.to_vec(),
            vec![],
            vec![],
            v.block_offsets.to_vec(),
            v.blocks.to_vec(),
            v.num_docs,
            v.block_size,
        );
        assert!(bad.is_err());
        let mut docs = v.docs.to_vec();
        docs.swap(1, 2); // break per-bucket ordering
        let bad2 = InvertedIndex::from_raw_parts(
            v.offsets.to_vec(),
            docs,
            v.impacts.to_vec(),
            v.block_offsets.to_vec(),
            v.blocks.to_vec(),
            v.num_docs,
            v.block_size,
        );
        assert!(bad2.is_err());
        assert!(InvertedIndex::from_raw_parts(vec![0], vec![], vec![], vec![0], vec![], 0, 0)
            .is_err());
    }

    #[test]
    fn results_identical_across_block_sizes() {
        let docs: Vec<ShardDoc> = (0..150)
            .map(|i| {
                let pairs: Vec<(u32, f32)> = (0..6u32)
                    .filter(|b| (i * 7 + *b as usize) % 4 != 0)
                    .map(|b| (b, 1.0 + (i % 3) as f32))
                    .collect();
                doc(i as u64, &pairs)
            })
            .collect();
        let reference = InvertedIndex::build_with_block_size(&docs, 8, 1)
            .retrieve(&[0, 1, 2, 3, 4, 5], 20);
        for bs in [2usize, 5, 33, 128, 4096] {
            let ix = InvertedIndex::build_with_block_size(&docs, 8, bs);
            assert_eq!(ix.retrieve(&[0, 1, 2, 3, 4, 5], 20), reference, "bs={bs}");
        }
    }
}
