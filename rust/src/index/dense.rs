//! Dense packing: candidates -> `[NF, D, F]` tiles + query vectors.
//!
//! This is the rust side of the artifact ABI (python/compile/model.py):
//! row-major flattened `doc_tf [NF, D, F]`, `len_norm [NF, D]`,
//! `field_w [NF]`, `qw [Q, F]`. Packing is on the request hot path —
//! the §Perf pass optimizes the scatter loop here.

use super::store::{GlobalStats, Shard};
use crate::text::NUM_FIELDS;

/// A packed candidate block ready for the PJRT executor.
#[derive(Debug, Clone)]
pub struct PackedBlock {
    /// Flattened `[NF, D, F]` term counts (row-major).
    pub doc_tf: Vec<f32>,
    /// Flattened `[NF, D]` length normalisers.
    pub len_norm: Vec<f32>,
    /// Local shard ids of the real (non-padding) rows, in packed order.
    pub local_ids: Vec<u32>,
    /// Number of real rows (<= d).
    pub n_real: usize,
    /// Block doc capacity (the artifact D).
    pub d: usize,
    /// Feature dimension (the artifact F).
    pub f: usize,
}

/// Pack `candidates` (local shard ids) into one dense block of capacity
/// `d`. Rows beyond `candidates.len()` are zero (score exactly 0 in the
/// kernel). `b` is the BM25 length-normalisation constant; averages come
/// from corpus-global stats so scores merge consistently across shards.
pub fn pack_block(
    shard: &Shard,
    stats: &GlobalStats,
    candidates: &[u32],
    d: usize,
    b: f32,
) -> PackedBlock {
    assert!(candidates.len() <= d, "candidates {} exceed block capacity {d}", candidates.len());
    let f = shard.features;
    let mut doc_tf = vec![0.0f32; NUM_FIELDS * d * f];
    let mut len_norm = vec![0.0f32; NUM_FIELDS * d];

    for (row, &local_id) in candidates.iter().enumerate() {
        let doc = &shard.docs[local_id as usize];
        for (fi, tf) in doc.field_tf.iter().enumerate() {
            let base = fi * d * f + row * f;
            for &(bucket, count) in tf {
                doc_tf[base + bucket as usize] = count;
            }
            let avg = stats.avg_field_len[fi].max(1e-3);
            let ln = 1.0 / (1.0 - b + b * doc.field_len[fi] / avg);
            len_norm[fi * d + row] = ln;
        }
    }

    PackedBlock { doc_tf, len_norm, local_ids: candidates.to_vec(), n_real: candidates.len(), d, f }
}

/// Build the `[Q, F]` query-weight matrix: for each query, scatter
/// `idf(bucket) * query_tf(bucket)` into its row. Queries are lists of
/// feature buckets (already tokenized/hashed by the query parser).
pub fn build_query_weights(
    queries: &[Vec<u32>],
    stats: &GlobalStats,
    f: usize,
    q_capacity: usize,
) -> Vec<f32> {
    assert!(queries.len() <= q_capacity, "queries {} exceed artifact Q {q_capacity}", queries.len());
    let mut qw = vec![0.0f32; q_capacity * f];
    for (qi, buckets) in queries.iter().enumerate() {
        for &bucket in buckets {
            debug_assert!((bucket as usize) < f);
            qw[qi * f + bucket as usize] += stats.idf(bucket);
        }
    }
    qw
}

/// Reusable packer: same layout as [`pack_block`], but the block buffers
/// are reused across calls and cleared *sparsely* — instead of zeroing the
/// whole `[NF, D, F]` tile (8.4 MB at d=1024) per call, only the entries
/// written by the previous pack are reset. §Perf P2: candidate tiles are
/// ~1–5% dense, so this cuts the packer's memory traffic ~20x. A shape
/// change (different `d` or `f`, routine on the per-query exact-size
/// rust-scorer path) resizes the buffers in place — capacity is retained,
/// so steady state stays allocation-free even across varying candidate
/// counts.
#[derive(Debug, Default)]
pub struct Packer {
    block: Option<PackedBlock>,
    /// Flat doc_tf indices written by the previous pack.
    written: Vec<u32>,
}

impl Packer {
    pub fn new() -> Packer {
        Packer::default()
    }

    /// Pack candidates into the reused block (same semantics as
    /// [`pack_block`]).
    pub fn pack(
        &mut self,
        shard: &Shard,
        stats: &GlobalStats,
        candidates: &[u32],
        d: usize,
        b: f32,
    ) -> &PackedBlock {
        assert!(candidates.len() <= d, "candidates {} exceed block capacity {d}", candidates.len());
        let f = shard.features;
        if self.block.is_none() {
            self.block = Some(PackedBlock {
                doc_tf: Vec::new(),
                len_norm: Vec::new(),
                local_ids: Vec::new(),
                n_real: 0,
                d: 0,
                f: 0,
            });
            self.written.clear();
        }
        let block = self.block.as_mut().expect("block allocated");
        // Sparse clear of the previous pack's entries *at the previous
        // layout* — after this the buffer is all zeros, so resizing to a
        // new [NF, d, f] shape keeps the all-zero invariant (resize only
        // appends zeros or drops zeros; capacity is retained).
        for &idx in &self.written {
            block.doc_tf[idx as usize] = 0.0;
        }
        self.written.clear();
        if block.d != d || block.f != f {
            block.doc_tf.resize(NUM_FIELDS * d * f, 0.0);
            block.len_norm.resize(NUM_FIELDS * d, 0.0);
            block.d = d;
            block.f = f;
        }
        block.len_norm.iter_mut().for_each(|x| *x = 0.0); // small: NF*D

        for (row, &local_id) in candidates.iter().enumerate() {
            let doc = &shard.docs[local_id as usize];
            for (fi, tf) in doc.field_tf.iter().enumerate() {
                let base = fi * d * f + row * f;
                for &(bucket, count) in tf {
                    let idx = base + bucket as usize;
                    block.doc_tf[idx] = count;
                    self.written.push(idx as u32);
                }
                let avg = stats.avg_field_len[fi].max(1e-3);
                block.len_norm[fi * d + row] =
                    1.0 / (1.0 - b + b * doc.field_len[fi] / avg);
            }
        }
        block.local_ids.clear();
        block.local_ids.extend_from_slice(candidates);
        block.n_real = candidates.len();
        block
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusGenerator, CorpusSpec};
    use crate::index::store::{Shard, ShardStats};

    fn shard_and_stats(n: u64, features: usize) -> (Shard, GlobalStats) {
        let spec = CorpusSpec { num_docs: n, vocab_size: 500, ..CorpusSpec::default() };
        let gen = CorpusGenerator::new(spec);
        let shard = Shard::build(0, gen.generate_range(0, n), features);
        let mut acc = ShardStats::empty(features);
        acc.merge(&shard.stats);
        (shard, acc.finalize())
    }

    #[test]
    fn pack_shapes_and_padding() {
        let (shard, stats) = shard_and_stats(20, 128);
        let block = pack_block(&shard, &stats, &[0, 5, 7], 8, 0.75);
        assert_eq!(block.doc_tf.len(), NUM_FIELDS * 8 * 128);
        assert_eq!(block.len_norm.len(), NUM_FIELDS * 8);
        assert_eq!(block.n_real, 3);
        // Padding rows are all zero.
        for fi in 0..NUM_FIELDS {
            for row in 3..8 {
                let base = fi * 8 * 128 + row * 128;
                assert!(block.doc_tf[base..base + 128].iter().all(|&x| x == 0.0));
                assert_eq!(block.len_norm[fi * 8 + row], 0.0);
            }
        }
    }

    #[test]
    fn pack_scatters_tf_correctly() {
        let (shard, stats) = shard_and_stats(10, 128);
        let block = pack_block(&shard, &stats, &[2], 4, 0.75);
        let doc = &shard.docs[2];
        for (fi, tf) in doc.field_tf.iter().enumerate() {
            for &(bucket, count) in tf {
                let v = block.doc_tf[fi * 4 * 128 + bucket as usize];
                assert_eq!(v, count, "field {fi} bucket {bucket}");
            }
        }
    }

    #[test]
    fn len_norm_formula() {
        let (shard, stats) = shard_and_stats(10, 128);
        let b = 0.75f32;
        let block = pack_block(&shard, &stats, &[1], 2, b);
        let doc = &shard.docs[1];
        for fi in 0..NUM_FIELDS {
            let avg = stats.avg_field_len[fi].max(1e-3);
            let want = 1.0 / (1.0 - b + b * doc.field_len[fi] / avg);
            assert!((block.len_norm[fi * 2] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn average_length_doc_has_unit_norm() {
        let (shard, stats) = shard_and_stats(10, 128);
        // A doc whose field_len equals the average must get len_norm == 1.
        let b = 0.75f32;
        let block = pack_block(&shard, &stats, &[0], 1, b);
        let doc = &shard.docs[0];
        for fi in 0..NUM_FIELDS {
            if (doc.field_len[fi] - stats.avg_field_len[fi]).abs() < 1e-6 {
                assert!((block.len_norm[fi] - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    #[should_panic(expected = "exceed block capacity")]
    fn overflow_panics() {
        let (shard, stats) = shard_and_stats(10, 64);
        pack_block(&shard, &stats, &[0, 1, 2], 2, 0.75);
    }

    #[test]
    fn packer_matches_pack_block_across_reuse() {
        let (shard, stats) = shard_and_stats(30, 128);
        let mut packer = Packer::new();
        // Several packs with different candidate sets; each must equal the
        // from-scratch pack (i.e. stale entries fully cleared).
        let sets: [&[u32]; 4] = [&[0, 5, 7], &[1], &[2, 3, 4, 6, 8, 9], &[0]];
        for cands in sets {
            let reused = packer.pack(&shard, &stats, cands, 16, 0.75).clone();
            let fresh = pack_block(&shard, &stats, cands, 16, 0.75);
            assert_eq!(reused.doc_tf, fresh.doc_tf);
            assert_eq!(reused.len_norm, fresh.len_norm);
            assert_eq!(reused.local_ids, fresh.local_ids);
            assert_eq!(reused.n_real, fresh.n_real);
        }
    }

    #[test]
    fn packer_resizes_in_place_on_shape_change() {
        let (shard, stats) = shard_and_stats(10, 64);
        let mut packer = Packer::new();
        // Grow, shrink, regrow: every layout change must still match a
        // from-scratch pack (stale entries cleared at the *old* layout
        // before the buffer is reshaped).
        for (cands, d) in [
            (&[0u32, 1][..], 4usize),
            (&[0, 1, 2][..], 8),
            (&[3][..], 2),
            (&[0, 1, 2, 3][..], 8),
        ] {
            let reused = packer.pack(&shard, &stats, cands, d, 0.75).clone();
            assert_eq!(reused.d, d);
            let fresh = pack_block(&shard, &stats, cands, d, 0.75);
            assert_eq!(reused.doc_tf, fresh.doc_tf, "d={d}");
            assert_eq!(reused.len_norm, fresh.len_norm, "d={d}");
            assert_eq!(reused.local_ids, fresh.local_ids);
        }
    }

    #[test]
    fn query_weights_scatter_idf() {
        let (_, stats) = shard_and_stats(30, 64);
        let queries = vec![vec![3u32, 9], vec![3, 3]];
        let qw = build_query_weights(&queries, &stats, 64, 4);
        assert_eq!(qw.len(), 4 * 64);
        assert!((qw[3] - stats.idf(3)).abs() < 1e-6);
        assert!((qw[9] - stats.idf(9)).abs() < 1e-6);
        // Repeated term accumulates (qtf * idf).
        assert!((qw[64 + 3] - 2.0 * stats.idf(3)).abs() < 1e-6);
        // Unused query rows are zero.
        assert!(qw[2 * 64..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn block_scores_zero_query_overlap() {
        // A candidate with no query-term overlap gets doc_tf mass only in
        // non-query buckets; qw row dot that row must be 0 — verified at
        // the scorer level, here we just confirm disjoint support.
        let (shard, stats) = shard_and_stats(5, 64);
        let block = pack_block(&shard, &stats, &[0], 1, 0.75);
        let doc_buckets: std::collections::HashSet<u32> = shard.docs[0]
            .field_tf
            .iter()
            .flat_map(|tf| tf.iter().map(|(b, _)| *b))
            .collect();
        let free = (0..64u32).find(|b| !doc_buckets.contains(b));
        if let Some(fb) = free {
            let qw = build_query_weights(&[vec![fb]], &stats, 64, 1);
            let mut dot = 0.0f32;
            for fi in 0..NUM_FIELDS {
                for t in 0..64 {
                    dot += qw[t] * block.doc_tf[fi * 64 + t];
                }
            }
            assert_eq!(dot, 0.0);
        }
    }
}
