//! Indexing substrate: document store + shards, the impact-bearing
//! inverted index used for candidate retrieval, and the dense packer that
//! turns candidates into the `[NF, D, F]` tiles the AOT scoring artifacts
//! consume.
//!
//! Request-path split (mirrors a modern retrieve-then-rank engine, and the
//! paper's "local search service scans its local dataset"):
//!
//! 1. **retrieve** — block-max pruned inverted-index probe produces a
//!    pre-ranked candidate set of local ids (WAND over quantized
//!    impacts; see below);
//! 2. **rank** — candidates are packed into dense blocks and scored by the
//!    Layer-1/2 artifact through the PJRT runtime (or the pure-rust
//!    fallback scorer, used for the traditional baseline and tests).
//!
//! # Posting / block binary layout
//!
//! Each shard's [`InvertedIndex`] is four flat arrays (one allocation
//! each, CSR-style):
//!
//! ```text
//! offsets[features+1]: u32        per-bucket posting ranges
//! docs[P]:             u32        sorted local doc ids
//! impacts[P]:          u8         quantized impacts, parallel to docs
//! block_offsets[features+1]: u32  per-bucket block ranges
//! blocks[B]:           BlockMeta  { last_doc: u32, max_impact: u8 }
//! ```
//!
//! Posting `i` of bucket `b` lives at `docs[offsets[b] + i]` /
//! `impacts[offsets[b] + i]`; its block metadata at
//! `blocks[block_offsets[b] + i / BLOCK_SIZE]`. A block covers up to
//! [`BLOCK_SIZE`] postings: `last_doc` lets both the WAND OR path and the
//! AND intersection seek at block granularity, `max_impact` bounds the
//! block's best possible score contribution so whole blocks are skipped
//! when they cannot beat the current top-k threshold.
//!
//! # Impact quantization
//!
//! `impact = clamp(round(sum over fields of tf[field][bucket]), 1, 255)`
//! — monotone in total term frequency, saturating at 255
//! ([`quantize_impact`]). Retrieval scores are
//! `sum over matched terms (TERM_UNIT + impact)` with
//! [`TERM_UNIT`] `= 256 > 255`, so distinct-term match count strictly
//! dominates (the seed ordering is preserved) and impacts refine ties;
//! the same u8 impacts are available as inputs to a future SIMD/Pallas
//! scoring kernel. Work avoided by the pruning is reported through the
//! deterministic [`RetrievalCounters`], which CI gates on.

mod dense;
mod inverted;
mod store;

pub use dense::{build_query_weights, pack_block, PackedBlock, Packer};
pub use inverted::{
    quantize_impact, ArenaView, BlockMeta, InvertedIndex, RetrievalCounters, RetrievalScratch,
    BLOCK_SIZE, TERM_UNIT,
};
pub use store::{GlobalStats, Shard, ShardDoc, ShardStats};
