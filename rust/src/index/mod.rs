//! Indexing substrate: document store + shards, the inverted index used
//! for candidate retrieval, and the dense packer that turns candidates
//! into the `[NF, D, F]` tiles the AOT scoring artifacts consume.
//!
//! Request-path split (mirrors a modern retrieve-then-rank engine, and the
//! paper's "local search service scans its local dataset"):
//!
//! 1. **retrieve** — inverted-index probe produces candidate local ids;
//! 2. **rank** — candidates are packed into dense blocks and scored by the
//!    Layer-1/2 artifact through the PJRT runtime (or the pure-rust
//!    fallback scorer, used for the traditional baseline and tests).

mod dense;
mod inverted;
mod store;

pub use dense::{build_query_weights, pack_block, PackedBlock, Packer};
pub use inverted::{InvertedIndex, RetrievalScratch};
pub use store::{GlobalStats, Shard, ShardDoc, ShardStats};
