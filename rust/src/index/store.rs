//! Shard store: per-node document data in both raw (for result rendering /
//! filtering) and analyzed (hashed sparse term vectors) forms, plus the
//! corpus-level statistics BM25 needs. The analyzed docs feed the
//! impact-bearing inverted index (`index::inverted`): each posting's
//! quantized impact is derived from the cross-field tf sums exposed by
//! [`ShardDoc::bucket_tf_iter`].

use crate::corpus::Publication;
use crate::text::{HashingVectorizer, NUM_FIELDS};
use crate::util::json::Json;

use super::inverted::InvertedIndex;

/// Analyzed form of one document within a shard.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardDoc {
    /// Corpus-global document id.
    pub global_id: u64,
    /// Per-field sparse hashed term frequencies (bucket, count).
    pub field_tf: [Vec<(u32, f32)>; NUM_FIELDS],
    /// Per-field token counts (BM25 lengths).
    pub field_len: [f32; NUM_FIELDS],
}

impl ShardDoc {
    /// All (bucket, tf) pairs across every field, in field order. A
    /// bucket occurring in several fields yields several pairs; the
    /// inverted-index build accumulates them into one posting whose
    /// impact is the cross-field tf sum (see `index::inverted`).
    pub fn bucket_tf_iter(&self) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.field_tf.iter().flat_map(|tf| tf.iter().copied())
    }
}

/// Per-shard statistics contributed to the corpus-global BM25 stats.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStats {
    pub num_docs: u64,
    /// Document frequency per feature bucket (any field).
    pub df: Vec<u64>,
    /// Sum of field lengths (for global averages).
    pub field_len_sum: [f64; NUM_FIELDS],
}

/// Corpus-global statistics (merged from shard stats by the Data Source
/// Locator; consistent IDF across nodes is what makes distributed scores
/// mergeable).
#[derive(Debug, Clone, PartialEq)]
pub struct GlobalStats {
    pub total_docs: u64,
    pub df: Vec<u64>,
    pub avg_field_len: [f32; NUM_FIELDS],
}

impl ShardStats {
    pub fn empty(features: usize) -> Self {
        ShardStats { num_docs: 0, df: vec![0; features], field_len_sum: [0.0; NUM_FIELDS] }
    }

    /// Merge another shard's stats into this accumulator.
    pub fn merge(&mut self, other: &ShardStats) {
        assert_eq!(self.df.len(), other.df.len(), "feature space mismatch");
        self.num_docs += other.num_docs;
        for (a, b) in self.df.iter_mut().zip(&other.df) {
            *a += b;
        }
        for f in 0..NUM_FIELDS {
            self.field_len_sum[f] += other.field_len_sum[f];
        }
    }

    /// Finalize into global stats.
    pub fn finalize(&self) -> GlobalStats {
        let n = self.num_docs.max(1) as f64;
        let mut avg = [0.0f32; NUM_FIELDS];
        for f in 0..NUM_FIELDS {
            avg[f] = ((self.field_len_sum[f] / n) as f32).max(1e-3);
        }
        GlobalStats { total_docs: self.num_docs, df: self.df.clone(), avg_field_len: avg }
    }
}

impl GlobalStats {
    /// BM25 IDF for a feature bucket.
    pub fn idf(&self, feature: u32) -> f32 {
        let n = self.total_docs as f64;
        let df = self.df.get(feature as usize).copied().unwrap_or(0) as f64;
        ((1.0 + (n - df + 0.5) / (df + 0.5)).ln() as f32).max(0.0)
    }
}

/// One node-local shard: raw records + analyzed docs + inverted index.
#[derive(Debug, Clone)]
pub struct Shard {
    /// Shard id (unique within the grid; assigned by the locator).
    pub id: u32,
    /// Feature-space size (must equal the artifact F).
    pub features: usize,
    /// Raw records, parallel to `docs`.
    pub pubs: Vec<Publication>,
    /// Analyzed docs, parallel to `pubs`.
    pub docs: Vec<ShardDoc>,
    /// Inverted index over hashed features (any field).
    pub inverted: InvertedIndex,
    /// This shard's contribution to global stats.
    pub stats: ShardStats,
}

impl Shard {
    /// Analyze `pubs` into a shard with inverted index and stats.
    pub fn build(id: u32, pubs: Vec<Publication>, features: usize) -> Shard {
        let vectorizer = HashingVectorizer::new(features);
        let mut docs = Vec::with_capacity(pubs.len());
        let mut stats = ShardStats::empty(features);
        let mut seen = vec![0u64; features]; // df scratch (dedup per doc)

        for (local_id, p) in pubs.iter().enumerate() {
            let mut field_tf: [Vec<(u32, f32)>; NUM_FIELDS] = Default::default();
            let mut field_len = [0.0f32; NUM_FIELDS];
            for (fi, field) in crate::text::FIELDS.iter().enumerate() {
                let text = p.field_text(*field);
                field_tf[fi] = vectorizer.tf_sparse(text);
                field_len[fi] = vectorizer.field_len(text);
                stats.field_len_sum[fi] += field_len[fi] as f64;
            }
            // df: a feature counts once per doc regardless of field.
            let marker = local_id as u64 + 1;
            for tf in &field_tf {
                for (bucket, _) in tf {
                    if seen[*bucket as usize] != marker {
                        seen[*bucket as usize] = marker;
                        stats.df[*bucket as usize] += 1;
                    }
                }
            }
            docs.push(ShardDoc { global_id: p.id, field_tf, field_len });
        }
        stats.num_docs = pubs.len() as u64;
        let inverted = InvertedIndex::build(&docs, features);
        Shard { id, features, pubs, docs, inverted, stats }
    }

    pub fn len(&self) -> usize {
        self.docs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    /// Persist raw records as JSONL (one publication per line) — the
    /// "file-form data source" of the paper. Analysis is recomputed on
    /// load; files stay small and tool-friendly.
    pub fn save_jsonl(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
        for p in &self.pubs {
            writeln!(out, "{}", p.to_json().to_string_compact())?;
        }
        Ok(())
    }

    /// Load a shard from JSONL produced by [`Shard::save_jsonl`].
    pub fn load_jsonl(id: u32, path: &std::path::Path, features: usize) -> std::io::Result<Shard> {
        let text = std::fs::read_to_string(path)?;
        let mut pubs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = Json::parse(line).map_err(|e| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{}:{}: {e}", path.display(), lineno + 1),
                )
            })?;
            let p = Publication::from_json(&v).ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{}:{}: not a publication", path.display(), lineno + 1),
                )
            })?;
            pubs.push(p);
        }
        Ok(Shard::build(id, pubs, features))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusGenerator, CorpusSpec};

    fn small_shard(n: u64) -> Shard {
        let spec = CorpusSpec { num_docs: n, vocab_size: 500, ..CorpusSpec::default() };
        let gen = CorpusGenerator::new(spec);
        Shard::build(0, gen.generate_range(0, n), 256)
    }

    #[test]
    fn build_analyzes_all_docs() {
        let s = small_shard(50);
        assert_eq!(s.len(), 50);
        assert_eq!(s.stats.num_docs, 50);
        for d in &s.docs {
            assert!(!d.field_tf[0].is_empty(), "title tf empty");
            assert!(d.field_len[1] >= 10.0, "abstract too short");
        }
    }

    #[test]
    fn df_bounded_by_num_docs() {
        let s = small_shard(40);
        assert!(s.stats.df.iter().all(|&df| df <= 40));
        assert!(s.stats.df.iter().sum::<u64>() > 0);
    }

    #[test]
    fn stats_merge_and_finalize() {
        let a = small_shard(30);
        let b = small_shard(20);
        let mut acc = ShardStats::empty(256);
        acc.merge(&a.stats);
        acc.merge(&b.stats);
        assert_eq!(acc.num_docs, 50);
        let g = acc.finalize();
        assert_eq!(g.total_docs, 50);
        assert!(g.avg_field_len[1] > g.avg_field_len[0], "abstracts longer than titles");
    }

    #[test]
    fn idf_decreases_with_df() {
        let s = small_shard(60);
        let g = {
            let mut acc = ShardStats::empty(256);
            acc.merge(&s.stats);
            acc.finalize()
        };
        // find a frequent and a rare bucket
        let (mut hi, mut lo) = (0u32, 0u32);
        for (i, &df) in g.df.iter().enumerate() {
            if df > g.df[hi as usize] {
                hi = i as u32;
            }
            if df > 0 && (g.df[lo as usize] == 0 || df < g.df[lo as usize]) {
                lo = i as u32;
            }
        }
        assert!(g.idf(lo) >= g.idf(hi), "idf(rare) >= idf(common)");
        // unseen bucket has max idf
        let unseen = g.df.iter().position(|&d| d == 0);
        if let Some(u) = unseen {
            assert!(g.idf(u as u32) >= g.idf(hi));
        }
    }

    #[test]
    fn jsonl_roundtrip() {
        let s = small_shard(10);
        let dir = std::env::temp_dir().join("gaps_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard0.jsonl");
        s.save_jsonl(&path).unwrap();
        let loaded = Shard::load_jsonl(0, &path, 256).unwrap();
        assert_eq!(loaded.len(), 10);
        assert_eq!(loaded.pubs, s.pubs);
        assert_eq!(loaded.docs, s.docs);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_bad_lines() {
        let dir = std::env::temp_dir().join("gaps_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"id\": 1}\n").unwrap();
        assert!(Shard::load_jsonl(0, &path, 64).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
