//! Hashing vectorizer: terms -> fixed feature space.
//!
//! The Layer-1/Layer-2 artifacts score over a fixed `[NF, D, F]` feature
//! space (F hashed buckets per field). This module owns the term->bucket
//! mapping (FNV-1a, stable across rust and experiment runs) and builds the
//! per-field dense term-frequency rows the Search Service packs into
//! candidate blocks.

use super::tokenizer::terms;

/// FNV-1a 64-bit hash of a term (stable, dependency-free).
pub fn fnv1a(term: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in term.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Feature bucket of a term in a space of `f` buckets.
pub fn term_feature(term: &str, f: usize) -> usize {
    (fnv1a(term) % f as u64) as usize
}

/// Hashing vectorizer over a fixed number of buckets.
#[derive(Debug, Clone)]
pub struct HashingVectorizer {
    /// Number of feature buckets (the artifact F dimension).
    pub features: usize,
}

impl HashingVectorizer {
    pub fn new(features: usize) -> Self {
        assert!(features > 0);
        HashingVectorizer { features }
    }

    /// Dense term-frequency vector of a text (counts per bucket).
    pub fn tf_dense(&self, text: &str) -> Vec<f32> {
        let mut v = vec![0.0f32; self.features];
        for t in terms(text) {
            v[term_feature(&t, self.features)] += 1.0;
        }
        v
    }

    /// Sparse (bucket, count) pairs — what the doc store persists; the
    /// packer scatters these into block tiles on the request path.
    pub fn tf_sparse(&self, text: &str) -> Vec<(u32, f32)> {
        let mut v = self.tf_dense(text);
        let mut out = Vec::new();
        for (i, c) in v.drain(..).enumerate() {
            if c > 0.0 {
                out.push((i as u32, c));
            }
        }
        out
    }

    /// Token count of a text after normalization (the BM25 field length).
    pub fn field_len(&self, text: &str) -> f32 {
        terms(text).len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable() {
        // Regression-pin known FNV-1a 64 values so the feature mapping
        // never silently changes (it is part of the artifact ABI contract).
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a("grid"), fnv1a("grid"));
        assert_ne!(fnv1a("grid"), fnv1a("grids"));
    }

    #[test]
    fn buckets_in_range() {
        let f = 512;
        for w in ["grid", "search", "academic", "publication", "2014"] {
            assert!(term_feature(w, f) < f);
        }
    }

    #[test]
    fn tf_dense_counts_terms() {
        let v = HashingVectorizer::new(128);
        let tf = v.tf_dense("grid grid search");
        let g = term_feature("grid", 128);
        let s = term_feature("search", 128);
        assert_eq!(tf[g], 2.0);
        assert_eq!(tf[s], 1.0);
        assert_eq!(tf.iter().sum::<f32>(), 3.0);
    }

    #[test]
    fn sparse_matches_dense() {
        let v = HashingVectorizer::new(64);
        let text = "massive academic publications distributed over grid nodes";
        let dense = v.tf_dense(text);
        let sparse = v.tf_sparse(text);
        let mut rebuilt = vec![0.0f32; 64];
        for (i, c) in sparse {
            rebuilt[i as usize] = c;
        }
        assert_eq!(dense, rebuilt);
    }

    #[test]
    fn field_len_counts_kept_tokens() {
        let v = HashingVectorizer::new(64);
        assert_eq!(v.field_len("the grid and the search"), 2.0);
        assert_eq!(v.field_len(""), 0.0);
    }

    #[test]
    fn query_and_doc_share_buckets() {
        // Core retrieval invariant: a query term hashes to the same bucket
        // as the document term it should match.
        let f = 512;
        let doc_terms = terms("Searching massive publications");
        let query_terms = terms("search publication");
        assert_eq!(term_feature(&doc_terms[0], f), term_feature(&query_terms[0], f));
        assert_eq!(term_feature(&doc_terms[2], f), term_feature(&query_terms[1], f));
    }
}
