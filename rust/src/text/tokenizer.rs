//! Tokenizer + normalization + stopword filtering.
//!
//! Deliberately simple (the paper's search is keyword matching over
//! article metadata): Unicode-aware lowercase, alphanumeric token spans,
//! a small English stopword list, and a light suffix stemmer ("s"/"es"/
//! "ing"/"ed" stripping with guards) so query and document forms agree.

/// A normalized token with its source byte span.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    pub term: String,
    pub start: usize,
    pub end: usize,
}

/// English stopwords (top function words; enough for metadata search).
pub const STOPWORDS: &[&str] = &[
    "a", "an", "and", "are", "as", "at", "be", "by", "for", "from", "has", "have", "in",
    "is", "it", "its", "of", "on", "or", "that", "the", "their", "this", "to", "was",
    "were", "which", "with",
];

fn is_stopword(term: &str) -> bool {
    STOPWORDS.binary_search(&term).is_ok()
}

/// Light suffix stemmer: plural/participle stripping with length guards.
/// Applied identically to documents and queries, so exactness matters less
/// than consistency.
fn stem(term: &str) -> String {
    let t = term;
    let strip = |s: &str, suffix: &str, min_stem: usize| -> Option<String> {
        s.strip_suffix(suffix).and_then(|stem| {
            (stem.len() >= min_stem).then(|| stem.to_string())
        })
    };
    if let Some(s) = strip(t, "ing", 4) {
        return s;
    }
    if let Some(s) = strip(t, "ies", 3).map(|s| s + "y") {
        return s;
    }
    if let Some(s) = strip(t, "es", 3) {
        // guard: "techniques" -> "techniqu"? prefer plain "s" strip when the
        // base ends with a vowel+consonant; keep simple: only strip "es"
        // after sibilants (s, x, z, ch-ish).
        if s.ends_with('s') || s.ends_with('x') || s.ends_with('z') || s.ends_with('h') {
            return s;
        }
    }
    if t.len() >= 4 && t.ends_with('s') && !t.ends_with("ss") && !t.ends_with("us") {
        return t[..t.len() - 1].to_string();
    }
    if let Some(s) = strip(t, "ed", 4) {
        return s;
    }
    t.to_string()
}

/// Tokenize: lowercase alphanumeric spans, stopwords removed, stemmed.
/// Numbers are kept verbatim (years matter for multivariate search).
pub fn tokenize(text: &str) -> Vec<Token> {
    let mut out = Vec::new();
    let mut start: Option<usize> = None;
    let push = |s: usize, e: usize, out: &mut Vec<Token>, text: &str| {
        let raw: String = text[s..e].to_lowercase();
        if raw.is_empty() || is_stopword(&raw) {
            return;
        }
        let term = if raw.chars().all(|c| c.is_ascii_digit()) { raw } else { stem(&raw) };
        if !term.is_empty() && !is_stopword(&term) {
            out.push(Token { term, start: s, end: e });
        }
    };
    for (i, c) in text.char_indices() {
        if c.is_alphanumeric() {
            if start.is_none() {
                start = Some(i);
            }
        } else if let Some(s) = start.take() {
            push(s, i, &mut out, text);
        }
    }
    if let Some(s) = start {
        push(s, text.len(), &mut out, text);
    }
    out
}

/// Convenience: just the terms.
pub fn terms(text: &str) -> Vec<String> {
    tokenize(text).into_iter().map(|t| t.term).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwords_sorted_for_binary_search() {
        let mut sorted = STOPWORDS.to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, STOPWORDS, "STOPWORDS must stay sorted");
    }

    #[test]
    fn basic_tokenization() {
        let toks = terms("Grid-based Search Technique for Massive Academic Publications");
        assert_eq!(
            toks,
            vec!["grid", "based", "search", "technique", "massive", "academic", "publication"]
        );
    }

    #[test]
    fn stopwords_removed() {
        assert_eq!(terms("the cat and the hat"), vec!["cat", "hat"]);
        assert!(terms("the of and").is_empty());
    }

    #[test]
    fn numbers_kept_verbatim() {
        assert_eq!(terms("published in 2014"), vec!["publish", "2014"]);
    }

    #[test]
    fn spans_point_into_source() {
        let text = "Grid computing!";
        let toks = tokenize(text);
        assert_eq!(&text[toks[0].start..toks[0].end], "Grid");
        assert_eq!(&text[toks[1].start..toks[1].end], "computing");
    }

    #[test]
    fn unicode_does_not_panic_and_lowercases() {
        let toks = terms("Łukasz studies Sökmotor");
        assert!(toks.contains(&"łukasz".to_string()));
        assert!(toks.iter().any(|t| t.starts_with("sökmotor") || t.starts_with("sökmot")));
    }

    #[test]
    fn stemming_conflates_query_and_doc_forms() {
        // The invariant the index relies on: same stem for variants.
        assert_eq!(terms("searching")[0], terms("search")[0]);
        assert_eq!(terms("publications")[0], terms("publication")[0]);
        assert_eq!(terms("queries")[0], terms("query")[0]);
    }

    #[test]
    fn short_words_not_overstemmed() {
        assert_eq!(terms("gas")[0], "gas"); // not "ga"
        assert_eq!(terms("class")[0], "class"); // ss guard
        assert_eq!(terms("corpus")[0], "corpus"); // "us" guard
    }

    #[test]
    fn empty_and_punctuation_only() {
        assert!(terms("").is_empty());
        assert!(terms("--- !!! ...").is_empty());
    }
}
