//! Text pipeline: tokenization, normalization, stopwords, and the hashing
//! vectorizer that maps terms into the fixed feature space shared with the
//! Layer-1/Layer-2 scoring artifacts.
//!
//! The paper's data sources are files (XML/HTML article metadata), "not in
//! the form of database management system", searched by keyword; this
//! module is the analysis chain both the inverted index (retrieval) and
//! the dense packer (ranking) run over publication fields.

mod tokenizer;
mod vectorizer;

pub use tokenizer::{terms, tokenize, Token, STOPWORDS};
pub use vectorizer::{fnv1a, term_feature, HashingVectorizer};

/// Publication fields, in the exact order of the artifact ABI
/// (python/compile/model.py FIELDS). Index with `Field as usize`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Field {
    Title = 0,
    Abstract = 1,
    Authors = 2,
    Venue = 3,
}

/// Number of fields in the ABI.
pub const NUM_FIELDS: usize = 4;

/// All fields in ABI order.
pub const FIELDS: [Field; NUM_FIELDS] =
    [Field::Title, Field::Abstract, Field::Authors, Field::Venue];

impl Field {
    pub fn name(self) -> &'static str {
        match self {
            Field::Title => "title",
            Field::Abstract => "abstract",
            Field::Authors => "authors",
            Field::Venue => "venue",
        }
    }

    pub fn parse(s: &str) -> Option<Field> {
        match s.to_ascii_lowercase().as_str() {
            "title" => Some(Field::Title),
            "abstract" => Some(Field::Abstract),
            "authors" | "author" => Some(Field::Authors),
            "venue" => Some(Field::Venue),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_order_matches_abi() {
        // python/compile/model.py: FIELDS = ("title","abstract","authors","venue")
        assert_eq!(FIELDS[0].name(), "title");
        assert_eq!(FIELDS[1].name(), "abstract");
        assert_eq!(FIELDS[2].name(), "authors");
        assert_eq!(FIELDS[3].name(), "venue");
        assert_eq!(Field::Venue as usize, 3);
    }

    #[test]
    fn field_parse_roundtrip() {
        for f in FIELDS {
            assert_eq!(Field::parse(f.name()), Some(f));
        }
        assert_eq!(Field::parse("author"), Some(Field::Authors));
        assert_eq!(Field::parse("body"), None);
    }
}
