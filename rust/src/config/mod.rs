//! Layered configuration system.
//!
//! One typed [`GapsConfig`] drives the whole stack (launcher, examples,
//! benches). Values resolve in order: compiled defaults -> JSON config
//! file (`--config file.json`) -> individual CLI flags (`--nodes 8`).
//! Every knob is documented where it is defined; `GapsConfig::describe()`
//! dumps the effective config (printed by the launcher at startup, and
//! recorded alongside experiment runs).

use crate::util::cli::{Args, CliError};
use crate::util::json::Json;

/// Scheduling policy for assigning search jobs to nodes (Fig 4/5 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// GAPS: use recorded node performance to size per-node work
    /// ("the execution plan ... depends on the previous performance").
    PerfHistory,
    /// Naive round-robin over nodes (the traditional-search distribution).
    RoundRobin,
}

impl SchedulePolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "perf" | "perf-history" | "perfhistory" | "gaps" => Some(SchedulePolicy::PerfHistory),
            "rr" | "round-robin" | "roundrobin" | "traditional" => Some(SchedulePolicy::RoundRobin),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedulePolicy::PerfHistory => "perf-history",
            SchedulePolicy::RoundRobin => "round-robin",
        }
    }
}

/// Grid fabric shape + simulated network/service parameters.
#[derive(Debug, Clone)]
pub struct GridConfig {
    /// Number of Virtual Organizations (paper testbed: 3).
    pub num_vos: usize,
    /// Worker nodes per VO (paper testbed: 4, one doubling as broker).
    pub nodes_per_vo: usize,
    /// Node speed heterogeneity: speed factors drawn uniform in
    /// [speed_min, speed_max] (1.0 = nominal). The paper notes "grid nodes
    /// have different specifications".
    pub speed_min: f64,
    pub speed_max: f64,
    /// Simulated LAN latency within a VO (µs, one way).
    pub lan_latency_us: u64,
    /// Simulated WAN latency between VOs (µs, one way).
    pub wan_latency_us: u64,
    /// Simulated bandwidth for result/JDF transfer (MB/s).
    pub bandwidth_mbps: f64,
    /// Whether Search Services stay resident in the container (paper's
    /// globus-container design) or cold-start per job (ablation).
    pub resident_services: bool,
    /// Cold-start penalty when services are not resident (ms).
    pub cold_start_ms: f64,
    /// Per-job dispatch overhead at a broker (ms). Brokers dispatch their
    /// jobs serially, so this is the term that makes centralized
    /// coordination degrade with node count (Fig 4's traditional curve).
    pub dispatch_ms: f64,
    /// Probation window: ticks a Down node must wait before the
    /// coordinator health-probes it for rejoin (grid churn recovery).
    pub probe_after_ticks: u64,
    /// RNG seed for fabric heterogeneity.
    pub seed: u64,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            num_vos: 3,
            nodes_per_vo: 4,
            speed_min: 0.5,
            speed_max: 1.5,
            lan_latency_us: 200,
            wan_latency_us: 8_000,
            bandwidth_mbps: 40.0,
            resident_services: true,
            cold_start_ms: 350.0,
            dispatch_ms: 8.0,
            probe_after_ticks: 2,
            seed: 0x6169D,
        }
    }
}

impl GridConfig {
    pub fn total_nodes(&self) -> usize {
        self.num_vos * self.nodes_per_vo
    }
}

/// Corpus/workload shape.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Total documents in the corpus.
    pub num_docs: u64,
    /// Queries per experiment batch.
    pub num_queries: usize,
    /// Total data sources (sub-shards) the corpus is split into,
    /// independent of node count — adding nodes means fewer sources per
    /// node (the paper's fixed datasets spread over a growing grid).
    /// Clamped up to the node count so every node hosts at least one.
    pub sub_shards: usize,
    /// Corpus seed (distinct from the fabric seed).
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            num_docs: 20_000,
            num_queries: 16,
            sub_shards: 24,
            seed: 0xC0/*rpus*/,
        }
    }
}

/// Search/scoring parameters (shared with the artifact ABI).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Feature buckets per field (must match an artifact F).
    pub features: usize,
    /// Results per query.
    pub top_k: usize,
    /// Max candidates retrieved per shard before ranking.
    pub max_candidates: usize,
    /// BM25 length-normalisation b.
    pub b: f32,
    /// Field weights in ABI order (title, abstract, authors, venue).
    pub field_weights: [f32; 4],
    /// Execute scoring through the PJRT artifacts (true) or the pure-rust
    /// fallback scorer (false; baseline + environments without artifacts).
    pub use_xla: bool,
    /// Directory containing `manifest.json` + HLO artifacts.
    pub artifact_dir: String,
    /// Scheduling policy.
    pub policy: SchedulePolicy,
    /// Worker threads for the coordinator's parallel shard fan-out
    /// (0 = auto: one per available core; 1 = serial dispatch, the
    /// reference the Fig 4/5 speedup curves compare against). The XLA
    /// scorer path always executes serially — PJRT handles are !Send.
    pub workers: usize,
    /// Mid-flight failover: extra planning rounds allowed after per-node
    /// job failures before the batch gives up (0 = fail on first fault).
    pub failover_retries: usize,
    /// Simulated per-attempt backoff charged to the response timeline on
    /// each failover retry (ms, scaled by the attempt number).
    pub retry_backoff_ms: f64,
}

impl SearchConfig {
    /// Resolve the `workers` knob: 0 means one worker per available core.
    pub fn effective_workers(&self) -> usize {
        match self.workers {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        }
    }
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            features: 512,
            top_k: 10,
            max_candidates: 1024,
            b: 0.75,
            field_weights: [2.0, 1.0, 1.5, 0.5],
            use_xla: true,
            artifact_dir: "artifacts".into(),
            policy: SchedulePolicy::PerfHistory,
            workers: 0,
            failover_retries: 2,
            retry_backoff_ms: 25.0,
        }
    }
}

/// Persistence + live-ingestion knobs (the `storage` config section).
#[derive(Debug, Clone)]
pub struct StorageConfig {
    /// Snapshot directory: `gaps serve`/`gaps search` boot from it when
    /// set (`--snapshot DIR`), `gaps snapshot` writes into it. Empty =
    /// build the corpus from the generator as before.
    pub snapshot_dir: String,
    /// Buffered publications per source before the ingest buffer seals
    /// into an immutable overlay segment (searchable from that point).
    pub seal_docs: usize,
    /// Sealed overlay segments per source that trigger a compaction
    /// merge into one segment (values < 2 disable merging).
    pub merge_fanout: usize,
}

impl Default for StorageConfig {
    fn default() -> Self {
        StorageConfig { snapshot_dir: String::new(), seal_docs: 512, merge_fanout: 4 }
    }
}

/// Plan/result caching knobs (the `cache` config section).
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Master switch: when false neither the compiled-plan cache nor the
    /// top-k result cache is consulted (the off-switch for parity
    /// oracles and cache-suspect debugging). Single-flight coalescing in
    /// the admission queue stays on either way — it dedups *in-flight*
    /// work, not completed results.
    pub enabled: bool,
    /// Compiled-plan cache capacity, in entries (0 disables just the
    /// plan cache). Keyed on the raw request, so a hit skips
    /// lex + parse + plan entirely.
    pub plan_capacity: usize,
    /// Top-k result cache capacity, in entries across all shards
    /// (0 disables just the result cache). Keyed on the normalized-AST
    /// fingerprint + index epoch; invalidated wholesale when the epoch
    /// moves (segment seal/merge).
    pub result_capacity: usize,
    /// Result-cache shard count (reduces lock contention under
    /// concurrent submitters; clamped to >= 1).
    pub result_shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { enabled: true, plan_capacity: 4096, result_capacity: 2048, result_shards: 8 }
    }
}

/// Serving-layer knobs (the `serve` config section; surfaced by the
/// `gaps serve` CLI flags of the same names).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bounded HTTP handler pool: at most this many connections are
    /// served concurrently; the acceptor sheds the rest with a typed
    /// 503 + `Retry-After` (clamped to >= 1).
    pub handlers: usize,
    /// Executor shards: deterministic `GapsSystem` replicas, each with
    /// its own admission lane and executor thread; searches route
    /// round-robin, ingest fans out to all (clamped to >= 1).
    pub shards: usize,
    /// HTTP keep-alive (persistent connections with pipelined reads).
    /// Off serves one request per connection, `Connection: close` on
    /// every response.
    pub keep_alive: bool,
    /// Most requests coalesced into one `search_batch` round (>= 1).
    pub max_batch: usize,
    /// Linger window (ms) a drain waits past the oldest pending
    /// request's arrival for co-arriving requests.
    pub linger_ms: u64,
    /// Admission high-water mark: pending requests beyond this are shed
    /// with `overloaded`.
    pub max_depth: usize,
    /// Socket read/write timeout (ms) on the HTTP front (0 disables).
    pub read_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            handlers: 32,
            shards: 1,
            keep_alive: true,
            max_batch: 16,
            linger_ms: 2,
            max_depth: 1024,
            read_timeout_ms: 10_000,
        }
    }
}

/// Observability knobs (the `obs` config section): slow-query logging
/// thresholds for the serving layer. The metrics registry and request
/// tracing have no knobs — they are always on and provably zero-impact
/// on results (see `tests/prop_serve_parity.rs`).
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Requests whose end-to-end time (arrival -> settled) meets or
    /// exceeds this many milliseconds are recorded in the slow-query
    /// ring buffer (`GET /debug/slow`).
    pub slow_query_ms: u64,
    /// Slow-query ring capacity, in entries (oldest evicted; clamped
    /// to >= 1).
    pub slow_log_capacity: usize,
    /// Also append each slow-query entry as one JSONL line to this
    /// file (`--slow-log FILE`). Empty = ring buffer only.
    pub slow_log_file: String,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { slow_query_ms: 500, slow_log_capacity: 128, slow_log_file: String::new() }
    }
}

/// Root configuration.
#[derive(Debug, Clone, Default)]
pub struct GapsConfig {
    pub grid: GridConfig,
    pub workload: WorkloadConfig,
    pub search: SearchConfig,
    pub storage: StorageConfig,
    pub cache: CacheConfig,
    pub serve: ServeConfig,
    pub obs: ObsConfig,
}

impl GapsConfig {
    /// Apply a JSON config object (unknown keys are an error — catches
    /// typos in experiment configs).
    pub fn apply_json(&mut self, v: &Json) -> Result<(), CliError> {
        let obj = v
            .as_obj()
            .ok_or_else(|| CliError("config root must be an object".into()))?;
        for (section, body) in obj {
            match section.as_str() {
                "grid" => apply_section(body, |k, v| self.set_grid(k, v))?,
                "workload" => apply_section(body, |k, v| self.set_workload(k, v))?,
                "search" => apply_section(body, |k, v| self.set_search(k, v))?,
                "storage" => apply_section(body, |k, v| self.set_storage(k, v))?,
                "cache" => apply_section(body, |k, v| self.set_cache(k, v))?,
                "serve" => apply_section(body, |k, v| self.set_serve(k, v))?,
                "obs" => apply_section(body, |k, v| self.set_obs(k, v))?,
                other => return Err(CliError(format!("unknown config section '{other}'"))),
            }
        }
        Ok(())
    }

    fn set_grid(&mut self, key: &str, v: &Json) -> Result<(), CliError> {
        let g = &mut self.grid;
        match key {
            "num_vos" => g.num_vos = as_usize(key, v)?,
            "nodes_per_vo" => g.nodes_per_vo = as_usize(key, v)?,
            "speed_min" => g.speed_min = as_f64(key, v)?,
            "speed_max" => g.speed_max = as_f64(key, v)?,
            "lan_latency_us" => g.lan_latency_us = as_usize(key, v)? as u64,
            "wan_latency_us" => g.wan_latency_us = as_usize(key, v)? as u64,
            "bandwidth_mbps" => g.bandwidth_mbps = as_f64(key, v)?,
            "resident_services" => g.resident_services = as_bool(key, v)?,
            "cold_start_ms" => g.cold_start_ms = as_f64(key, v)?,
            "dispatch_ms" => g.dispatch_ms = as_f64(key, v)?,
            "probe_after_ticks" => g.probe_after_ticks = as_usize(key, v)? as u64,
            "seed" => g.seed = as_usize(key, v)? as u64,
            _ => return Err(CliError(format!("unknown grid key '{key}'"))),
        }
        Ok(())
    }

    fn set_workload(&mut self, key: &str, v: &Json) -> Result<(), CliError> {
        let w = &mut self.workload;
        match key {
            "num_docs" => w.num_docs = as_usize(key, v)? as u64,
            "num_queries" => w.num_queries = as_usize(key, v)?,
            "sub_shards" => w.sub_shards = as_usize(key, v)?,
            "seed" => w.seed = as_usize(key, v)? as u64,
            _ => return Err(CliError(format!("unknown workload key '{key}'"))),
        }
        Ok(())
    }

    fn set_search(&mut self, key: &str, v: &Json) -> Result<(), CliError> {
        let s = &mut self.search;
        match key {
            "features" => s.features = as_usize(key, v)?,
            "top_k" => s.top_k = as_usize(key, v)?,
            "max_candidates" => s.max_candidates = as_usize(key, v)?,
            "workers" => s.workers = as_usize(key, v)?,
            "failover_retries" => s.failover_retries = as_usize(key, v)?,
            "retry_backoff_ms" => s.retry_backoff_ms = as_f64(key, v)?,
            "b" => s.b = as_f64(key, v)? as f32,
            "use_xla" => s.use_xla = as_bool(key, v)?,
            "artifact_dir" => {
                s.artifact_dir = v
                    .as_str()
                    .ok_or_else(|| CliError(format!("search.{key} must be a string")))?
                    .to_string()
            }
            "policy" => {
                let name = v
                    .as_str()
                    .ok_or_else(|| CliError(format!("search.{key} must be a string")))?;
                s.policy = SchedulePolicy::parse(name)
                    .ok_or_else(|| CliError(format!("unknown policy '{name}'")))?;
            }
            "field_weights" => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| CliError(format!("search.{key} must be an array")))?;
                if arr.len() != 4 {
                    return Err(CliError("field_weights needs 4 entries".into()));
                }
                for (i, x) in arr.iter().enumerate() {
                    s.field_weights[i] = as_f64(key, x)? as f32;
                }
            }
            _ => return Err(CliError(format!("unknown search key '{key}'"))),
        }
        Ok(())
    }

    fn set_storage(&mut self, key: &str, v: &Json) -> Result<(), CliError> {
        let st = &mut self.storage;
        match key {
            "snapshot_dir" => {
                st.snapshot_dir = v
                    .as_str()
                    .ok_or_else(|| CliError(format!("storage.{key} must be a string")))?
                    .to_string()
            }
            "seal_docs" => st.seal_docs = as_usize(key, v)?,
            "merge_fanout" => st.merge_fanout = as_usize(key, v)?,
            _ => return Err(CliError(format!("unknown storage key '{key}'"))),
        }
        Ok(())
    }

    fn set_cache(&mut self, key: &str, v: &Json) -> Result<(), CliError> {
        let c = &mut self.cache;
        match key {
            "enabled" => c.enabled = as_bool(key, v)?,
            "plan_capacity" => c.plan_capacity = as_usize(key, v)?,
            "result_capacity" => c.result_capacity = as_usize(key, v)?,
            "result_shards" => c.result_shards = as_usize(key, v)?,
            _ => return Err(CliError(format!("unknown cache key '{key}'"))),
        }
        Ok(())
    }

    fn set_serve(&mut self, key: &str, v: &Json) -> Result<(), CliError> {
        let sv = &mut self.serve;
        match key {
            "handlers" => sv.handlers = as_usize(key, v)?,
            "shards" => sv.shards = as_usize(key, v)?,
            "keep_alive" => sv.keep_alive = as_bool(key, v)?,
            "max_batch" => sv.max_batch = as_usize(key, v)?,
            "linger_ms" => sv.linger_ms = as_usize(key, v)? as u64,
            "max_depth" => sv.max_depth = as_usize(key, v)?,
            "read_timeout_ms" => sv.read_timeout_ms = as_usize(key, v)? as u64,
            _ => return Err(CliError(format!("unknown serve key '{key}'"))),
        }
        Ok(())
    }

    fn set_obs(&mut self, key: &str, v: &Json) -> Result<(), CliError> {
        let o = &mut self.obs;
        match key {
            "slow_query_ms" => o.slow_query_ms = as_usize(key, v)? as u64,
            "slow_log_capacity" => o.slow_log_capacity = as_usize(key, v)?,
            "slow_log_file" => {
                o.slow_log_file = v
                    .as_str()
                    .ok_or_else(|| CliError(format!("obs.{key} must be a string")))?
                    .to_string()
            }
            _ => return Err(CliError(format!("unknown obs key '{key}'"))),
        }
        Ok(())
    }

    /// Apply CLI flag overrides (flat names; see README "Configuration").
    pub fn apply_args(&mut self, args: &Args) -> Result<(), CliError> {
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path)
                .map_err(|e| CliError(format!("--config {path}: {e}")))?;
            let v = Json::parse(&text).map_err(|e| CliError(format!("--config {path}: {e}")))?;
            self.apply_json(&v)?;
        }
        let g = &mut self.grid;
        g.num_vos = args.get_parse("vos", g.num_vos)?;
        g.nodes_per_vo = args.get_parse("nodes-per-vo", g.nodes_per_vo)?;
        g.seed = args.get_parse("grid-seed", g.seed)?;
        if args.has("no-resident-services") {
            g.resident_services = false;
        }
        let w = &mut self.workload;
        w.num_docs = args.get_parse("docs", w.num_docs)?;
        w.num_queries = args.get_parse("queries", w.num_queries)?;
        w.seed = args.get_parse("seed", w.seed)?;
        let s = &mut self.search;
        s.top_k = args.get_parse("top-k", s.top_k)?;
        s.max_candidates = args.get_parse("max-candidates", s.max_candidates)?;
        s.workers = args.get_parse("workers", s.workers)?;
        s.failover_retries = args.get_parse("failover-retries", s.failover_retries)?;
        if let Some(p) = args.get("policy") {
            s.policy = SchedulePolicy::parse(p)
                .ok_or_else(|| CliError(format!("unknown policy '{p}'")))?;
        }
        if args.has("no-xla") {
            s.use_xla = false;
        }
        if let Some(dir) = args.get("artifacts") {
            s.artifact_dir = dir.to_string();
        }
        let st = &mut self.storage;
        st.seal_docs = args.get_parse("seal-docs", st.seal_docs)?;
        st.merge_fanout = args.get_parse("merge-fanout", st.merge_fanout)?;
        if let Some(dir) = args.get("snapshot") {
            st.snapshot_dir = dir.to_string();
        }
        let c = &mut self.cache;
        if args.has("no-cache") {
            c.enabled = false;
        }
        c.plan_capacity = args.get_parse("cache-plan-capacity", c.plan_capacity)?;
        c.result_capacity = args.get_parse("cache-result-capacity", c.result_capacity)?;
        c.result_shards = args.get_parse("cache-result-shards", c.result_shards)?;
        let sv = &mut self.serve;
        sv.handlers = args.get_parse("handlers", sv.handlers)?;
        sv.shards = args.get_parse("shards", sv.shards)?;
        sv.max_batch = args.get_parse("max-batch", sv.max_batch)?;
        sv.linger_ms = args.get_parse("linger-ms", sv.linger_ms)?;
        sv.max_depth = args.get_parse("max-depth", sv.max_depth)?;
        sv.read_timeout_ms = args.get_parse("read-timeout-ms", sv.read_timeout_ms)?;
        if let Some(v) = args.get("keep-alive") {
            sv.keep_alive = parse_on_off("keep-alive", v)?;
        }
        let o = &mut self.obs;
        o.slow_query_ms = args.get_parse("slow-query-ms", o.slow_query_ms)?;
        o.slow_log_capacity = args.get_parse("slow-log-capacity", o.slow_log_capacity)?;
        if let Some(path) = args.get("slow-log") {
            o.slow_log_file = path.to_string();
        }
        Ok(())
    }

    /// Human-readable dump of the effective configuration.
    pub fn describe(&self) -> String {
        format!(
            "grid: {} VOs x {} nodes (speed {:.2}-{:.2}, lan {}us wan {}us, {} services)\n\
             workload: {} docs, {} queries (seed {})\n\
             search: F={} top_k={} max_cand={} policy={} xla={} artifacts={} workers={} \
             failover_retries={}\n\
             storage: snapshot_dir={} seal_docs={} merge_fanout={}\n\
             cache: enabled={} plan_capacity={} result_capacity={} result_shards={}\n\
             serve: handlers={} shards={} keep_alive={} max_batch={} linger_ms={} \
             max_depth={} read_timeout_ms={}\n\
             obs: slow_query_ms={} slow_log_capacity={} slow_log={}",
            self.grid.num_vos,
            self.grid.nodes_per_vo,
            self.grid.speed_min,
            self.grid.speed_max,
            self.grid.lan_latency_us,
            self.grid.wan_latency_us,
            if self.grid.resident_services { "resident" } else { "cold-start" },
            self.workload.num_docs,
            self.workload.num_queries,
            self.workload.seed,
            self.search.features,
            self.search.top_k,
            self.search.max_candidates,
            self.search.policy.name(),
            self.search.use_xla,
            self.search.artifact_dir,
            self.search.workers,
            self.search.failover_retries,
            if self.storage.snapshot_dir.is_empty() { "-" } else { &self.storage.snapshot_dir },
            self.storage.seal_docs,
            self.storage.merge_fanout,
            self.cache.enabled,
            self.cache.plan_capacity,
            self.cache.result_capacity,
            self.cache.result_shards,
            self.serve.handlers,
            self.serve.shards,
            self.serve.keep_alive,
            self.serve.max_batch,
            self.serve.linger_ms,
            self.serve.max_depth,
            self.serve.read_timeout_ms,
            self.obs.slow_query_ms,
            self.obs.slow_log_capacity,
            if self.obs.slow_log_file.is_empty() { "-" } else { &self.obs.slow_log_file },
        )
    }
}

fn as_usize(key: &str, v: &Json) -> Result<usize, CliError> {
    v.as_i64()
        .filter(|x| *x >= 0)
        .map(|x| x as usize)
        .ok_or_else(|| CliError(format!("{key} must be a non-negative integer")))
}

fn as_f64(key: &str, v: &Json) -> Result<f64, CliError> {
    v.as_f64().ok_or_else(|| CliError(format!("{key} must be a number")))
}

fn as_bool(key: &str, v: &Json) -> Result<bool, CliError> {
    v.as_bool().ok_or_else(|| CliError(format!("{key} must be a boolean")))
}

/// Parse an on/off CLI value (`--keep-alive on|off`). The flag takes an
/// explicit value rather than acting as a boolean switch so keep-alive
/// can be turned *off* from the command line (a bare boolean flag could
/// only ever assert the default).
fn parse_on_off(flag: &str, v: &str) -> Result<bool, CliError> {
    match v.to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => Err(CliError(format!("--{flag} must be on|off, got '{other}'"))),
    }
}

fn apply_section<F>(body: &Json, mut set: F) -> Result<(), CliError>
where
    F: FnMut(&str, &Json) -> Result<(), CliError>,
{
    let obj = body
        .as_obj()
        .ok_or_else(|| CliError("config section must be an object".into()))?;
    for (k, v) in obj {
        set(k, v)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = GapsConfig::default();
        assert_eq!(c.grid.num_vos, 3);
        assert_eq!(c.grid.nodes_per_vo, 4);
        assert_eq!(c.grid.total_nodes(), 12);
        assert_eq!(c.search.policy, SchedulePolicy::PerfHistory);
    }

    #[test]
    fn json_overrides_apply() {
        let mut c = GapsConfig::default();
        let v = Json::parse(
            r#"{"grid": {"num_vos": 2, "resident_services": false},
                 "workload": {"num_docs": 500},
                 "search": {"policy": "round-robin", "field_weights": [1,1,1,1]}}"#,
        )
        .unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.grid.num_vos, 2);
        assert!(!c.grid.resident_services);
        assert_eq!(c.workload.num_docs, 500);
        assert_eq!(c.search.policy, SchedulePolicy::RoundRobin);
        assert_eq!(c.search.field_weights, [1.0; 4]);
    }

    #[test]
    fn unknown_keys_rejected() {
        let mut c = GapsConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"grid": {"nodez": 3}}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"grd": {}}"#).unwrap()).is_err());
    }

    #[test]
    fn cli_overrides_apply() {
        let mut c = GapsConfig::default();
        let toks: Vec<String> = ["--vos", "2", "--docs", "1000", "--policy", "rr", "--no-xla"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&toks, false, &["no-xla"]).unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.grid.num_vos, 2);
        assert_eq!(c.workload.num_docs, 1000);
        assert_eq!(c.search.policy, SchedulePolicy::RoundRobin);
        assert!(!c.search.use_xla);
    }

    #[test]
    fn policy_parse_aliases() {
        assert_eq!(SchedulePolicy::parse("gaps"), Some(SchedulePolicy::PerfHistory));
        assert_eq!(SchedulePolicy::parse("traditional"), Some(SchedulePolicy::RoundRobin));
        assert_eq!(SchedulePolicy::parse("bogus"), None);
    }

    #[test]
    fn fault_tolerance_knobs_parse() {
        let mut c = GapsConfig::default();
        assert_eq!(c.search.failover_retries, 2);
        assert_eq!(c.grid.probe_after_ticks, 2);
        c.apply_json(
            &Json::parse(
                r#"{"grid": {"probe_after_ticks": 5},
                     "search": {"failover_retries": 0, "retry_backoff_ms": 10}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.grid.probe_after_ticks, 5);
        assert_eq!(c.search.failover_retries, 0);
        assert_eq!(c.search.retry_backoff_ms, 10.0);
    }

    #[test]
    fn workers_knob_parses_and_resolves() {
        let mut c = GapsConfig::default();
        c.apply_json(&Json::parse(r#"{"search": {"workers": 3}}"#).unwrap()).unwrap();
        assert_eq!(c.search.workers, 3);
        assert_eq!(c.search.effective_workers(), 3);
        c.search.workers = 0;
        assert!(c.search.effective_workers() >= 1, "auto resolves to >=1");
    }

    #[test]
    fn storage_knobs_parse() {
        let mut c = GapsConfig::default();
        assert!(c.storage.snapshot_dir.is_empty());
        assert_eq!(c.storage.seal_docs, 512);
        assert_eq!(c.storage.merge_fanout, 4);
        c.apply_json(
            &Json::parse(
                r#"{"storage": {"snapshot_dir": "/tmp/snap", "seal_docs": 64, "merge_fanout": 2}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.storage.snapshot_dir, "/tmp/snap");
        assert_eq!(c.storage.seal_docs, 64);
        assert_eq!(c.storage.merge_fanout, 2);
        // Unknown storage keys are typos, not silently ignored.
        assert!(c.apply_json(&Json::parse(r#"{"storage": {"seal_dox": 1}}"#).unwrap()).is_err());
    }

    #[test]
    fn storage_cli_flags_apply() {
        let mut c = GapsConfig::default();
        let toks: Vec<String> =
            ["--snapshot", "/tmp/snap2", "--seal-docs", "32", "--merge-fanout", "3"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let args = Args::parse(&toks, false, &[]).unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.storage.snapshot_dir, "/tmp/snap2");
        assert_eq!(c.storage.seal_docs, 32);
        assert_eq!(c.storage.merge_fanout, 3);
    }

    #[test]
    fn cache_knobs_parse() {
        let mut c = GapsConfig::default();
        assert!(c.cache.enabled);
        assert_eq!(c.cache.plan_capacity, 4096);
        assert_eq!(c.cache.result_capacity, 2048);
        assert_eq!(c.cache.result_shards, 8);
        c.apply_json(
            &Json::parse(
                r#"{"cache": {"enabled": false, "plan_capacity": 16,
                     "result_capacity": 32, "result_shards": 2}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert!(!c.cache.enabled);
        assert_eq!(c.cache.plan_capacity, 16);
        assert_eq!(c.cache.result_capacity, 32);
        assert_eq!(c.cache.result_shards, 2);
        // Unknown cache keys are typos, not silently ignored.
        assert!(c.apply_json(&Json::parse(r#"{"cache": {"capasity": 1}}"#).unwrap()).is_err());
    }

    #[test]
    fn cache_cli_flags_apply() {
        let mut c = GapsConfig::default();
        let toks: Vec<String> = [
            "--no-cache",
            "--cache-plan-capacity",
            "64",
            "--cache-result-capacity",
            "128",
            "--cache-result-shards",
            "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&toks, false, &["no-cache"]).unwrap();
        c.apply_args(&args).unwrap();
        assert!(!c.cache.enabled);
        assert_eq!(c.cache.plan_capacity, 64);
        assert_eq!(c.cache.result_capacity, 128);
        assert_eq!(c.cache.result_shards, 4);
    }

    #[test]
    fn serve_knobs_parse() {
        let mut c = GapsConfig::default();
        assert_eq!(c.serve.handlers, 32);
        assert_eq!(c.serve.shards, 1);
        assert!(c.serve.keep_alive);
        assert_eq!(c.serve.max_batch, 16);
        assert_eq!(c.serve.linger_ms, 2);
        assert_eq!(c.serve.max_depth, 1024);
        assert_eq!(c.serve.read_timeout_ms, 10_000);
        c.apply_json(
            &Json::parse(
                r#"{"serve": {"handlers": 8, "shards": 4, "keep_alive": false,
                     "max_batch": 2, "linger_ms": 0, "max_depth": 64,
                     "read_timeout_ms": 250}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.serve.handlers, 8);
        assert_eq!(c.serve.shards, 4);
        assert!(!c.serve.keep_alive);
        assert_eq!(c.serve.max_batch, 2);
        assert_eq!(c.serve.linger_ms, 0);
        assert_eq!(c.serve.max_depth, 64);
        assert_eq!(c.serve.read_timeout_ms, 250);
        // Unknown serve keys are typos, not silently ignored.
        assert!(c.apply_json(&Json::parse(r#"{"serve": {"handelrs": 1}}"#).unwrap()).is_err());
    }

    #[test]
    fn serve_cli_flags_apply() {
        let mut c = GapsConfig::default();
        let toks: Vec<String> = [
            "--handlers",
            "4",
            "--shards",
            "2",
            "--keep-alive",
            "off",
            "--max-batch",
            "8",
            "--linger-ms",
            "1",
            "--max-depth",
            "99",
            "--read-timeout-ms",
            "500",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&toks, false, &[]).unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.serve.handlers, 4);
        assert_eq!(c.serve.shards, 2);
        assert!(!c.serve.keep_alive);
        assert_eq!(c.serve.max_batch, 8);
        assert_eq!(c.serve.linger_ms, 1);
        assert_eq!(c.serve.max_depth, 99);
        assert_eq!(c.serve.read_timeout_ms, 500);
    }

    #[test]
    fn keep_alive_flag_parses_on_off_and_rejects_garbage() {
        let apply = |val: &str| {
            let mut c = GapsConfig::default();
            let toks: Vec<String> =
                ["--keep-alive", val].iter().map(|s| s.to_string()).collect();
            let args = Args::parse(&toks, false, &[]).unwrap();
            c.apply_args(&args).map(|_| c.serve.keep_alive)
        };
        assert_eq!(apply("on").unwrap(), true);
        assert_eq!(apply("ON").unwrap(), true);
        assert_eq!(apply("1").unwrap(), true);
        assert_eq!(apply("off").unwrap(), false);
        assert_eq!(apply("false").unwrap(), false);
        assert!(apply("maybe").is_err(), "garbage must be rejected, not defaulted");
    }

    #[test]
    fn obs_knobs_parse() {
        let mut c = GapsConfig::default();
        assert_eq!(c.obs.slow_query_ms, 500);
        assert_eq!(c.obs.slow_log_capacity, 128);
        assert!(c.obs.slow_log_file.is_empty());
        c.apply_json(
            &Json::parse(
                r#"{"obs": {"slow_query_ms": 50, "slow_log_capacity": 16,
                     "slow_log_file": "/tmp/slow.jsonl"}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.obs.slow_query_ms, 50);
        assert_eq!(c.obs.slow_log_capacity, 16);
        assert_eq!(c.obs.slow_log_file, "/tmp/slow.jsonl");
        // Unknown obs keys are typos, not silently ignored.
        assert!(c.apply_json(&Json::parse(r#"{"obs": {"slowquery": 1}}"#).unwrap()).is_err());
    }

    #[test]
    fn obs_cli_flags_apply() {
        let mut c = GapsConfig::default();
        let toks: Vec<String> = [
            "--slow-query-ms",
            "25",
            "--slow-log-capacity",
            "8",
            "--slow-log",
            "/tmp/slow2.jsonl",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&toks, false, &[]).unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.obs.slow_query_ms, 25);
        assert_eq!(c.obs.slow_log_capacity, 8);
        assert_eq!(c.obs.slow_log_file, "/tmp/slow2.jsonl");
    }

    #[test]
    fn describe_mentions_key_facts() {
        let d = GapsConfig::default().describe();
        assert!(d.contains("3 VOs"));
        assert!(d.contains("perf-history"));
        assert!(d.contains("handlers=32"));
        assert!(d.contains("shards=1"));
        assert!(d.contains("slow_query_ms=500"));
    }
}
