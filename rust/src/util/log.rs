//! Tiny leveled logger with per-component tags.
//!
//! The grid services (QEE, QM, SS, brokers) tag every line with their
//! component id, which is how the paper-era Globus logs looked and makes
//! multi-"node" traces readable. Controlled by `GAPS_LOG` env var
//! (error|warn|info|debug|trace) or programmatically via [`set_level`].

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static SINK: Mutex<Option<Vec<String>>> = Mutex::new(None);

fn current_level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let from_env = std::env::var("GAPS_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Warn) as u8;
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Route log lines into an in-memory buffer (for tests); returns captured
/// lines when called with `false` after capturing.
pub fn capture(enable: bool) -> Vec<String> {
    let mut sink = SINK.lock().unwrap();
    if enable {
        *sink = Some(Vec::new());
        Vec::new()
    } else {
        sink.take().unwrap_or_default()
    }
}

/// Emit a log line if `level` is enabled.
pub fn log(level: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if (level as u8) > current_level() {
        return;
    }
    let line = format!("[{:5}] [{}] {}", level.as_str(), component, msg);
    let mut sink = SINK.lock().unwrap();
    if let Some(buf) = sink.as_mut() {
        buf.push(line);
    } else {
        let stderr = std::io::stderr();
        let _ = writeln!(stderr.lock(), "{line}");
    }
}

#[macro_export]
macro_rules! log_error {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, $component,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $component,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $component,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $component,
                               format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::parse("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn capture_and_filter() {
        let _ = capture(true);
        set_level(Level::Info);
        log(Level::Info, "qee", format_args!("plan ready jobs={}", 3));
        log(Level::Debug, "qee", format_args!("hidden"));
        let lines = capture(false);
        set_level(Level::Warn);
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("[qee] plan ready jobs=3"), "{lines:?}");
    }
}
