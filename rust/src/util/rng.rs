//! Deterministic PRNGs for the grid simulator, corpus generator and
//! property tests.
//!
//! Everything in GAPS that involves randomness (corpus synthesis, node
//! heterogeneity, network jitter, workload generation, property tests) is
//! seeded through [`Rng`], so every recorded experiment is exactly
//! reproducible from its recorded seed.
//!
//! The generator is xoshiro256** seeded via splitmix64 — tiny, fast, and
//! adequate statistical quality for simulation (not cryptography).

/// xoshiro256** PRNG with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // splitmix64 to spread the seed over the full state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent child generator (stable: depends only on the
    /// parent state *at call time* and the stream id).
    pub fn fork(&self, stream: u64) -> Self {
        Rng::new(
            self.s[0]
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                .wrapping_add(stream.wrapping_mul(0xd1b5_4a32_d192_ed03)),
        )
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform u64 in [0, n) (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "Rng::range empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli trial with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean and stddev.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Poisson-distributed count (Knuth for small lambda, normal approx for
    /// large) — used for per-field document lengths.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            return self.normal_ms(lambda, lambda.sqrt()).max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Exponential inter-arrival with given rate (events/sec).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.f64().max(1e-12).ln() / rate
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Sample an index proportional to `weights` (all >= 0, sum > 0).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Bounded Zipf(s) sampler over ranks [0, n) using the Gray et al. method
/// (as in YCSB): O(n) one-time precompute of the zeta constant, O(1) draws.
/// Used by the corpus generator for vocabulary and topic draws.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: usize,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
}

impl Zipf {
    /// Build a sampler over [0, n) with exponent `theta` (> 0, != 1 is not
    /// required; theta == 1 works because we never divide by 1 - theta with
    /// theta == 1... we clamp instead).
    pub fn new(n: usize, theta: f64) -> Self {
        assert!(n > 0, "Zipf over empty domain");
        let theta = if (theta - 1.0).abs() < 1e-9 { 1.0 + 1e-9 } else { theta };
        let zetan: f64 = (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum();
        let zeta2: f64 = (1..=2.min(n)).map(|i| 1.0 / (i as f64).powf(theta)).sum();
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipf { n, theta, alpha, zetan, eta }
    }

    /// Draw a rank in [0, n); rank 0 is the most popular.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let k = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as usize;
        k.min(self.n - 1)
    }

    /// Domain size.
    pub fn n(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn fork_is_stable_and_independent() {
        let parent = Rng::new(7);
        let mut c1 = parent.fork(0);
        let mut c2 = parent.fork(0);
        let mut c3 = parent.fork(1);
        assert_eq!(c1.next_u64(), c2.next_u64());
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut r = Rng::new(7);
        for &lambda in &[0.5, 4.0, 40.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.1,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(8);
        let z = Zipf::new(1000, 1.07);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            counts[k] += 1;
        }
        // Head should dominate tail for a Zipfian draw.
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[990..].iter().sum();
        assert!(head > tail * 10, "head={head} tail={tail}");
        assert!(counts[0] > counts[100], "rank 0 must beat rank 100");
    }

    #[test]
    fn zipf_single_element_domain() {
        let mut r = Rng::new(12);
        let z = Zipf::new(1, 1.0);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut r), 0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(10);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 30_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
