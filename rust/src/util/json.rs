//! Minimal JSON substrate (value model + parser + writer).
//!
//! GAPS uses JSON for everything the paper stores as documents: the Job
//! Description File (JDF) the Query Manager ships to workers, the
//! performance-history database, the artifact `manifest.json` emitted by
//! the python AOT path, search-result envelopes, and config overrides.
//! The vendored offline crate set has no serde, so this is a small
//! self-contained implementation: full JSON syntax, UTF-8 strings with
//! escapes, i64/f64 numbers, and a pretty/compact writer.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (stable golden files in tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integral numbers parse as `Int` when they fit i64 exactly.
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and message.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e18 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` that errors with context — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            offset: 0,
            msg: format!("missing required field '{key}'"),
        })
    }

    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------- writer

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(x) => {
                if x.is_finite() {
                    // Shortest roundtrip-ish: {:?} gives e.g. 1.5, 1e30.
                    out.push_str(&format!("{x:?}"));
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------------------------------------- parser

    /// Parse a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal (expected {word})")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xd800..0xdc00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xd800) << 10)
                                        + (lo.wrapping_sub(0xdc00));
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is &str so it's valid).
                    let rest = &self.bytes[self.pos..];
                    let st = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let c = st.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        if text.is_empty() || text == "-" {
            return Err(self.err("bad number"));
        }
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// From conversions used all over the JDF / result builders.
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<i64> for Json {
    fn from(i: i64) -> Self {
        Json::Int(i)
    }
}
impl From<usize> for Json {
    fn from(i: usize) -> Self {
        Json::Int(i as i64)
    }
}
impl From<u64> for Json {
    fn from(i: u64) -> Self {
        Json::Int(i as i64)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-17", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn int_vs_float() {
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("42.0").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("42.0").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "{\"a\":}", "1 2", "{'a':1}", "nul"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::Str("quote\" slash\\ nl\n tab\t unicode\u{1F600}ctrl\u{1}".into());
        let parsed = Json::parse(&original.to_string_compact()).unwrap();
        assert_eq!(parsed, original);
    }

    #[test]
    fn unicode_escape_parses() {
        let v = Json::parse(r#""A😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\u{1F600}");
    }

    #[test]
    fn object_ordering_is_deterministic() {
        let a = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        let b = Json::parse(r#"{"a":2,"z":1}"#).unwrap();
        assert_eq!(a.to_string_compact(), b.to_string_compact());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("jobs", Json::from(vec![1i64, 2, 3])),
            ("query", Json::str("grid search")),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn non_finite_floats_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
    }

    #[test]
    fn req_reports_missing_field() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert!(v.req("a").is_ok());
        let err = v.req("b").unwrap_err();
        assert!(err.msg.contains("'b'"));
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut v = Json::Int(7);
        for _ in 0..50 {
            v = Json::Arr(vec![v]);
        }
        let text = v.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn manifest_shape_parses() {
        // Mirrors python/compile/aot.py manifest structure.
        let text = r#"{
          "abi": {"fields": ["title","abstract","authors","venue"], "k1": 1.2,
                   "return_tuple": true},
          "artifacts": [{"name": "ranker_q1_d256_f512_k32",
                          "file": "ranker_q1_d256_f512_k32.hlo.txt",
                          "q": 1, "d": 256, "f": 512, "k": 32, "nf": 4}]
        }"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("d").unwrap().as_i64(), Some(256));
        assert_eq!(v.get("abi").unwrap().get("k1").unwrap().as_f64(), Some(1.2));
    }
}
