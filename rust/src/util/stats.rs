//! Summary statistics and histograms for the metrics / bench layers.

/// Online summary of a stream of samples plus exact percentiles
/// (keeps all samples; experiment scales here are small enough).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Summary::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.sum() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - m) * (x - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Exact percentile by nearest-rank (p in [0, 100]).
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let n = self.samples.len();
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        self.samples[rank.min(n) - 1]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-bucket latency histogram (log-spaced), cheap to merge.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [base * 2^i, base * 2^(i+1)) seconds
    buckets: Vec<u64>,
    base_s: f64,
    count: u64,
    sum_s: f64,
}

impl LatencyHistogram {
    /// `base_s` is the lower bound of bucket 0; 32 octaves above it.
    pub fn new(base_s: f64) -> Self {
        LatencyHistogram { buckets: vec![0; 32], base_s, count: 0, sum_s: 0.0 }
    }

    pub fn record(&mut self, seconds: f64) {
        let idx = if seconds <= self.base_s {
            0
        } else {
            ((seconds / self.base_s).log2().floor() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_s += seconds;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum_s / self.count as f64
        }
    }

    /// Upper edge of the bucket containing the p-th percentile sample.
    pub fn percentile_upper_bound_s(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.base_s * 2f64.powi(i as i32 + 1);
            }
        }
        self.base_s * 2f64.powi(self.buckets.len() as i32)
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.base_s, other.base_s, "histogram bases differ");
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_s += other.sum_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - 1.2909944487).abs() < 1e-6);
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p99(), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(1.0), 1.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn histogram_counts_and_bounds() {
        let mut h = LatencyHistogram::new(1e-4);
        for _ in 0..90 {
            h.record(1e-3); // bucket ~3
        }
        for _ in 0..10 {
            h.record(1.0); // much slower tail
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_upper_bound_s(50.0);
        let p99 = h.percentile_upper_bound_s(99.0);
        assert!(p50 < 0.01, "p50={p50}");
        assert!(p99 >= 1.0, "p99={p99}");
        assert!((h.mean_s() - (90.0 * 1e-3 + 10.0) / 100.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new(1e-4);
        let mut b = LatencyHistogram::new(1e-4);
        a.record(0.001);
        b.record(0.002);
        b.record(0.004);
        a.merge(&b);
        assert_eq!(a.count(), 3);
    }
}
