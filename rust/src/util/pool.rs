//! "gridpool" — the thread-pool substrate the simulated grid runs on.
//!
//! Each simulated grid node owns a long-lived worker thread (the analogue
//! of the paper's always-resident globus container: services are loaded
//! once and reused across queries, never cold-started per job). The pool
//! is a plain Mutex<VecDeque> + Condvar job queue; no tokio in the
//! offline vendored crate set, and the paper's concurrency pattern —
//! fan out search jobs, join on a barrier — maps directly onto this.
//!
//! [`Pool::scope`] is the borrow-friendly submit API: jobs may capture
//! references into the caller's stack because the scope blocks until
//! every job has drained. It is what lets the coordinator fan a batch
//! out over the *resident* gridpool (warm worker thread-locals, no
//! per-batch thread spawns) instead of `std::thread::scope`.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    signal: Condvar,
}

struct QueueState {
    pending: VecDeque<Job>,
    shutdown: bool,
}

/// Fixed-size worker pool with FIFO job dispatch.
pub struct Pool {
    queue: Arc<Queue>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn `n` resident workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "pool needs at least one worker");
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState { pending: VecDeque::new(), shutdown: false }),
            signal: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let q = Arc::clone(&queue);
                thread::Builder::new()
                    .name(format!("gridpool-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn worker")
            })
            .collect();
        Pool { queue, workers }
    }

    /// Number of resident workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job for any worker.
    pub fn submit<F>(&self, job: F)
    where
        F: FnOnce() + Send + 'static,
    {
        let mut state = self.queue.jobs.lock().unwrap();
        assert!(!state.shutdown, "submit after shutdown");
        state.pending.push_back(Box::new(job));
        drop(state);
        self.queue.signal.notify_one();
    }

    /// Submit a closure and get a handle to its result.
    pub fn submit_with_result<F, T>(&self, job: F) -> JobHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        self.submit(move || {
            // Receiver may have been dropped; that's fine.
            let _ = tx.send(job());
        });
        JobHandle { rx }
    }

    /// Run a scope whose jobs may borrow from the caller's stack.
    ///
    /// This is the borrow-friendly counterpart of [`par_map_scoped`] on
    /// the *resident* pool: jobs submitted through the [`PoolScope`] run
    /// on the long-lived workers (no per-call thread spawns, and worker
    /// thread-locals — e.g. the Search Service's retrieval scratches —
    /// stay warm across scopes), yet they may capture non-`'static`
    /// references because `scope` does not return until every submitted
    /// job has finished.
    ///
    /// If a scoped job panics, the panic is caught on the worker (the
    /// worker survives and keeps serving the pool) and re-raised from
    /// `scope` on the submitting thread after the drain.
    pub fn scope<'env, R>(&self, f: impl FnOnce(&PoolScope<'_, 'env>) -> R) -> R {
        let scope = PoolScope {
            pool: self,
            state: Arc::new(ScopeState::default()),
            env: PhantomData,
        };
        // `scope` is dropped (and its Drop drains) even if `f` panics, so
        // borrowed data is never freed while a job can still touch it.
        let result = f(&scope);
        scope.finish();
        result
    }

    /// Run `f` over `items` on the resident pool and collect the results
    /// in item order. Scoped version of [`Pool::map`]: `items`, `f` and
    /// the result buffer are borrowed, not moved, so callers keep using
    /// them afterwards.
    pub fn scope_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        let mut results: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
        let f = &f; // shared by every job closure (the jobs only need &F)
        self.scope(|s| {
            for (item, slot) in items.iter().zip(results.iter_mut()) {
                s.submit(move || *slot = Some(f(item)));
            }
        });
        results.into_iter().map(|r| r.expect("scoped job finished")).collect()
    }

    /// Run `f` over all items on the pool and collect results in order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<JobHandle<R>> = items
            .into_iter()
            .map(|item| {
                let f = Arc::clone(&f);
                self.submit_with_result(move || f(item))
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut state = self.queue.jobs.lock().unwrap();
            state.shutdown = true;
        }
        self.queue.signal.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Progress of one [`Pool::scope`]: outstanding jobs + panic count.
#[derive(Default)]
struct ScopeState {
    progress: Mutex<ScopeProgress>,
    done: Condvar,
}

#[derive(Default)]
struct ScopeProgress {
    pending: usize,
    panicked: usize,
}

impl ScopeState {
    fn begin(&self) {
        self.progress.lock().unwrap().pending += 1;
    }

    fn complete(&self, panicked: bool) {
        let mut p = self.progress.lock().unwrap();
        p.pending -= 1;
        if panicked {
            p.panicked += 1;
        }
        if p.pending == 0 {
            drop(p);
            self.done.notify_all();
        }
    }

    /// Block until every submitted job has finished; returns the panic
    /// count.
    fn drain(&self) -> usize {
        let mut p = self.progress.lock().unwrap();
        while p.pending > 0 {
            p = self.done.wait(p).unwrap();
        }
        p.panicked
    }
}

/// Handle for submitting borrow-carrying jobs inside a [`Pool::scope`]
/// call. `'env` is the lifetime of the data jobs may borrow; the scope
/// guarantees every job finishes before `'env` data can go away.
pub struct PoolScope<'pool, 'env> {
    pool: &'pool Pool,
    state: Arc<ScopeState>,
    /// Invariant over `'env` (mirrors `std::thread::Scope`): the borrow
    /// lifetime must not be shortened behind the scope's back.
    env: PhantomData<&'env mut &'env ()>,
}

impl<'env> PoolScope<'_, 'env> {
    /// Enqueue `job` on the resident workers. The job may borrow `'env`
    /// data; [`Pool::scope`] blocks until it has run.
    pub fn submit<F>(&self, job: F)
    where
        F: FnOnce() + Send + 'env,
    {
        self.state.begin();
        let state = Arc::clone(&self.state);
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(job);
        // SAFETY: the queue requires 'static jobs, but this job only
        // lives until `scope` returns: both the normal path (`finish`)
        // and the unwind path (`Drop`) drain the scope before giving
        // control back to the owner of the `'env` borrows. The panic is
        // caught so the counter is decremented (and the worker survives)
        // even when the job unwinds.
        let job = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'env>,
                Box<dyn FnOnce() + Send + 'static>,
            >(job)
        };
        self.pool.submit(move || {
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            state.complete(outcome.is_err());
        });
    }

    /// Drain and propagate job panics (normal exit path). The `Drop`
    /// drain that follows is a no-op: `pending` is already 0.
    fn finish(self) {
        let panicked = self.state.drain();
        if panicked > 0 {
            panic!("{panicked} scoped pool job(s) panicked");
        }
    }
}

impl Drop for PoolScope<'_, '_> {
    fn drop(&mut self) {
        // Unwind path (the scope closure panicked before `finish`): jobs
        // still borrow `'env` data further up the unwinding stack, so
        // block here until they are done. Job panics are swallowed — the
        // original panic is already propagating.
        self.state.drain();
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut state = q.jobs.lock().unwrap();
            loop {
                if let Some(job) = state.pending.pop_front() {
                    break job;
                }
                if state.shutdown {
                    return;
                }
                state = q.signal.wait(state).unwrap();
            }
        };
        job();
    }
}

/// Handle to a submitted job's result.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block until the job completes. Panics if the job panicked.
    pub fn join(self) -> T {
        self.rx.recv().expect("worker dropped result (job panicked?)")
    }

    /// Non-blocking poll.
    pub fn try_join(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

/// Scoped parallel map without a resident pool (std::thread::scope):
/// spawns fresh threads per call. Retained for one-shot contexts that
/// have no pool at hand; anything on a request path should prefer
/// [`Pool::scope`] / [`Pool::scope_map`], which reuse resident workers
/// (no spawn cost, warm worker thread-locals).
pub fn par_map_scoped<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    let mut results: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let chunk = items.len().div_ceil(threads);
    if chunk == 0 {
        return Vec::new();
    }
    thread::scope(|s| {
        for (chunk_items, chunk_results) in
            items.chunks(chunk).zip(results.chunks_mut(chunk))
        {
            s.spawn(|| {
                for (item, slot) in chunk_items.iter().zip(chunk_results.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    results.into_iter().map(|r| r.expect("scoped job finished")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..64)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit_with_result(move || c.fetch_add(1, Ordering::SeqCst))
            })
            .collect();
        for h in handles {
            h.join();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(3);
        let out = pool.map((0..100).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_many_rounds() {
        let pool = Pool::new(2);
        for round in 0..10 {
            let out = pool.map(vec![round; 8], |x| x + 1);
            assert_eq!(out, vec![round + 1; 8]);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = Pool::new(2);
            for _ in 0..16 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for queue drain because shutdown only stops
            // workers once pending is empty.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scoped_map_matches_serial() {
        let items: Vec<u64> = (0..57).collect();
        let out = par_map_scoped(&items, 4, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty_and_single() {
        let empty: Vec<u64> = vec![];
        assert!(par_map_scoped(&empty, 4, |x| *x).is_empty());
        assert_eq!(par_map_scoped(&[7u64], 4, |x| *x + 1), vec![8]);
    }

    #[test]
    fn scope_jobs_borrow_caller_stack() {
        // The whole point of `scope`: jobs write through non-'static
        // borrows, on resident workers.
        let pool = Pool::new(4);
        let items: Vec<u64> = (0..40).collect();
        let mut out = vec![0u64; items.len()];
        pool.scope(|s| {
            for (item, slot) in items.iter().zip(out.iter_mut()) {
                s.submit(move || *slot = item * 3);
            }
        });
        assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_matches_serial_and_preserves_order() {
        let pool = Pool::new(3);
        let items: Vec<u64> = (0..57).collect();
        let out = pool.scope_map(&items, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
        assert!(pool.scope_map(&[] as &[u64], |x| *x).is_empty());
        assert_eq!(pool.scope_map(&[7u64], |x| x + 1), vec![8]);
    }

    #[test]
    fn scope_drains_before_returning() {
        // Shutdown-drain: by the time `scope` returns, every job has run
        // to completion — no job may still touch the borrowed counter.
        let pool = Pool::new(2);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..16 {
                let c = &counter;
                s.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        // And the pool shuts down cleanly afterwards (Drop joins workers).
        drop(pool);
    }

    #[test]
    fn scope_runs_on_resident_workers() {
        // Two scopes on a 1-worker pool run on the *same* OS thread —
        // the residency that keeps per-thread scratches warm.
        let pool = Pool::new(1);
        let mut first = None;
        let mut second = None;
        pool.scope(|s| {
            let slot = &mut first;
            s.submit(move || *slot = Some(std::thread::current().id()));
        });
        pool.scope(|s| {
            let slot = &mut second;
            s.submit(move || *slot = Some(std::thread::current().id()));
        });
        assert_eq!(first.expect("ran"), second.expect("ran"));
    }

    #[test]
    fn scope_propagates_job_panic_but_pool_survives() {
        let pool = Pool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.submit(|| panic!("scoped job boom"));
            });
        }));
        assert!(caught.is_err(), "scope must re-raise the job panic");
        // Workers caught the unwind and keep serving.
        assert_eq!(pool.scope_map(&[1u64, 2, 3], |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_scope_returns_value() {
        let pool = Pool::new(2);
        let v = pool.scope(|_| 42);
        assert_eq!(v, 42);
    }

    #[test]
    fn try_join_eventually_ready() {
        let pool = Pool::new(1);
        let h = pool.submit_with_result(|| 42);
        let mut val = None;
        for _ in 0..1000 {
            if let Some(v) = h.try_join() {
                val = Some(v);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(val, Some(42));
    }
}
