//! "gridpool" — the thread-pool substrate the simulated grid runs on.
//!
//! Each simulated grid node owns a long-lived worker thread (the analogue
//! of the paper's always-resident globus container: services are loaded
//! once and reused across queries, never cold-started per job). The pool
//! is a plain Mutex<VecDeque> + Condvar job queue; no tokio in the
//! offline vendored crate set, and the paper's concurrency pattern —
//! fan out search jobs, join on a barrier — maps directly onto this.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    signal: Condvar,
}

struct QueueState {
    pending: VecDeque<Job>,
    shutdown: bool,
}

/// Fixed-size worker pool with FIFO job dispatch.
pub struct Pool {
    queue: Arc<Queue>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn `n` resident workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "pool needs at least one worker");
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState { pending: VecDeque::new(), shutdown: false }),
            signal: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let q = Arc::clone(&queue);
                thread::Builder::new()
                    .name(format!("gridpool-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn worker")
            })
            .collect();
        Pool { queue, workers }
    }

    /// Number of resident workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job for any worker.
    pub fn submit<F>(&self, job: F)
    where
        F: FnOnce() + Send + 'static,
    {
        let mut state = self.queue.jobs.lock().unwrap();
        assert!(!state.shutdown, "submit after shutdown");
        state.pending.push_back(Box::new(job));
        drop(state);
        self.queue.signal.notify_one();
    }

    /// Submit a closure and get a handle to its result.
    pub fn submit_with_result<F, T>(&self, job: F) -> JobHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        self.submit(move || {
            // Receiver may have been dropped; that's fine.
            let _ = tx.send(job());
        });
        JobHandle { rx }
    }

    /// Run `f` over all items on the pool and collect results in order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<JobHandle<R>> = items
            .into_iter()
            .map(|item| {
                let f = Arc::clone(&f);
                self.submit_with_result(move || f(item))
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut state = self.queue.jobs.lock().unwrap();
            state.shutdown = true;
        }
        self.queue.signal.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut state = q.jobs.lock().unwrap();
            loop {
                if let Some(job) = state.pending.pop_front() {
                    break job;
                }
                if state.shutdown {
                    return;
                }
                state = q.signal.wait(state).unwrap();
            }
        };
        job();
    }
}

/// Handle to a submitted job's result.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block until the job completes. Panics if the job panicked.
    pub fn join(self) -> T {
        self.rx.recv().expect("worker dropped result (job panicked?)")
    }

    /// Non-blocking poll.
    pub fn try_join(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

/// Scoped parallel map without a resident pool (std::thread::scope):
/// used where task-local borrows make the 'static pool inconvenient.
pub fn par_map_scoped<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    let mut results: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let chunk = items.len().div_ceil(threads);
    if chunk == 0 {
        return Vec::new();
    }
    thread::scope(|s| {
        for (chunk_items, chunk_results) in
            items.chunks(chunk).zip(results.chunks_mut(chunk))
        {
            s.spawn(|| {
                for (item, slot) in chunk_items.iter().zip(chunk_results.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    results.into_iter().map(|r| r.expect("scoped job finished")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..64)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit_with_result(move || c.fetch_add(1, Ordering::SeqCst))
            })
            .collect();
        for h in handles {
            h.join();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(3);
        let out = pool.map((0..100).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_many_rounds() {
        let pool = Pool::new(2);
        for round in 0..10 {
            let out = pool.map(vec![round; 8], |x| x + 1);
            assert_eq!(out, vec![round + 1; 8]);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = Pool::new(2);
            for _ in 0..16 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for queue drain because shutdown only stops
            // workers once pending is empty.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scoped_map_matches_serial() {
        let items: Vec<u64> = (0..57).collect();
        let out = par_map_scoped(&items, 4, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty_and_single() {
        let empty: Vec<u64> = vec![];
        assert!(par_map_scoped(&empty, 4, |x| *x).is_empty());
        assert_eq!(par_map_scoped(&[7u64], 4, |x| *x + 1), vec![8]);
    }

    #[test]
    fn try_join_eventually_ready() {
        let pool = Pool::new(1);
        let h = pool.submit_with_result(|| 42);
        let mut val = None;
        for _ in 0..1000 {
            if let Some(v) = h.try_join() {
                val = Some(v);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(val, Some(42));
    }
}
