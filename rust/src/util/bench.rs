//! Criterion-less micro/macro benchmark harness.
//!
//! The offline crate set has no criterion, so `cargo bench` targets link
//! this harness instead (`harness = false` in Cargo.toml). It provides
//! warmup, a fixed-iteration or fixed-duration measurement loop, and
//! mean/p50/p99 reporting, plus a small table printer the figure benches
//! use to emit the same rows the paper's figures plot. Benches also write
//! CSV series next to the binary (target/bench_csv/) for replotting.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// One measured series: name -> samples (seconds).
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    pub iterations: usize,
}

impl BenchResult {
    pub fn report_line(&mut self) -> String {
        format!(
            "{:<44} iters={:<5} mean={:>10.4}ms p50={:>10.4}ms p99={:>10.4}ms",
            self.name,
            self.iterations,
            self.summary.mean() * 1e3,
            self.summary.p50() * 1e3,
            self.summary.p99() * 1e3,
        )
    }
}

/// Benchmark runner with warmup + measurement phases.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    min_iters: usize,
    max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(500),
            min_iters: 3,
            max_iters: 100_000,
        }
    }

    pub fn with_measure(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    pub fn with_min_iters(mut self, n: usize) -> Self {
        self.min_iters = n;
        self
    }

    /// Measure `f` (each call is one iteration).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w = Instant::now();
        while w.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut summary = Summary::new();
        let started = Instant::now();
        let mut iters = 0usize;
        while (started.elapsed() < self.measure || iters < self.min_iters)
            && iters < self.max_iters
        {
            let t = Instant::now();
            f();
            summary.add(t.elapsed().as_secs_f64());
            iters += 1;
        }
        BenchResult { name: name.to_string(), summary, iterations: iters }
    }
}

/// Prevent the optimizer from eliding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for paper-figure rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering for replotting.
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV into target/bench_csv/<name>.csv (best effort).
    pub fn write_csv(&self, name: &str) {
        let dir = std::path::Path::new("target/bench_csv");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{name}.csv")), self.to_csv());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures_something() {
        let b = Bencher::quick().with_measure(Duration::from_millis(30));
        let mut r = b.run("noop", || {
            black_box(1 + 1);
        });
        assert!(r.iterations >= 3);
        assert!(r.summary.mean() >= 0.0);
        assert!(r.report_line().contains("noop"));
    }

    #[test]
    fn bencher_ordering_sane() {
        let b = Bencher::quick().with_measure(Duration::from_millis(50));
        // A multiply-chain: LLVM cannot closed-form it (unlike a plain
        // range sum, which release builds reduce to n*(n-1)/2).
        fn spin(n: u64) -> u64 {
            let mut x = 0u64;
            for i in 0..n {
                x = x.wrapping_mul(31).wrapping_add(i);
            }
            x
        }
        let mut fast = b.run("fast", || {
            black_box(spin(black_box(10)));
        });
        let mut slow = b.run("slow", || {
            black_box(spin(black_box(100_000)));
        });
        assert!(slow.summary.p50() > fast.summary.p50());
    }

    #[test]
    fn table_renders_and_csv() {
        let mut t = Table::new(&["nodes", "gaps_ms", "trad_ms"]);
        t.row(vec!["2".into(), "100.0".into(), "155.0".into()]);
        t.row(vec!["11".into(), "60.0".into(), "104.0".into()]);
        let text = t.render();
        assert!(text.contains("nodes"));
        assert!(text.lines().count() == 4);
        let csv = t.to_csv();
        assert_eq!(csv.lines().next().unwrap(), "nodes,gaps_ms,trad_ms");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
