//! Property-testing helper (proptest is not in the offline crate set).
//!
//! `check` runs a property over N generated cases; on failure it performs
//! a bounded greedy shrink by re-generating from derived seeds with a
//! "size" knob that shrinks toward minimal cases, then reports the seed so
//! the failure is reproducible (`GAPS_PROP_SEED=<seed>` re-runs one case).
//!
//! Generators are plain closures `Fn(&mut Rng, usize /*size*/) -> T`, so
//! domain modules define generators next to their types (see
//! rust/tests/prop_invariants.rs).

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Max generation size; cases sweep sizes 1..=max_size cyclically.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("GAPS_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases: 100, seed, max_size: 40 }
    }
}

/// Outcome of a single case.
pub enum CaseResult {
    Pass,
    /// Failure with a human-readable description of the case.
    Fail(String),
}

impl From<bool> for CaseResult {
    fn from(ok: bool) -> Self {
        if ok {
            CaseResult::Pass
        } else {
            CaseResult::Fail("property returned false".into())
        }
    }
}

impl From<Result<(), String>> for CaseResult {
    fn from(r: Result<(), String>) -> Self {
        match r {
            Ok(()) => CaseResult::Pass,
            Err(e) => CaseResult::Fail(e),
        }
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panics with the seed and
/// smallest failing size on failure.
pub fn check<T, G, P, R>(name: &str, cfg: &Config, generate: G, prop: P)
where
    G: Fn(&mut Rng, usize) -> T,
    P: Fn(&T) -> R,
    R: Into<CaseResult>,
    T: std::fmt::Debug,
{
    let mut failure: Option<(u64, usize, String)> = None;
    'outer: for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 1 + (case % cfg.max_size);
        let mut rng = Rng::new(case_seed);
        let input = generate(&mut rng, size);
        if let CaseResult::Fail(msg) = prop(&input).into() {
            // Greedy shrink: try smaller sizes with the same seed.
            let mut best = (case_seed, size, msg);
            for s in 1..size {
                let mut rng = Rng::new(case_seed);
                let input = generate(&mut rng, s);
                if let CaseResult::Fail(msg2) = prop(&input).into() {
                    best = (case_seed, s, msg2);
                    break;
                }
            }
            failure = Some(best);
            break 'outer;
        }
    }
    if let Some((seed, size, msg)) = failure {
        let mut rng = Rng::new(seed);
        let input = generate(&mut rng, size);
        panic!(
            "property '{name}' failed (seed={seed}, size={size}):\n  {msg}\n  input: {input:?}\n  \
             reproduce with GAPS_PROP_SEED={seed}"
        );
    }
}

// ------------------------------------------------------ common generators

/// Vec of f64 in [lo, hi) with length in [0, size].
pub fn gen_f64_vec(rng: &mut Rng, size: usize, lo: f64, hi: f64) -> Vec<f64> {
    let n = rng.range(0, size + 1);
    (0..n).map(|_| rng.range_f64(lo, hi)).collect()
}

/// Vec of usize below `bound` with length in [0, size].
pub fn gen_usize_vec(rng: &mut Rng, size: usize, bound: usize) -> Vec<usize> {
    let n = rng.range(0, size + 1);
    (0..n).map(|_| rng.range(0, bound.max(1))).collect()
}

/// Lowercase ASCII word of length 1..=8.
pub fn gen_word(rng: &mut Rng) -> String {
    let n = rng.range(1, 9);
    (0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
}

/// Whitespace-joined text of up to `size` words.
pub fn gen_text(rng: &mut Rng, size: usize) -> String {
    let n = rng.range(0, size + 1);
    (0..n).map(|_| gen_word(rng)).collect::<Vec<_>>().join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let cfg = Config { cases: 50, seed: 1, max_size: 20 };
        check("sum-commutes", &cfg, |rng, size| gen_f64_vec(rng, size, 0.0, 1.0), |xs| {
            let fwd: f64 = xs.iter().sum();
            let rev: f64 = xs.iter().rev().sum();
            (fwd - rev).abs() < 1e-9
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-small'")]
    fn failing_property_panics_with_seed() {
        let cfg = Config { cases: 200, seed: 2, max_size: 30 };
        check(
            "always-small",
            &cfg,
            |rng, size| gen_usize_vec(rng, size, 1000),
            |xs| xs.len() < 5, // false for size >= 5 eventually
        );
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Rng::new(3);
        for size in 1..30 {
            let v = gen_f64_vec(&mut rng, size, -2.0, 3.0);
            assert!(v.len() <= size);
            assert!(v.iter().all(|x| (-2.0..3.0).contains(x)));
            let u = gen_usize_vec(&mut rng, size, 7);
            assert!(u.iter().all(|&x| x < 7));
            let w = gen_word(&mut rng);
            assert!((1..=8).contains(&w.len()));
            assert!(w.chars().all(|c| c.is_ascii_lowercase()));
        }
    }

    #[test]
    fn shrink_reports_smaller_case() {
        // Catch the panic and confirm the reported size is minimal-ish.
        let res = std::panic::catch_unwind(|| {
            let cfg = Config { cases: 100, seed: 4, max_size: 40 };
            check(
                "len-lt-3",
                &cfg,
                |rng, size| gen_usize_vec(rng, size, 10),
                |xs| xs.len() < 3,
            );
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("seed="), "{msg}");
    }
}
