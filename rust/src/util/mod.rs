//! Infrastructure substrates (offline build: no serde/clap/tokio/criterion
//! in the vendored crate set, so GAPS carries its own minimal versions).

pub mod bench;
pub mod cli;
pub mod clock;
pub mod json;
pub mod log;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
