//! Flag-style CLI argument parser (clap is not in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, repeated flags,
//! positional arguments and subcommands. Used by the `gaps` binary, the
//! examples and the bench harness, all of which share one grammar:
//!
//! ```text
//! gaps <subcommand> [--flag] [--key value]... [positional]...
//! ```

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, flags and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token, if declared as a subcommand grammar.
    pub subcommand: Option<String>,
    /// --key value / --key=value pairs; repeated keys keep all values.
    flags: BTreeMap<String, Vec<String>>,
    /// Bare positionals (after subcommand).
    pub positionals: Vec<String>,
}

/// Parse error with the offending token.
#[derive(Debug, Clone, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw tokens. `with_subcommand` makes the first bare token the
    /// subcommand; boolean flags are those listed in `bool_flags`
    /// (they consume no value).
    pub fn parse(
        tokens: &[String],
        with_subcommand: bool,
        bool_flags: &[&str],
    ) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` separator: rest are positionals.
                    args.positionals.extend(tokens[i + 1..].iter().cloned());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if bool_flags.contains(&body) {
                    args.flags.entry(body.to_string()).or_default().push("true".into());
                } else {
                    let v = tokens
                        .get(i + 1)
                        .ok_or_else(|| CliError(format!("--{body} expects a value")))?;
                    if v.starts_with("--") {
                        return Err(CliError(format!("--{body} expects a value")));
                    }
                    args.flags.entry(body.to_string()).or_default().push(v.clone());
                    i += 1;
                }
            } else if with_subcommand && args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positionals.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env(with_subcommand: bool, bool_flags: &[&str]) -> Result<Args, CliError> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&tokens, with_subcommand, bool_flags)
    }

    /// Last value of a flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeated flag.
    pub fn get_all(&self, key: &str) -> &[String] {
        self.flags.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Typed lookup with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError(format!("--{key}: cannot parse {s:?}"))),
        }
    }

    /// Boolean flag presence.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn subcommand_flags_positionals() {
        let a = Args::parse(
            &toks("search --nodes 8 --vos=3 grid computing --verbose"),
            true,
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("search"));
        assert_eq!(a.get("nodes"), Some("8"));
        assert_eq!(a.get("vos"), Some("3"));
        assert!(a.has("verbose"));
        assert_eq!(a.positionals, vec!["grid", "computing"]);
    }

    #[test]
    fn typed_parse_and_defaults() {
        let a = Args::parse(&toks("--nodes 8"), false, &[]).unwrap();
        assert_eq!(a.get_parse("nodes", 1usize).unwrap(), 8);
        assert_eq!(a.get_parse("missing", 5usize).unwrap(), 5);
        let bad = Args::parse(&toks("--nodes eight"), false, &[]).unwrap();
        assert!(bad.get_parse("nodes", 1usize).is_err());
    }

    #[test]
    fn repeated_flags_accumulate() {
        let a = Args::parse(&toks("--field title --field abstract"), false, &[]).unwrap();
        assert_eq!(a.get_all("field"), &["title", "abstract"]);
        assert_eq!(a.get("field"), Some("abstract")); // last wins
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&toks("--nodes"), false, &[]).is_err());
        assert!(Args::parse(&toks("--nodes --other 3"), false, &[]).is_err());
    }

    #[test]
    fn double_dash_stops_flag_parsing() {
        let a = Args::parse(&toks("query -- --not-a-flag"), true, &[]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("query"));
        assert_eq!(a.positionals, vec!["--not-a-flag"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&toks("--k=v=w"), false, &[]).unwrap();
        assert_eq!(a.get("k"), Some("v=w"));
    }
}
