//! Clocks and timers.
//!
//! The grid fabric is simulated in-process, but the *work* (tokenizing,
//! index probes, XLA execution) is real; experiment timing therefore mixes
//! two time sources:
//!
//! * [`WallClock`] — monotonic real time, used for all measured work.
//! * [`SimClock`] — a logical clock used by the network model to account
//!   for transfer/launch delays the simulated fabric would add (the paper's
//!   testbed paid real Globus/GridFTP latencies; we account for them
//!   explicitly so they are visible and tunable rather than implicit).
//!
//! A [`TaskTimeline`] combines both: real measured durations plus simulated
//! delay components, which is what the metrics layer reports.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic wall-clock timer.
#[derive(Debug, Clone, Copy)]
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn start() -> Self {
        WallClock { start: Instant::now() }
    }

    /// Seconds elapsed since `start()`.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Microseconds elapsed since `start()`.
    pub fn elapsed_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }
}

/// Thread-safe logical clock, microsecond resolution. Advancing is
/// monotonic; independent components may account delays concurrently.
#[derive(Debug, Default)]
pub struct SimClock {
    now_us: AtomicU64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { now_us: AtomicU64::new(0) }
    }

    /// Current simulated time in microseconds.
    pub fn now_us(&self) -> u64 {
        self.now_us.load(Ordering::Relaxed)
    }

    /// Advance by `us` and return the new time.
    pub fn advance_us(&self, us: u64) -> u64 {
        self.now_us.fetch_add(us, Ordering::Relaxed) + us
    }

    /// Move the clock forward to at least `t_us` (no-op if already past).
    pub fn advance_to_us(&self, t_us: u64) {
        self.now_us.fetch_max(t_us, Ordering::Relaxed);
    }
}

/// Per-task time accounting: real measured work plus simulated fabric
/// delays, kept separate so benches can report both and their sum.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TaskTimeline {
    /// Real, measured compute time (seconds).
    pub work_s: f64,
    /// Simulated network transfer time (seconds).
    pub net_s: f64,
    /// Simulated job launch / service overhead (seconds).
    pub overhead_s: f64,
}

impl TaskTimeline {
    pub fn total_s(&self) -> f64 {
        self.work_s + self.net_s + self.overhead_s
    }

    /// Element-wise accumulate (for sequential phases on one node).
    pub fn add(&mut self, other: TaskTimeline) {
        self.work_s += other.work_s;
        self.net_s += other.net_s;
        self.overhead_s += other.overhead_s;
    }

    /// Max-combine (for parallel branches joined by a barrier): the
    /// response time of a fan-out is the slowest branch.
    pub fn max(self, other: TaskTimeline) -> TaskTimeline {
        if self.total_s() >= other.total_s() {
            self
        } else {
            other
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_monotonic() {
        let c = WallClock::start();
        let a = c.elapsed_s();
        let b = c.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn sim_clock_advances() {
        let c = SimClock::new();
        assert_eq!(c.now_us(), 0);
        assert_eq!(c.advance_us(10), 10);
        assert_eq!(c.advance_us(5), 15);
        c.advance_to_us(12); // behind current: no-op
        assert_eq!(c.now_us(), 15);
        c.advance_to_us(100);
        assert_eq!(c.now_us(), 100);
    }

    #[test]
    fn timeline_add_and_total() {
        let mut t = TaskTimeline { work_s: 1.0, net_s: 0.5, overhead_s: 0.1 };
        t.add(TaskTimeline { work_s: 0.5, net_s: 0.5, overhead_s: 0.0 });
        assert!((t.total_s() - 2.6).abs() < 1e-12);
    }

    #[test]
    fn timeline_max_picks_slowest_branch() {
        let fast = TaskTimeline { work_s: 0.1, net_s: 0.0, overhead_s: 0.0 };
        let slow = TaskTimeline { work_s: 0.0, net_s: 0.5, overhead_s: 0.0 };
        assert_eq!(fast.max(slow), slow);
        assert_eq!(slow.max(fast), slow);
    }
}
