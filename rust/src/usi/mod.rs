//! User Search Interface (USI).
//!
//! Paper §III.4: "an interaction mechanism proposed to provide the end
//! user access point to deal with the system ... provides keyword-based
//! and multivariate-based search types ... the USI overhead is very small
//! as compared with the response time."
//!
//! Two modes: one-shot ([`one_shot`] / [`one_shot_request`]) used by the
//! `gaps search` subcommand and examples, and an interactive REPL
//! ([`repl`]) for the `gaps repl` subcommand. Both build typed
//! [`SearchRequest`]s and report typed [`SearchError`]s. The USI layer is
//! deliberately thin — its cost is measured by `benches/usi_overhead.rs`
//! to validate the paper's overhead claim.
//!
//! The USI is one of three entry points over the same typed surface: the
//! CLI/REPL here serve a single interactive user, while the
//! [`crate::serve`] HTTP front-end serves many concurrent users through
//! the admission queue (same requests, same JSON wire forms, same
//! responses — `:batch a | b` in the REPL and two coalesced `POST
//! /search` calls produce identical hits).

use std::io::{BufRead, Write};

use crate::coordinator::{GapsSystem, SearchResponse};
use crate::search::{SearchError, SearchRequest};
use crate::util::clock::WallClock;

/// Render a search response the way the USI displays it.
pub fn format_response(resp: &SearchResponse) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "query: {:?}  ({} jobs, {} candidates, {} docs scanned)\n",
        resp.query, resp.jobs, resp.candidates, resp.docs_scanned
    ));
    out.push_str(&format!(
        "response time: {:.2} ms  (work {:.2} + net {:.2} + overhead {:.2})\n",
        resp.response_s() * 1e3,
        resp.timeline.work_s * 1e3,
        resp.timeline.net_s * 1e3,
        resp.timeline.overhead_s * 1e3,
    ));
    if let Some(explain) = &resp.explain {
        out.push_str(&format!(
            "explain: ast={}  keywords={:?}  batch={}\n",
            explain.ast, explain.keywords, explain.batch_size
        ));
        let c = &explain.counters;
        out.push_str(&format!(
            "explain: retrieval touched {}/{} postings ({:.1}% skipped), \
             {} blocks skipped, {} candidates\n",
            c.postings_touched,
            c.postings_total,
            c.skipped_fraction() * 100.0,
            c.blocks_skipped,
            c.candidates_emitted,
        ));
        for (node, sources) in &explain.plan {
            out.push_str(&format!("explain: {node} <- {sources} sources\n"));
        }
    }
    if resp.hits.is_empty() {
        out.push_str("no results.\n");
    }
    for (rank, hit) in resp.hits.iter().enumerate() {
        out.push_str(&format!(
            "{:>3}. [{:>8.3}] #{:<8} {}\n",
            rank + 1,
            hit.score,
            hit.global_id,
            hit.title
        ));
    }
    out
}

/// USI timing envelope: interface work (parse/format) vs grid time.
#[derive(Debug, Clone, Copy)]
pub struct UsiTiming {
    /// Seconds spent inside the USI layer itself.
    pub interface_s: f64,
    /// Seconds the grid spent answering.
    pub grid_s: f64,
}

impl UsiTiming {
    /// The paper's claim, made checkable: interface share of total.
    pub fn interface_fraction(&self) -> f64 {
        self.interface_s / (self.interface_s + self.grid_s).max(1e-12)
    }
}

/// One-shot raw-text query through the USI with the overhead split
/// measured.
pub fn one_shot(sys: &mut GapsSystem, query: &str) -> Result<(String, UsiTiming), SearchError> {
    let iface = WallClock::start();
    let request = SearchRequest::new(query.trim()); // input handling
    let pre_s = iface.elapsed_s();
    one_shot_prepared(sys, &request, pre_s)
}

/// One-shot typed request through the USI.
pub fn one_shot_request(
    sys: &mut GapsSystem,
    request: &SearchRequest,
) -> Result<(String, UsiTiming), SearchError> {
    one_shot_prepared(sys, request, 0.0)
}

fn one_shot_prepared(
    sys: &mut GapsSystem,
    request: &SearchRequest,
    pre_s: f64,
) -> Result<(String, UsiTiming), SearchError> {
    let resp = sys.search_request(request)?;
    let grid_s = resp.response_s();

    let fmt_clock = WallClock::start();
    let rendered = format_response(&resp);
    let interface_s = pre_s + fmt_clock.elapsed_s();
    Ok((rendered, UsiTiming { interface_s, grid_s }))
}

/// Interactive REPL over stdin/stdout (the `gaps repl` subcommand).
/// Commands: a query per line; `:quit` exits; `:batch a | b | c` runs a
/// request batch in one fan-out; `:topk N` / `:explain` set session
/// request knobs; `:fail <node>` / `:recover <node>` exercise grid
/// dynamicity; `:stats` shows the job table.
pub fn repl(
    sys: &mut GapsSystem,
    input: impl BufRead,
    mut output: impl Write,
) -> Result<(), SearchError> {
    writeln!(output, "GAPS USI — type a query, :help for commands")?;
    let mut top_k: Option<usize> = None;
    let mut explain = false;
    let build = |query: &str, top_k: Option<usize>, explain: bool| {
        let mut req = SearchRequest::new(query).explain(explain);
        if let Some(k) = top_k {
            req = req.top_k(k);
        }
        req
    };
    for line in input.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(cmd) = line.strip_prefix(':') {
            let mut parts = cmd.split_whitespace();
            match parts.next() {
                Some("quit") | Some("q") => break,
                Some("help") => {
                    writeln!(
                        output,
                        ":quit  :stats  :batch q1 | q2 | ...  :topk N  :explain  \
                         :fail <node#>  :recover <node#>  — anything else is a query"
                    )?;
                }
                Some("stats") => {
                    writeln!(
                        output,
                        "jobs total={} completed={}",
                        sys.query_manager().total_jobs(),
                        sys.query_manager().completed_jobs()
                    )?;
                }
                Some("topk") => match parts.next().and_then(|s| s.parse::<usize>().ok()) {
                    Some(k) => {
                        top_k = Some(k);
                        writeln!(output, "top_k={k} for this session")?;
                    }
                    None => writeln!(output, "usage: :topk <n>")?,
                },
                Some("explain") => {
                    explain = !explain;
                    writeln!(output, "explain={explain}")?;
                }
                Some("batch") => {
                    let rest = cmd.strip_prefix("batch").unwrap_or("").trim();
                    let requests: Vec<SearchRequest> = rest
                        .split('|')
                        .map(str::trim)
                        .filter(|q| !q.is_empty())
                        .map(|q| build(q, top_k, explain))
                        .collect();
                    if requests.is_empty() {
                        writeln!(output, "usage: :batch query1 | query2 | ...")?;
                        continue;
                    }
                    let n = requests.len();
                    for (i, result) in sys.search_batch(&requests).into_iter().enumerate() {
                        writeln!(output, "--- batch {}/{} ---", i + 1, n)?;
                        match result {
                            Ok(resp) => write!(output, "{}", format_response(&resp))?,
                            Err(e) => writeln!(output, "error: {e}")?,
                        }
                    }
                }
                Some("fail") => match parts.next().and_then(|s| s.parse::<u32>().ok()) {
                    Some(n) => {
                        sys.fail_node(crate::grid::NodeId(n));
                        writeln!(output, "node{n} marked down")?;
                    }
                    None => writeln!(output, "usage: :fail <node#>")?,
                },
                Some("recover") => match parts.next().and_then(|s| s.parse::<u32>().ok()) {
                    Some(n) => {
                        sys.recover_node(crate::grid::NodeId(n));
                        writeln!(output, "node{n} recovered")?;
                    }
                    None => writeln!(output, "usage: :recover <node#>")?,
                },
                _ => writeln!(output, "unknown command; :help")?,
            }
            continue;
        }
        match one_shot_request(sys, &build(line, top_k, explain)) {
            Ok((rendered, timing)) => {
                write!(output, "{rendered}")?;
                writeln!(
                    output,
                    "usi overhead: {:.3} ms ({:.2}% of total)",
                    timing.interface_s * 1e3,
                    timing.interface_fraction() * 100.0
                )?;
            }
            Err(e) => writeln!(output, "error: {e}")?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GapsConfig;

    fn system() -> GapsSystem {
        let mut cfg = GapsConfig::default();
        cfg.workload.num_docs = 400;
        cfg.workload.sub_shards = 8;
        cfg.search.use_xla = false;
        GapsSystem::deploy(cfg, 3).unwrap()
    }

    #[test]
    fn one_shot_renders_hits_and_timing() {
        let mut sys = system();
        let title = sys.deployment().publication(7).unwrap().title.clone();
        let (rendered, timing) = one_shot(&mut sys, &title).unwrap();
        assert!(rendered.contains("response time"));
        assert!(rendered.contains("#7") || rendered.contains(" 7 "), "{rendered}");
        assert!(timing.grid_s > 0.0);
        // The paper's USI claim: interface is a small share.
        assert!(timing.interface_fraction() < 0.5, "{timing:?}");
    }

    #[test]
    fn one_shot_request_renders_explain() {
        let mut sys = system();
        let req = SearchRequest::new("grid data").top_k(3).explain(true);
        let (rendered, _) = one_shot_request(&mut sys, &req).unwrap();
        assert!(rendered.contains("explain: ast="), "{rendered}");
    }

    #[test]
    fn format_handles_empty_results() {
        let resp = SearchResponse {
            query: "x".into(),
            hits: vec![],
            timeline: Default::default(),
            jobs: 0,
            candidates: 0,
            docs_scanned: 0,
            degraded: false,
            missing_sources: Vec::new(),
            explain: None,
            trace: None,
        };
        assert!(format_response(&resp).contains("no results"));
    }

    #[test]
    fn repl_runs_queries_and_commands() {
        let mut sys = system();
        let input = ":help\ngrid computing\n:stats\n:fail 1\n:recover 1\n:bogus\n:quit\n";
        let mut out = Vec::new();
        repl(&mut sys, std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("response time"));
        assert!(text.contains("jobs total="));
        assert!(text.contains("node1 marked down"));
        assert!(text.contains("node1 recovered"));
        assert!(text.contains("unknown command"));
    }

    #[test]
    fn repl_batch_and_knobs() {
        let mut sys = system();
        let input = ":topk 2\n:explain\n:batch grid computing | data search | the of\n:quit\n";
        let mut out = Vec::new();
        repl(&mut sys, std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("top_k=2"));
        assert!(text.contains("explain=true"));
        assert!(text.contains("--- batch 1/3 ---"));
        assert!(text.contains("--- batch 3/3 ---"));
        assert!(text.contains("explain: ast="), "{text}");
        assert!(text.contains("error: query error"), "{text}");
    }

    #[test]
    fn repl_reports_query_errors() {
        let mut sys = system();
        let input = "the of and\n:quit\n";
        let mut out = Vec::new();
        repl(&mut sys, std::io::Cursor::new(input), &mut out).unwrap();
        assert!(String::from_utf8(out).unwrap().contains("error:"));
    }
}
