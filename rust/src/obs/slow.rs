//! Slow-query log: a bounded in-memory ring of structured entries for
//! requests whose end-to-end time crossed `obs.slow_query_ms`, exposed
//! via `GET /debug/slow` and optionally appended as JSONL to a file
//! (`--slow-log FILE`).
//!
//! One entry is one line: fingerprint, query, shard, epoch, total
//! seconds, the stage-timing span tree, retrieval counters, and the
//! degraded/error disposition — everything needed to retell a slow
//! request without re-running it.

use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::Path;
use std::sync::Mutex;

use crate::obs::trace::TraceSpan;
use crate::util::json::Json;

/// One slow (or failed-slow) request.
#[derive(Debug, Clone, PartialEq)]
pub struct SlowEntry {
    /// Compiled-plan fingerprint (0 when compilation never happened).
    pub fingerprint: u64,
    pub query: String,
    /// Executor shard that served the request.
    pub shard: usize,
    /// Index epoch at execution time.
    pub epoch: u64,
    /// End-to-end seconds (arrival → settled).
    pub total_s: f64,
    pub degraded: bool,
    /// Error kind for requests that settled with an error.
    pub error: Option<String>,
    /// Aggregated retrieval counters, when the request executed.
    pub counters: Option<Json>,
    /// Stage-timing tree (`request` root).
    pub stages: Option<TraceSpan>,
}

impl SlowEntry {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("fingerprint", Json::from(self.fingerprint)),
            ("query", Json::str(&self.query)),
            ("shard", Json::from(self.shard)),
            ("epoch", Json::from(self.epoch)),
            ("total_s", Json::from(self.total_s)),
            ("degraded", Json::Bool(self.degraded)),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Json::str(e)));
        }
        if let Some(c) = &self.counters {
            pairs.push(("counters", c.clone()));
        }
        if let Some(s) = &self.stages {
            pairs.push(("stages", s.to_json()));
        }
        Json::obj(pairs)
    }
}

/// Bounded ring of slow-query entries plus an optional JSONL appender.
#[derive(Debug)]
pub struct SlowLog {
    capacity: usize,
    ring: Mutex<VecDeque<SlowEntry>>,
    file: Option<Mutex<File>>,
}

impl SlowLog {
    /// In-memory only; `capacity` is clamped to at least 1.
    pub fn new(capacity: usize) -> SlowLog {
        let capacity = capacity.max(1);
        SlowLog {
            capacity,
            ring: Mutex::new(VecDeque::with_capacity(capacity)),
            file: None,
        }
    }

    /// Ring plus append-mode JSONL file (one entry per line).
    pub fn with_file(capacity: usize, path: &Path) -> io::Result<SlowLog> {
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        let mut log = SlowLog::new(capacity);
        log.file = Some(Mutex::new(file));
        Ok(log)
    }

    /// Record an entry: newest wins, oldest evicted beyond capacity.
    /// File write errors are swallowed (observability must never fail
    /// a request).
    pub fn record(&self, entry: SlowEntry) {
        if let Some(file) = &self.file {
            let line = entry.to_json().to_string_compact();
            if let Ok(mut f) = file.lock() {
                let _ = writeln!(f, "{line}");
            }
        }
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        if ring.len() == self.capacity {
            ring.pop_front();
        }
        ring.push_back(entry);
    }

    /// Oldest-first copy of the ring.
    pub fn entries(&self) -> Vec<SlowEntry> {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `GET /debug/slow` body: `{"capacity": N, "entries": [...]}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("capacity", Json::from(self.capacity)),
            ("entries", Json::Arr(self.entries().iter().map(SlowEntry::to_json).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(i: usize) -> SlowEntry {
        SlowEntry {
            fingerprint: i as u64,
            query: format!("q{i}"),
            shard: 0,
            epoch: 1,
            total_s: 0.75,
            degraded: false,
            error: None,
            counters: None,
            stages: Some(TraceSpan::new("request", 0.75)),
        }
    }

    #[test]
    fn ring_keeps_newest_up_to_capacity() {
        let log = SlowLog::new(3);
        for i in 0..5 {
            log.record(entry(i));
        }
        let got: Vec<u64> = log.entries().iter().map(|e| e.fingerprint).collect();
        assert_eq!(got, vec![2, 3, 4]);
        assert_eq!(log.len(), 3);
    }

    #[test]
    fn debug_endpoint_json_shape() {
        let log = SlowLog::new(8);
        log.record(entry(7));
        let j = log.to_json();
        assert_eq!(j.get("capacity").and_then(Json::as_i64), Some(8));
        let entries = j.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("query").and_then(Json::as_str), Some("q7"));
        assert_eq!(entries[0].get("stages").and_then(|s| s.get("name")).and_then(Json::as_str), Some("request"));
        // Absent optionals are omitted, not null.
        assert!(entries[0].get("error").is_none());
    }

    #[test]
    fn file_appender_writes_one_json_line_per_entry() {
        let dir = std::env::temp_dir().join(format!("gaps_slowlog_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("slow.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let log = SlowLog::with_file(4, &path).unwrap();
            let mut e = entry(1);
            e.error = Some("deadline_exceeded".into());
            log.record(e);
            log.record(entry(2));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("error").and_then(Json::as_str), Some("deadline_exceeded"));
        assert_eq!(first.get("total_s").and_then(Json::as_f64), Some(0.75));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
