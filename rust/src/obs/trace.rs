//! Per-request trace spans.
//!
//! A [`TraceSpan`] is a named, monotonic-clock-timed tree node: the
//! serving layer builds one tree per request (HTTP admission → linger →
//! plan-cache probe → compile → fan-out with one child per node job →
//! merge → result-cache store), the coordinator contributes the
//! `search` subtree, and the finished tree is surfaced through
//! `Explain.stages`, the slow-query log, and the per-stage latency
//! histograms.
//!
//! Spans are *diagnostic* payload: they ride along with responses but
//! are excluded from semantic equality (see `coordinator::Explain`'s
//! manual `PartialEq`), so observability can never perturb parity
//! oracles.
//!
//! Timing invariant (pinned by `prop_serve_parity`): children occupy
//! disjoint or nested wall-clock windows inside their parent, so every
//! child's `seconds` is ≤ the parent's, and for *sequential* stages
//! the children sum to ≤ the parent. The one documented exception is
//! the `execute` span, whose children are per-node jobs that run in
//! parallel: each child is still ≤ the parent window, but their sum
//! may exceed it.

use crate::util::json::Json;

/// One timed stage in a request's lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpan {
    /// Stage name (`request`, `queued`, `probe`, `search`, `compile`,
    /// `plan`, `execute`, `job`, `merge`, `store`, …).
    pub name: String,
    /// Wall-clock duration of the stage, monotonic-clock measured.
    pub seconds: f64,
    /// Stage annotations (node id, sources searched, retrieval
    /// counters, cache verdicts) as ordered key/value strings.
    pub meta: Vec<(String, String)>,
    /// Sub-stages, in execution order.
    pub children: Vec<TraceSpan>,
}

impl TraceSpan {
    pub fn new(name: impl Into<String>, seconds: f64) -> TraceSpan {
        TraceSpan { name: name.into(), seconds, meta: Vec::new(), children: Vec::new() }
    }

    /// Builder-style annotation.
    pub fn with_meta(mut self, key: impl Into<String>, value: impl Into<String>) -> TraceSpan {
        self.meta.push((key.into(), value.into()));
        self
    }

    pub fn push_child(&mut self, child: TraceSpan) {
        self.children.push(child);
    }

    /// Sum of direct children's durations.
    pub fn children_total_s(&self) -> f64 {
        self.children.iter().map(|c| c.seconds).sum()
    }

    /// First span named `name` in a pre-order walk (self included).
    pub fn find(&self, name: &str) -> Option<&TraceSpan> {
        if self.name == name {
            return Some(self);
        }
        self.children.iter().find_map(|c| c.find(name))
    }

    /// Total number of spans in the tree (self included).
    pub fn span_count(&self) -> usize {
        1 + self.children.iter().map(|c| c.span_count()).sum::<usize>()
    }

    /// Wire form: `{"name": ..., "seconds": ..., "meta": {...},
    /// "children": [...]}` with empty `meta`/`children` omitted.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("seconds", Json::from(self.seconds)),
        ];
        if !self.meta.is_empty() {
            let map = self
                .meta
                .iter()
                .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                .collect::<std::collections::BTreeMap<_, _>>();
            pairs.push(("meta", Json::Obj(map)));
        }
        if !self.children.is_empty() {
            pairs.push(("children", Json::Arr(self.children.iter().map(|c| c.to_json()).collect())));
        }
        Json::obj(pairs)
    }

    /// Tolerant decode: absent fields default (wire-compatibility with
    /// pre-tracing payloads is handled one level up — an absent
    /// `stages` key decodes to `None`).
    pub fn from_json(v: &Json) -> Option<TraceSpan> {
        let name = v.get("name")?.as_str()?.to_string();
        let seconds = v.get("seconds").and_then(Json::as_f64).unwrap_or(0.0);
        let meta = v
            .get("meta")
            .and_then(Json::as_obj)
            .map(|m| {
                m.iter()
                    .filter_map(|(k, val)| Some((k.clone(), val.as_str()?.to_string())))
                    .collect()
            })
            .unwrap_or_default();
        let children = v
            .get("children")
            .and_then(Json::as_arr)
            .map(|arr| arr.iter().filter_map(TraceSpan::from_json).collect())
            .unwrap_or_default();
        Some(TraceSpan { name, seconds, meta, children })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TraceSpan {
        let mut root = TraceSpan::new("request", 0.010);
        root.push_child(TraceSpan::new("queued", 0.002));
        let mut search = TraceSpan::new("search", 0.007).with_meta("shard", "0");
        search.push_child(TraceSpan::new("compile", 0.001));
        search.push_child(
            TraceSpan::new("execute", 0.005)
                .with_meta("jobs", "2"),
        );
        root.push_child(search);
        root
    }

    #[test]
    fn json_round_trip_preserves_tree() {
        let span = sample();
        let back = TraceSpan::from_json(&span.to_json()).unwrap();
        assert_eq!(span, back);
    }

    #[test]
    fn empty_meta_and_children_are_omitted_from_wire() {
        let leaf = TraceSpan::new("store", 0.001);
        let j = leaf.to_json();
        assert!(j.get("meta").is_none());
        assert!(j.get("children").is_none());
        assert_eq!(TraceSpan::from_json(&j).unwrap(), leaf);
    }

    #[test]
    fn find_walks_preorder_and_counts_spans() {
        let span = sample();
        assert_eq!(span.find("compile").unwrap().seconds, 0.001);
        assert!(span.find("missing").is_none());
        assert_eq!(span.span_count(), 5);
        assert!((span.children_total_s() - 0.009).abs() < 1e-12);
    }

    #[test]
    fn tolerant_decode_defaults_missing_fields() {
        let j = Json::parse(r#"{"name":"probe"}"#).unwrap();
        let s = TraceSpan::from_json(&j).unwrap();
        assert_eq!(s.name, "probe");
        assert_eq!(s.seconds, 0.0);
        assert!(s.meta.is_empty() && s.children.is_empty());
        // No name at all -> not a span.
        assert!(TraceSpan::from_json(&Json::parse("{}").unwrap()).is_none());
    }
}
