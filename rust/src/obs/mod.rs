//! Observability: unified metrics registry, per-request trace spans,
//! and the slow-query log.
//!
//! The grid-resource-discovery literature the source paper builds on
//! (arXiv 1110.1685, 1703.03607) stresses that grid systems live or
//! die by visibility into per-node latency and load; this module is
//! that visibility layer for GAPS, in three pieces:
//!
//! * [`Registry`] — named counters, gauges, and fixed-bucket latency
//!   histograms behind one consistency gate, rendered in Prometheus
//!   text exposition format by `GET /metrics`. The serving layer's
//!   previously scattered counters (`QueueStats`, `HttpStats`, cache
//!   hit/miss, `IndexHealth` gauges, failover totals) are registry
//!   cells, so `/healthz` and `/metrics` are two renderings of the
//!   same point-in-time snapshot.
//! * [`TraceSpan`] — a per-request stage-timing tree threaded through
//!   admission, planning, fan-out, and merge, surfaced via
//!   `Explain.stages` (wire-compatible: absent unless requested).
//! * [`SlowLog`] — a bounded ring of structured JSONL entries for
//!   requests over `obs.slow_query_ms`, exposed at `GET /debug/slow`
//!   and optionally appended to `--slow-log FILE`.
//!
//! Everything is hand-rolled on `std` only — the same zero-dependency
//! discipline as `serve::http`.

pub mod registry;
pub mod slow;
pub mod trace;

pub use registry::{
    Counter, FamilySnapshot, Freeze, Gauge, Histogram, MetricKind, Registry, Sample, SampleValue,
    LATENCY_BOUNDS_S,
};
pub use slow::{SlowEntry, SlowLog};
pub use trace::TraceSpan;
