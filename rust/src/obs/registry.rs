//! Unified metrics registry: named counters, gauges, and fixed-bucket
//! latency histograms with Prometheus text exposition.
//!
//! Hand-rolled with the same zero-dependency discipline as
//! `serve::http`: cells are plain atomics, families live in a
//! `BTreeMap` so exposition order is stable, and there is no
//! background thread.
//!
//! ## Consistency model
//!
//! Every cell shares one registry-wide `RwLock<()>` *gate*. Mutations
//! (`inc`, `add`, `observe`, …) take the gate in *read* mode — many
//! writers proceed concurrently, so the hot path costs one uncontended
//! `RwLock` read plus one atomic RMW. A scrape ([`Registry::gather`],
//! [`Registry::freeze`]) takes the gate in *write* mode, which drains
//! all in-flight mutations and holds new ones, yielding a
//! point-in-time view across *all* cells of the registry.
//!
//! Combined with program order this gives cross-metric invariants: if
//! event A's counter is always bumped before event B's, no snapshot
//! can ever show B counted without A (the `/healthz` drift fix relies
//! on exactly this for `http.requests >= sum(shard.submitted)`).
//!
//! Do **not** call a cell mutation while holding [`Registry::freeze`]
//! (the guard is a write lock; mutating would deadlock). Reads
//! (`get`, `sum`, `count`) never touch the gate and are always safe.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock, RwLockWriteGuard};

/// Default latency bucket upper bounds, in seconds. Chosen to resolve
/// both sub-millisecond cache hits and multi-second degraded rounds.
pub const LATENCY_BOUNDS_S: &[f64] =
    &[0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5];

/// What a metric family measures; determines its `# TYPE` line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

type Gate = Arc<RwLock<()>>;

fn read_gate(gate: &RwLock<()>) -> std::sync::RwLockReadGuard<'_, ()> {
    gate.read().unwrap_or_else(|e| e.into_inner())
}

fn write_gate(gate: &RwLock<()>) -> RwLockWriteGuard<'_, ()> {
    gate.write().unwrap_or_else(|e| e.into_inner())
}

#[derive(Debug)]
struct CounterCore {
    gate: Gate,
    value: AtomicU64,
}

/// Monotonic counter handle. Cloning is cheap and refers to the same
/// cell; reads never block.
#[derive(Debug, Clone)]
pub struct Counter(Arc<CounterCore>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        let _g = read_gate(&self.0.gate);
        self.0.value.fetch_add(n, Ordering::SeqCst);
    }

    /// Overwrite with an externally maintained absolute total (used
    /// when migrating counters whose source of truth lives elsewhere,
    /// e.g. plan-cache hit counts published per round).
    pub fn store(&self, v: u64) {
        let _g = read_gate(&self.0.gate);
        self.0.value.store(v, Ordering::SeqCst);
    }

    /// Raise to `v` if larger (high-water marks).
    pub fn record_max(&self, v: u64) {
        let _g = read_gate(&self.0.gate);
        self.0.value.fetch_max(v, Ordering::SeqCst);
    }

    pub fn get(&self) -> u64 {
        self.0.value.load(Ordering::SeqCst)
    }
}

#[derive(Debug)]
struct GaugeCore {
    gate: Gate,
    value: AtomicI64,
}

/// Instantaneous-value handle (queue depth, active connections, epoch).
#[derive(Debug, Clone)]
pub struct Gauge(Arc<GaugeCore>);

impl Gauge {
    pub fn set(&self, v: i64) {
        let _g = read_gate(&self.0.gate);
        self.0.value.store(v, Ordering::SeqCst);
    }

    pub fn add(&self, n: i64) {
        let _g = read_gate(&self.0.gate);
        self.0.value.fetch_add(n, Ordering::SeqCst);
    }

    pub fn sub(&self, n: i64) {
        self.add(-n);
    }

    /// Raise to `v` if larger (high-water marks).
    pub fn record_max(&self, v: i64) {
        let _g = read_gate(&self.0.gate);
        self.0.value.fetch_max(v, Ordering::SeqCst);
    }

    pub fn get(&self) -> i64 {
        self.0.value.load(Ordering::SeqCst)
    }
}

#[derive(Debug)]
struct HistogramCore {
    gate: Gate,
    bounds: Vec<f64>,
    /// One slot per bound plus a final overflow (`+Inf`) slot.
    buckets: Vec<AtomicU64>,
    /// `f64` bits, CAS-accumulated.
    sum_bits: AtomicU64,
    count: AtomicU64,
}

/// Fixed-bucket latency histogram handle.
#[derive(Debug, Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    pub fn observe(&self, v: f64) {
        let _g = read_gate(&self.0.gate);
        // First bucket whose upper bound is >= v (Prometheus `le`).
        let i = self.0.bounds.partition_point(|&b| b < v);
        self.0.buckets[i].fetch_add(1, Ordering::SeqCst);
        self.0.count.fetch_add(1, Ordering::SeqCst);
        let mut cur = self.0.sum_bits.load(Ordering::SeqCst);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.0.sum_bits.compare_exchange(cur, next, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::SeqCst)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::SeqCst))
    }
}

#[derive(Debug, Clone)]
enum Cell {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Cell {
    fn kind(&self) -> MetricKind {
        match self {
            Cell::Counter(_) => MetricKind::Counter,
            Cell::Gauge(_) => MetricKind::Gauge,
            Cell::Histogram(_) => MetricKind::Histogram,
        }
    }
}

#[derive(Debug)]
struct Family {
    help: String,
    kind: MetricKind,
    label_names: Vec<String>,
    cells: Vec<(Vec<String>, Cell)>,
}

/// A snapshotted sample value.
#[derive(Debug, Clone, PartialEq)]
pub enum SampleValue {
    Counter(u64),
    Gauge(i64),
    /// `buckets` are *cumulative* counts per finite upper bound;
    /// `count` is the `+Inf` (total) count.
    Histogram { buckets: Vec<(f64, u64)>, sum: f64, count: u64 },
}

/// One labeled sample inside a family snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub labels: Vec<(String, String)>,
    pub value: SampleValue,
}

/// A consistent snapshot of one metric family.
#[derive(Debug, Clone, PartialEq)]
pub struct FamilySnapshot {
    pub name: String,
    pub help: String,
    pub kind: MetricKind,
    pub samples: Vec<Sample>,
}

/// RAII guard that holds all registry mutations; see [`Registry::freeze`].
#[derive(Debug)]
pub struct Freeze<'a>(#[allow(dead_code)] RwLockWriteGuard<'a, ()>);

/// The metrics registry. See the module docs for the consistency model.
#[derive(Debug)]
pub struct Registry {
    gate: Gate,
    families: Mutex<BTreeMap<String, Family>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry { gate: Arc::new(RwLock::new(())), families: Mutex::new(BTreeMap::new()) }
    }

    /// Get or create an unlabeled counter.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        self.counter_with(name, help, &[])
    }

    /// Get or create a counter with the given `(label, value)` pairs.
    /// Re-registering the same name+labels returns a handle to the
    /// same cell; a kind or label-name mismatch panics (programming
    /// error, caught in tests).
    pub fn counter_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        match self.cell(name, help, MetricKind::Counter, labels, |gate| {
            Cell::Counter(Counter(Arc::new(CounterCore { gate, value: AtomicU64::new(0) })))
        }) {
            Cell::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        self.gauge_with(name, help, &[])
    }

    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.cell(name, help, MetricKind::Gauge, labels, |gate| {
            Cell::Gauge(Gauge(Arc::new(GaugeCore { gate, value: AtomicI64::new(0) })))
        }) {
            Cell::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> Histogram {
        self.histogram_with(name, help, bounds, &[])
    }

    /// Get or create a histogram. `bounds` must be finite, strictly
    /// increasing upper bounds; a `+Inf` bucket is always appended.
    pub fn histogram_with(
        &self,
        name: &str,
        help: &str,
        bounds: &[f64],
        labels: &[(&str, &str)],
    ) -> Histogram {
        assert!(!bounds.is_empty(), "histogram {name} needs at least one bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]) && bounds.iter().all(|b| b.is_finite()),
            "histogram {name} bounds must be finite and strictly increasing"
        );
        match self.cell(name, help, MetricKind::Histogram, labels, |gate| {
            let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
            Cell::Histogram(Histogram(Arc::new(HistogramCore {
                gate,
                bounds: bounds.to_vec(),
                buckets,
                sum_bits: AtomicU64::new(0f64.to_bits()),
                count: AtomicU64::new(0),
            })))
        }) {
            Cell::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    fn cell(
        &self,
        name: &str,
        help: &str,
        kind: MetricKind,
        labels: &[(&str, &str)],
        make: impl FnOnce(Gate) -> Cell,
    ) -> Cell {
        let names: Vec<String> = labels.iter().map(|(k, _)| k.to_string()).collect();
        let values: Vec<String> = labels.iter().map(|(_, v)| v.to_string()).collect();
        let mut fams = self.families.lock().unwrap_or_else(|e| e.into_inner());
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            label_names: names.clone(),
            cells: Vec::new(),
        });
        assert!(
            fam.kind == kind,
            "metric {name} re-registered as {} but is {}",
            kind.as_str(),
            fam.kind.as_str()
        );
        assert!(
            fam.label_names == names,
            "metric {name} re-registered with labels {names:?} but has {:?}",
            fam.label_names
        );
        if let Some((_, cell)) = fam.cells.iter().find(|(v, _)| *v == values) {
            return cell.clone();
        }
        let cell = make(Arc::clone(&self.gate));
        debug_assert!(cell.kind() == kind);
        fam.cells.push((values, cell.clone()));
        cell
    }

    /// Hold all mutations while the guard lives, so a multi-cell read
    /// (e.g. the `/healthz` snapshot) observes one point in time.
    /// Cell *reads* are lock-free and safe under the guard; cell
    /// *mutations* from the holding thread would deadlock.
    pub fn freeze(&self) -> Freeze<'_> {
        Freeze(write_gate(&self.gate))
    }

    /// Snapshot every family at one point in time.
    pub fn gather(&self) -> Vec<FamilySnapshot> {
        let _freeze = self.freeze();
        let fams = self.families.lock().unwrap_or_else(|e| e.into_inner());
        fams.iter()
            .map(|(name, fam)| FamilySnapshot {
                name: name.clone(),
                help: fam.help.clone(),
                kind: fam.kind,
                samples: fam
                    .cells
                    .iter()
                    .map(|(values, cell)| Sample {
                        labels: fam
                            .label_names
                            .iter()
                            .cloned()
                            .zip(values.iter().cloned())
                            .collect(),
                        value: snapshot_cell(cell),
                    })
                    .collect(),
            })
            .collect()
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (`text/plain; version=0.0.4`): `# HELP` + `# TYPE` per family,
    /// cumulative `+Inf`-terminated histogram buckets, stable ordering.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for fam in self.gather() {
            out.push_str(&format!("# HELP {} {}\n", fam.name, escape_help(&fam.help)));
            out.push_str(&format!("# TYPE {} {}\n", fam.name, fam.kind.as_str()));
            for s in &fam.samples {
                match &s.value {
                    SampleValue::Counter(v) => {
                        out.push_str(&format!("{}{} {}\n", fam.name, label_str(&s.labels, None), v));
                    }
                    SampleValue::Gauge(v) => {
                        out.push_str(&format!("{}{} {}\n", fam.name, label_str(&s.labels, None), v));
                    }
                    SampleValue::Histogram { buckets, sum, count } => {
                        for (bound, cum) in buckets {
                            out.push_str(&format!(
                                "{}_bucket{} {}\n",
                                fam.name,
                                label_str(&s.labels, Some(&fmt_f64(*bound))),
                                cum
                            ));
                        }
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            fam.name,
                            label_str(&s.labels, Some("+Inf")),
                            count
                        ));
                        out.push_str(&format!(
                            "{}_sum{} {}\n",
                            fam.name,
                            label_str(&s.labels, None),
                            fmt_f64(*sum)
                        ));
                        out.push_str(&format!(
                            "{}_count{} {}\n",
                            fam.name,
                            label_str(&s.labels, None),
                            count
                        ));
                    }
                }
            }
        }
        out
    }
}

fn snapshot_cell(cell: &Cell) -> SampleValue {
    match cell {
        Cell::Counter(c) => SampleValue::Counter(c.get()),
        Cell::Gauge(g) => SampleValue::Gauge(g.get()),
        Cell::Histogram(h) => {
            let core = &h.0;
            let mut cum = 0u64;
            let buckets = core
                .bounds
                .iter()
                .enumerate()
                .map(|(i, &b)| {
                    cum += core.buckets[i].load(Ordering::SeqCst);
                    (b, cum)
                })
                .collect();
            SampleValue::Histogram { buckets, sum: h.sum(), count: h.count() }
        }
    }
}

/// `{k="v",...}` with the extra `le` label appended for histogram
/// buckets; empty label sets render as no braces at all.
fn label_str(labels: &[(String, String)], le: Option<&str>) -> String {
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn escape_help(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Rust's `{}` for f64 never uses scientific notation and prints the
/// shortest round-trip decimal — exactly what the exposition format
/// wants for bucket bounds ("0.005", "1", "2.5").
fn fmt_f64(v: f64) -> String {
    format!("{v}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::thread;

    #[test]
    fn counter_inc_add_and_dedup() {
        let r = Registry::new();
        let a = r.counter("gaps_test_total", "a test counter");
        a.inc();
        a.add(4);
        // Same name + labels -> same cell.
        let b = r.counter("gaps_test_total", "a test counter");
        b.inc();
        assert_eq!(a.get(), 6);
        assert_eq!(b.get(), 6);
    }

    #[test]
    fn labeled_cells_are_distinct() {
        let r = Registry::new();
        let s0 = r.counter_with("gaps_shard_total", "per shard", &[("shard", "0")]);
        let s1 = r.counter_with("gaps_shard_total", "per shard", &[("shard", "1")]);
        s0.add(2);
        s1.add(5);
        assert_eq!(s0.get(), 2);
        assert_eq!(s1.get(), 5);
    }

    #[test]
    #[should_panic(expected = "re-registered as gauge")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter("gaps_kind_total", "counter");
        let _ = r.gauge("gaps_kind_total", "now a gauge");
    }

    #[test]
    #[should_panic(expected = "re-registered with labels")]
    fn label_name_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter_with("gaps_lbl_total", "x", &[("shard", "0")]);
        let _ = r.counter_with("gaps_lbl_total", "x", &[("node", "0")]);
    }

    #[test]
    fn gauge_set_add_sub_max() {
        let r = Registry::new();
        let g = r.gauge("gaps_depth", "queue depth");
        g.set(10);
        g.add(5);
        g.sub(3);
        assert_eq!(g.get(), 12);
        g.record_max(40);
        g.record_max(1);
        assert_eq!(g.get(), 40);
    }

    #[test]
    fn counter_store_and_record_max() {
        let r = Registry::new();
        let c = r.counter("gaps_abs_total", "absolute publish");
        c.store(7);
        c.store(9);
        assert_eq!(c.get(), 9);
        c.record_max(4);
        assert_eq!(c.get(), 9);
        c.record_max(11);
        assert_eq!(c.get(), 11);
    }

    #[test]
    fn histogram_buckets_are_le_and_cumulative() {
        let r = Registry::new();
        let h = r.histogram("gaps_lat_seconds", "latency", &[0.001, 0.01, 0.1]);
        h.observe(0.0005); // -> le 0.001
        h.observe(0.001); // boundary counts in le 0.001 (le is <=)
        h.observe(0.05); // -> le 0.1
        h.observe(3.0); // -> +Inf only
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 3.0515).abs() < 1e-12);
        let fams = r.gather();
        let fam = fams.iter().find(|f| f.name == "gaps_lat_seconds").unwrap();
        match &fam.samples[0].value {
            SampleValue::Histogram { buckets, count, .. } => {
                assert_eq!(buckets, &vec![(0.001, 2), (0.01, 2), (0.1, 3)]);
                assert_eq!(*count, 4);
            }
            other => panic!("expected histogram, got {other:?}"),
        }
    }

    #[test]
    fn render_text_has_help_type_and_inf_terminated_buckets() {
        let r = Registry::new();
        r.counter_with("gaps_req_total", "requests served", &[("shard", "0")]).add(3);
        r.gauge("gaps_active", "active connections").set(2);
        let h = r.histogram("gaps_lat_seconds", "latency", &[0.5, 1.0]);
        h.observe(0.2);
        h.observe(2.0);
        let text = r.render_text();
        assert!(text.contains("# HELP gaps_req_total requests served\n"));
        assert!(text.contains("# TYPE gaps_req_total counter\n"));
        assert!(text.contains("gaps_req_total{shard=\"0\"} 3\n"));
        assert!(text.contains("# TYPE gaps_active gauge\n"));
        assert!(text.contains("gaps_active 2\n"));
        assert!(text.contains("# TYPE gaps_lat_seconds histogram\n"));
        assert!(text.contains("gaps_lat_seconds_bucket{le=\"0.5\"} 1\n"));
        assert!(text.contains("gaps_lat_seconds_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("gaps_lat_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("gaps_lat_seconds_count 2\n"));
    }

    #[test]
    fn label_values_are_escaped() {
        let r = Registry::new();
        r.counter_with("gaps_esc_total", "escaping", &[("q", "a\"b\\c\nd")]).inc();
        let text = r.render_text();
        assert!(text.contains("gaps_esc_total{q=\"a\\\"b\\\\c\\nd\"} 1\n"));
    }

    #[test]
    fn freeze_gives_a_point_in_time_across_cells() {
        // A writer thread increments `first` strictly before `second`
        // (each with its own gate acquisition). Under a freeze, no
        // snapshot may ever observe second > first — the exact
        // ordering argument the /healthz drift fix depends on.
        let r = Arc::new(Registry::new());
        let first = r.counter("gaps_first_total", "incremented first");
        let second = r.counter("gaps_second_total", "incremented second");
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let (first, second, stop) = (first.clone(), second.clone(), Arc::clone(&stop));
            thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    first.inc();
                    second.inc();
                }
            })
        };
        for _ in 0..200 {
            let _f = r.freeze();
            let (f, s) = (first.get(), second.get());
            assert!(f >= s, "snapshot saw second={s} ahead of first={f}");
        }
        stop.store(true, Ordering::SeqCst);
        writer.join().unwrap();
    }
}
