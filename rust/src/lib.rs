//! # GAPS — Grid-based Academic Publications Search
//!
//! Production-quality reproduction of *"Grid-based Search Technique for
//! Massive Academic Publications"* (Bashir, Abd Latiff, Abdulhamid, Loon —
//! 2014) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the GAPS coordination contribution: Query
//!   Execution Engines (one per Virtual Organization), the Query Manager
//!   with its Job Description Files and performance-history scheduling,
//!   Resource Manager, Data Source Locator, per-node Search Services, and
//!   the result merger — plus every substrate the paper assumes (grid
//!   fabric, corpus, text pipeline, inverted index, baseline, metrics)
//!   and the multi-user serving layer ([`serve`]) the paper's workload
//!   implies.
//! * **Layer 2 (python/compile/model.py)** — the BM25F candidate-ranking
//!   compute graph, AOT-lowered to HLO text artifacts at build time.
//! * **Layer 1 (python/compile/kernels/bm25.py)** — the tiled Pallas
//!   scoring kernel the Layer-2 graph calls.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and the Search
//! Services execute them directly from Rust.
//!
//! See `ARCHITECTURE.md` for the paper-component-to-module map and the
//! request lifecycle, `BENCHMARKS.md` for what the `BENCH_*.json` series
//! mean, and the repository `README.md` for a quickstart over all three
//! entry points (CLI, USI REPL, HTTP).
//!
//! ## Public search API
//!
//! The search surface is typed end to end: build a
//! [`search::SearchRequest`], execute it through
//! [`coordinator::GapsSystem::search_request`] (or a whole batch through
//! [`coordinator::GapsSystem::search_batch`] — one plan, one fan-out
//! round over the resident gridpool, Q>1 scoring rows), and branch on
//! the [`search::SearchError`] taxonomy on failure:
//!
//! ```
//! use gaps::config::GapsConfig;
//! use gaps::coordinator::GapsSystem;
//! use gaps::search::{Field, ReplicaPref, SearchRequest};
//!
//! // Small corpus so this example executes quickly under `cargo test`.
//! let mut cfg = GapsConfig::default();
//! cfg.workload.num_docs = 600;
//! cfg.workload.sub_shards = 6;
//! cfg.search.use_xla = false; // pure-rust scorer: no artifacts needed
//!
//! let mut sys = GapsSystem::deploy(cfg, 3)?;
//! let resp = sys.search_request(
//!     &SearchRequest::new("grid computing scheduling")
//!         .top_k(20)
//!         .year(1995..=2014)
//!         .prefer_replicas(ReplicaPref::SameVo)
//!         .explain(true),
//! )?;
//! assert!(resp.hits.len() <= 20);
//! assert!(resp.explain.is_some());
//! # let _ = Field::Title;
//! # Ok::<(), gaps::search::SearchError>(())
//! ```
//!
//! Query text follows the grammar documented in [`search::query`]:
//! free keywords (an OR group), quoted phrases, uppercase `AND`/`OR`
//! operators, `-`/`NOT` negation, parentheses, `field:term` scopes
//! (title/abstract/authors/venue), and `year:Y` / `year:Y..Y` ranges.
//! Requests and responses share one JSON wire encoding (`util::json`)
//! with the Job Description Files the Query Manager ships to nodes — and
//! with the HTTP front-end.
//!
//! ## Serving multiple users
//!
//! The [`serve`] module is the always-on front the paper's multi-user
//! experiment assumes: a [`serve::SearchServer`] owns the deployed
//! system on a dedicated executor thread, a [`serve::AdmissionQueue`]
//! coalesces concurrently arriving independent requests into
//! `search_batch` rounds (results stay bit-identical to serial
//! execution), and a [`serve::HttpServer`] exposes `POST /search`,
//! `POST /search_batch`, `POST /ingest` and `GET /healthz` over the
//! shared JSON wire forms. `gaps serve` is the CLI entry point.
//!
//! ## Persistence and live ingestion
//!
//! The [`storage`] module makes the index durable and live-updatable:
//! checksummed on-disk snapshots of every shard's CSR arena
//! (`gaps snapshot` writes them, `--snapshot DIR` boots from them in
//! milliseconds, bit-identical to the writer), Lucene-style immutable
//! overlay segments so publications ingested while serving become
//! searchable at their seal with tiered background compaction
//! ([`storage::SegmentedIndex`]), and an index epoch — bumped on every
//! seal and merge — reported through `GET /healthz` and the `explain`
//! diagnostics. `gaps ingest` streams JSONL publications into a
//! running server.

pub mod baseline;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod fault;
pub mod grid;
pub mod obs;
pub mod runtime;
pub mod search;
pub mod index;
pub mod metrics;
pub mod serve;
pub mod storage;
pub mod text;
pub mod usi;
pub mod util;
