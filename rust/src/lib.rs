//! # GAPS — Grid-based Academic Publications Search
//!
//! Production-quality reproduction of *"Grid-based Search Technique for
//! Massive Academic Publications"* (Bashir, Abd Latiff, Abdulhamid, Loon —
//! 2014) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the GAPS coordination contribution: Query
//!   Execution Engines (one per Virtual Organization), the Query Manager
//!   with its Job Description Files and performance-history scheduling,
//!   Resource Manager, Data Source Locator, per-node Search Services, and
//!   the result merger — plus every substrate the paper assumes (grid
//!   fabric, corpus, text pipeline, inverted index, baseline, metrics).
//! * **Layer 2 (python/compile/model.py)** — the BM25F candidate-ranking
//!   compute graph, AOT-lowered to HLO text artifacts at build time.
//! * **Layer 1 (python/compile/kernels/bm25.py)** — the tiled Pallas
//!   scoring kernel the Layer-2 graph calls.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and the Search
//! Services execute them directly from Rust.
//!
//! ## Public search API
//!
//! The search surface is typed end to end: build a
//! [`search::SearchRequest`], execute it through
//! [`coordinator::GapsSystem::search_request`] (or a whole batch through
//! [`coordinator::GapsSystem::search_batch`] — one plan, one fan-out
//! round, Q>1 artifact scoring rows), and branch on the
//! [`search::SearchError`] taxonomy on failure:
//!
//! ```no_run
//! use gaps::config::GapsConfig;
//! use gaps::coordinator::GapsSystem;
//! use gaps::search::{Field, ReplicaPref, SearchRequest};
//!
//! let mut sys = GapsSystem::deploy(GapsConfig::default(), 12)?;
//! let resp = sys.search_request(
//!     &SearchRequest::new("\"grid computing\" scheduling -cloud")
//!         .top_k(20)
//!         .year(2010..=2014)
//!         .require(Field::Title, "grid")
//!         .prefer_replicas(ReplicaPref::SameVo)
//!         .explain(true),
//! )?;
//! println!("{} hits", resp.hits.len());
//! # Ok::<(), gaps::search::SearchError>(())
//! ```
//!
//! Query text follows the grammar documented in [`search::query`]:
//! free keywords (an OR group), quoted phrases, uppercase `AND`/`OR`
//! operators, `-`/`NOT` negation, parentheses, `field:term` scopes
//! (title/abstract/authors/venue), and `year:Y` / `year:Y..Y` ranges.
//! Requests and responses share one JSON wire encoding (`util::json`)
//! with the Job Description Files the Query Manager ships to nodes.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-figure reproductions (response time, speedup, efficiency).

pub mod baseline;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod grid;
pub mod runtime;
pub mod search;
pub mod index;
pub mod metrics;
pub mod text;
pub mod usi;
pub mod util;
