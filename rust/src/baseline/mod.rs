//! Baselines the paper compares against.

mod traditional;

pub use traditional::TraditionalSearch;
