//! The "traditional search" comparator.
//!
//! The paper never specifies its traditional baseline beyond "the
//! traditional search"; its reported curves (speedup peaking near 5 nodes
//! then *declining*, efficiency falling to 0.17 at 11 nodes) are the
//! signature of a centralized, non-grid distribution:
//!
//! * one central coordinator talks to every worker directly (no VO
//!   brokers) — per-job dispatch is serialized at one point and pays WAN
//!   latency to the 2/3 of nodes living in other VOs;
//! * search processes are launched per job (no resident grid-service
//!   container), paying the cold-start cost the paper's SS design avoids;
//! * data is split uniformly (round-robin), blind to node heterogeneity —
//!   the slowest node dominates the barrier;
//! * no perf-history database, no adaptation.
//!
//! Everything else — corpus, analysis, scoring (same AOT artifacts or
//! rust scorer), merge, and the typed [`SearchRequest`] surface — is
//! identical to GAPS, so differences are purely coordination. See
//! ARCHITECTURE.md §Substitutions.

use std::sync::Arc;

use crate::config::{GapsConfig, SchedulePolicy};
use crate::coordinator::result_wire_bytes;
use crate::coordinator::{
    merge_topk, Deployment, ExecutionPlan, Explain, Hit, PerfDb, QueryExecutionEngine,
    SearchResponse,
};
use crate::grid::NodeId;
use crate::runtime::Executor;
use crate::search::{
    LocalHit, Query, ReplicaPref, Scorer, SearchError, SearchRequest, SearchService,
};
use crate::util::clock::{TaskTimeline, WallClock};

/// The deployed traditional (centralized) search system.
pub struct TraditionalSearch {
    cfg: GapsConfig,
    dep: Arc<Deployment>,
    service: SearchService,
    executor: Option<Executor>,
    /// Central coordinator (first active node).
    coordinator: NodeId,
}

impl std::fmt::Debug for TraditionalSearch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraditionalSearch")
            .field("active_nodes", &self.dep.active.len())
            .field("xla", &self.executor.is_some())
            .finish()
    }
}

impl TraditionalSearch {
    /// Deploy over a shared deployment (same data as the GAPS system).
    pub fn from_deployment(
        cfg: GapsConfig,
        dep: Arc<Deployment>,
    ) -> Result<TraditionalSearch, SearchError> {
        let executor = if cfg.search.use_xla {
            Some(
                Executor::new(std::path::Path::new(&cfg.search.artifact_dir))
                    .map_err(SearchError::executor)?,
            )
        } else {
            None
        };
        Ok(TraditionalSearch {
            service: SearchService::new(cfg.search.clone()),
            coordinator: dep.active[0],
            cfg,
            dep,
            executor,
        })
    }

    /// Build fabric + data and deploy.
    pub fn deploy(cfg: GapsConfig, n_nodes: usize) -> Result<TraditionalSearch, SearchError> {
        let dep = Arc::new(Deployment::build(&cfg, n_nodes)?);
        Self::from_deployment(cfg, dep)
    }

    pub fn deployment(&self) -> &Deployment {
        &self.dep
    }

    /// Execute one raw query string through the centralized flow.
    pub fn search(&mut self, raw: &str) -> Result<SearchResponse, SearchError> {
        self.search_request(&SearchRequest::new(raw))
    }

    /// Execute one typed request through the centralized flow.
    pub fn search_request(
        &mut self,
        request: &SearchRequest,
    ) -> Result<SearchResponse, SearchError> {
        let plan_clock = WallClock::start();
        let compiled = request.compile(self.cfg.search.features, self.cfg.search.top_k)?;
        let top_k = compiled.top_k;
        let query: &Query = &compiled.query;

        // Uniform (round-robin) plan, blind to speeds and history — and
        // blind to replica preferences too (a grid-era feature the
        // traditional system does not have).
        let available: Vec<_> = self
            .dep
            .active
            .iter()
            .map(|&n| self.dep.fabric.node(n).clone())
            .collect();
        let sources = self.dep.locator.sources();
        let plan: ExecutionPlan = QueryExecutionEngine.plan(
            &sources,
            &available,
            &PerfDb::default(),
            SchedulePolicy::RoundRobin,
            ReplicaPref::Any,
            None,
        )?;
        let plan_s = plan_clock.elapsed_s();

        let net = &self.dep.fabric.net;
        let coord_info = self.dep.fabric.node(self.coordinator).clone();
        let dispatch_s = self.cfg.grid.dispatch_ms * 1e-3;
        let cold_start_s = self.cfg.grid.cold_start_ms * 1e-3;
        // The request JSON is invariant across nodes: serialize once.
        let request_wire = request.wire_bytes();

        let mut branches: Vec<TaskTimeline> = Vec::new();
        let mut lists: Vec<Vec<LocalHit>> = Vec::new();
        let mut total_candidates = 0usize;
        let mut total_counters = crate::index::RetrievalCounters::default();
        let mut total_docs = 0u64;

        // The central coordinator dispatches every job itself, serially.
        for (j_idx, (node, source_ids)) in plan.assignments.iter().enumerate() {
            let node_info = self.dep.fabric.node(*node).clone();
            let mut work_measured = 0.0f64;
            let mut node_hits: Vec<Vec<LocalHit>> = Vec::new();
            for sid in source_ids {
                let shard = self
                    .dep
                    .shard(*sid)
                    .ok_or(SearchError::SourceUnknown { source: *sid })?;
                let mut scorer = match self.executor.as_mut() {
                    Some(e) => Scorer::Xla(e),
                    None => Scorer::Rust,
                };
                let batch = [(query, top_k)];
                let outs = self.service.search_batch(shard, &self.dep.stats, &batch, &mut scorer)?;
                let out = outs.into_iter().next().expect("one outcome");
                work_measured += out.work_s;
                total_candidates += out.candidates;
                total_counters.merge(&out.counters);
                total_docs += out.shard_docs as u64;
                node_hits.push(out.hits);
            }
            let hits = merge_topk(&node_hits, top_k);
            // Request-equivalent wire cost: the same typed-request JSON
            // the JDF ships, plus the source list.
            let request_bytes = 96 + request_wire + 8 * source_ids.len();
            let branch = TaskTimeline {
                work_s: work_measured / node_info.speed_factor,
                net_s: net.transfer_between_s(&coord_info, &node_info, request_bytes)
                    + net.transfer_between_s(
                        &node_info,
                        &coord_info,
                        result_wire_bytes(hits.len()),
                    ),
                // Serial central dispatch + per-job process launch (no
                // resident container in the traditional system).
                overhead_s: (j_idx + 1) as f64 * dispatch_s + cold_start_s,
            };
            branches.push(branch);
            lists.push(hits);
        }

        let mut timeline = TaskTimeline { work_s: plan_s, net_s: 0.0, overhead_s: 0.0 };
        let slowest = branches
            .into_iter()
            .fold(TaskTimeline::default(), |acc, b| acc.max(b));
        timeline.add(slowest);

        let merge_clock = WallClock::start();
        let merged = merge_topk(&lists, top_k);
        timeline.work_s += merge_clock.elapsed_s();

        let hits = merged
            .into_iter()
            .map(|h| Hit {
                global_id: h.global_id,
                score: h.score,
                title: self
                    .dep
                    .publication(h.global_id)
                    .map(|p| p.title.clone())
                    .unwrap_or_default(),
            })
            .collect();

        let explain = compiled.explain.then(|| Explain {
            ast: query.ast.to_string(),
            keywords: query.keywords.clone(),
            batch_size: 1, // the traditional system has no batching
            plan: plan
                .assignments
                .iter()
                .map(|(n, s)| (n.to_string(), s.len()))
                .collect(),
            counters: total_counters,
            epoch: 0, // the traditional baseline never ingests
            stages: None, // only the GAPS path is traced
        });
        Ok(SearchResponse {
            query: request.query.clone(),
            hits,
            timeline,
            jobs: plan.assignments.len(),
            candidates: total_candidates,
            docs_scanned: total_docs,
            degraded: false,
            missing_sources: Vec::new(),
            explain,
            trace: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GapsSystem;

    fn small_cfg() -> GapsConfig {
        let mut cfg = GapsConfig::default();
        cfg.workload.num_docs = 600;
        cfg.workload.sub_shards = 8;
        cfg.search.use_xla = false;
        cfg
    }

    #[test]
    fn finds_the_same_documents_as_gaps() {
        let cfg = small_cfg();
        let dep = Arc::new(Deployment::build(&cfg, 4).unwrap());
        let mut gaps = GapsSystem::from_deployment(cfg.clone(), Arc::clone(&dep)).unwrap();
        let mut trad = TraditionalSearch::from_deployment(cfg, dep).unwrap();
        let q = "grid distributed search academic";
        let g = gaps.search(q).unwrap();
        let t = trad.search(q).unwrap();
        // Same corpus, same scoring, same top-k => same result set.
        let g_ids: Vec<u64> = g.hits.iter().map(|h| h.global_id).collect();
        let t_ids: Vec<u64> = t.hits.iter().map(|h| h.global_id).collect();
        assert_eq!(g_ids, t_ids);
        for (gh, th) in g.hits.iter().zip(&t.hits) {
            assert!((gh.score - th.score).abs() < 1e-5);
        }
    }

    #[test]
    fn typed_request_top_k_applies() {
        let mut trad = TraditionalSearch::deploy(small_cfg(), 4).unwrap();
        let resp = trad
            .search_request(&SearchRequest::new("grid data search").top_k(2))
            .unwrap();
        assert!(resp.hits.len() <= 2);
    }

    #[test]
    fn parse_errors_are_typed() {
        let mut trad = TraditionalSearch::deploy(small_cfg(), 2).unwrap();
        assert_eq!(trad.search("the of and").unwrap_err().kind(), "parse");
    }

    #[test]
    fn pays_cold_start_and_serial_dispatch() {
        let mut trad = TraditionalSearch::deploy(small_cfg(), 4).unwrap();
        let resp = trad.search("grid computing").unwrap();
        let cold = trad.cfg.grid.cold_start_ms * 1e-3;
        let dispatch = trad.cfg.grid.dispatch_ms * 1e-3;
        // Critical path carries at least one cold start + the last
        // dispatch slot (4 jobs => 4 * dispatch on the last branch).
        assert!(
            resp.timeline.overhead_s >= cold + dispatch,
            "overhead {} too small",
            resp.timeline.overhead_s
        );
        assert_eq!(resp.docs_scanned, 600);
    }

    #[test]
    fn single_node_has_no_network_cost() {
        let mut trad = TraditionalSearch::deploy(small_cfg(), 1).unwrap();
        let resp = trad.search("grid computing").unwrap();
        assert_eq!(resp.timeline.net_s, 0.0, "coordinator == only worker");
        assert_eq!(resp.jobs, 1);
    }

    #[test]
    fn overhead_grows_with_node_count() {
        let r4 = TraditionalSearch::deploy(small_cfg(), 4)
            .unwrap()
            .search("grid")
            .unwrap();
        let r11 = TraditionalSearch::deploy(small_cfg(), 11)
            .unwrap()
            .search("grid")
            .unwrap();
        assert!(
            r11.timeline.overhead_s > r4.timeline.overhead_s,
            "serial dispatch must grow: {} vs {}",
            r11.timeline.overhead_s,
            r4.timeline.overhead_s
        );
    }
}
