//! `gaps` — the GAPS launcher.
//!
//! Subcommands:
//!
//! * `search <query...>` — deploy and run one query (or a batch through
//!   one fan-out round, queries separated by a space-padded `/`), print
//!   results. `--explain` attaches AST + plan diagnostics.
//! * `repl`              — interactive USI session.
//! * `serve`             — multi-user keep-alive HTTP front-end over
//!   sharded admission queues (`--addr`, `--handlers`, `--shards`,
//!   `--keep-alive on|off`, `--max-batch`, `--linger-ms`, `--max-depth`,
//!   `--read-timeout-ms`, `--slow-query-ms`, `--slow-log-capacity`,
//!   `--slow-log`; see `gaps::serve`). `POST /ingest` feeds the
//!   live-ingestion lane (fanned out to every shard); `GET /metrics`
//!   exposes the Prometheus-text metrics registry and `GET /debug/slow`
//!   the slow-query ring.
//! * `sweep`             — the paper's node sweep (Figs 3/4/5 series).
//! * `corpus`            — generate a corpus and save shard JSONL files.
//! * `snapshot`          — deploy and write a binary index snapshot
//!   (`--out DIR`; see `gaps::storage`).
//! * `ingest`            — stream a JSONL publication file into a
//!   running server (`--addr`, `--in FILE`, `--batch N`).
//! * `info`              — show the effective configuration and fabric.
//!
//! Common flags (see `config::GapsConfig::apply_args`): `--config <file>`,
//! `--vos N`, `--nodes-per-vo N`, `--docs N`, `--queries N`, `--top-k N`,
//! `--policy perf|rr`, `--no-xla`, `--artifacts DIR`, `--seed N`.
//! `--snapshot DIR` makes `search`/`repl`/`serve` boot from an on-disk
//! snapshot instead of regenerating and re-indexing the corpus.

use anyhow::{bail, Context, Result};

use gaps::config::GapsConfig;
use gaps::coordinator::GapsSystem;
use gaps::metrics::{run_node_sweep, System};
use gaps::search::SearchRequest;
use gaps::util::bench::Table;
use gaps::util::cli::Args;

const BOOL_FLAGS: &[&str] =
    &["no-xla", "no-resident-services", "no-cache", "verbose", "help", "explain"];

fn main() {
    if let Err(e) = run() {
        eprintln!("gaps: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(true, BOOL_FLAGS)?;
    if args.has("help") || args.subcommand.is_none() {
        print_usage();
        return Ok(());
    }
    if args.has("verbose") {
        gaps::util::log::set_level(gaps::util::log::Level::Debug);
    }
    let mut cfg = GapsConfig::default();
    cfg.apply_args(&args)?;

    match args.subcommand.as_deref().unwrap() {
        "search" => cmd_search(&args, cfg),
        "repl" => cmd_repl(&args, cfg),
        "serve" => cmd_serve(&args, cfg),
        "sweep" => cmd_sweep(&args, cfg),
        "corpus" => cmd_corpus(&args, cfg),
        "snapshot" => cmd_snapshot(&args, cfg),
        "ingest" => cmd_ingest(&args),
        "info" => cmd_info(cfg),
        other => bail!("unknown subcommand '{other}' (try --help)"),
    }
}

fn print_usage() {
    println!(
        "gaps — Grid-based Academic Publications Search (reproduction)\n\n\
         usage: gaps <search|repl|serve|sweep|corpus|snapshot|ingest|info> [flags] [query...]\n\n\
         subcommands:\n\
           search <query...>   one-shot search (e.g. gaps search grid computing);\n\
                               \" / \" separates a batch, --explain shows AST + plan\n\
           repl                interactive USI session\n\
           serve               keep-alive HTTP front-end (POST /search,\n\
                               POST /search_batch, POST /ingest, GET /healthz,\n\
                               GET /metrics — Prometheus text, GET /debug/slow) over\n\
                               sharded admission queues that coalesce concurrent\n\
                               queries; --addr HOST:PORT (default 127.0.0.1:7171),\n\
                               --handlers N (bounded handler pool; overflow is shed\n\
                               with 503 + Retry-After), --shards N (executor\n\
                               replicas, round-robin), --keep-alive on|off,\n\
                               --max-batch N, --linger-ms N, --max-depth N (shed\n\
                               beyond it, 503 + Retry-After),\n\
                               --read-timeout-ms N (stalled clients get 408),\n\
                               --slow-query-ms N (threshold for the slow-query\n\
                               ring at GET /debug/slow), --slow-log-capacity N,\n\
                               --slow-log FILE (mirror slow queries as JSONL)\n\
           sweep               node sweep: response time / speedup / efficiency\n\
           corpus --out DIR    generate the corpus as shard JSONL files\n\
           snapshot --out DIR  deploy and write a binary index snapshot (shards,\n\
                               quantized impacts, block metadata, manifest)\n\
           ingest --in FILE    stream a JSONL publication file into a running\n\
                               server; --addr HOST:PORT, --batch N docs per POST\n\
           info                print the effective configuration\n\n\
         common flags: --config FILE --vos N --nodes-per-vo N --nodes N\n\
           --docs N --queries N --top-k N --policy perf|rr --no-xla\n\
           --artifacts DIR --seed N --no-resident-services\n\
           --snapshot DIR (boot search/repl/serve from a snapshot)\n\
           --seal-docs N --merge-fanout N (live-ingestion knobs)\n\
           --no-cache --cache-plan-capacity N --cache-result-capacity N\n\
           --cache-result-shards N (plan/result caching knobs)"
    );
}

/// Number of participating nodes for a command (defaults to the fabric).
fn n_nodes(args: &Args, cfg: &GapsConfig) -> Result<usize> {
    args.get_parse("nodes", cfg.grid.total_nodes()).map_err(Into::into)
}

/// Deploy the system: from an on-disk snapshot when `--snapshot DIR`
/// (or the config's `storage.snapshot_dir`) is set, from the corpus
/// generator otherwise.
fn deploy_system(cfg: GapsConfig, n: usize) -> Result<GapsSystem> {
    if cfg.storage.snapshot_dir.is_empty() {
        Ok(GapsSystem::deploy(cfg, n)?)
    } else {
        let dir = std::path::PathBuf::from(&cfg.storage.snapshot_dir);
        eprintln!("booting from snapshot {}", dir.display());
        Ok(GapsSystem::deploy_from_snapshot(cfg, n, &dir)?)
    }
}

fn cmd_search(args: &Args, cfg: GapsConfig) -> Result<()> {
    // `gaps search a b / c d` runs a batch of two queries ("a b", "c d")
    // through one plan + fan-out round. Only a space-padded " / " is a
    // separator, so query text containing a slash (e.g. "client/server")
    // is not hijacked into a batch.
    let joined = args.positionals.join(" ");
    let queries: Vec<&str> =
        joined.split(" / ").map(str::trim).filter(|q| !q.is_empty()).collect();
    if queries.is_empty() {
        bail!("search needs a query, e.g.: gaps search grid computing");
    }
    let n = n_nodes(args, &cfg)?;
    eprintln!("{}", cfg.describe());
    let mut sys = deploy_system(cfg, n)?;
    let requests: Vec<SearchRequest> = queries
        .iter()
        .map(|q| SearchRequest::new(*q).explain(args.has("explain")))
        .collect();
    if let [request] = requests.as_slice() {
        let (rendered, timing) = gaps::usi::one_shot_request(&mut sys, request)?;
        print!("{rendered}");
        println!(
            "usi overhead: {:.3} ms ({:.2}% of total)",
            timing.interface_s * 1e3,
            timing.interface_fraction() * 100.0
        );
        return Ok(());
    }
    let mut failures = 0usize;
    let total = requests.len();
    for (request, result) in requests.iter().zip(sys.search_batch(&requests)) {
        println!("=== {:?} ===", request.query);
        match result {
            Ok(resp) => print!("{}", gaps::usi::format_response(&resp)),
            Err(e) => {
                failures += 1;
                println!("error: {e}");
            }
        }
    }
    if failures == total {
        bail!("all {total} batch queries failed");
    }
    Ok(())
}

fn cmd_repl(args: &Args, cfg: GapsConfig) -> Result<()> {
    let n = n_nodes(args, &cfg)?;
    eprintln!("{}", cfg.describe());
    let mut sys = deploy_system(cfg, n)?;
    let stdin = std::io::stdin();
    gaps::usi::repl(&mut sys, stdin.lock(), std::io::stdout())?;
    Ok(())
}

fn cmd_serve(args: &Args, cfg: GapsConfig) -> Result<()> {
    let n = n_nodes(args, &cfg)?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7171").to_string();
    let shards = cfg.serve.shards.max(1);
    let queue_cfg = gaps::serve::QueueConfig {
        max_batch: cfg.serve.max_batch.max(1),
        max_linger: std::time::Duration::from_millis(cfg.serve.linger_ms),
        max_depth: cfg.serve.max_depth,
    };
    let http_cfg = gaps::serve::HttpConfig {
        read_timeout: std::time::Duration::from_millis(cfg.serve.read_timeout_ms),
        write_timeout: std::time::Duration::from_millis(cfg.serve.read_timeout_ms),
        handlers: cfg.serve.handlers.max(1),
        keep_alive: cfg.serve.keep_alive,
    };
    eprintln!("{}", cfg.describe());
    eprintln!(
        "serving shape: {} executor shard(s), {} handler(s), keep-alive {}; \
         admission per shard: max_batch={} max_linger={:?} max_depth={}",
        shards,
        http_cfg.handlers,
        if http_cfg.keep_alive { "on" } else { "off" },
        queue_cfg.max_batch,
        queue_cfg.max_linger,
        queue_cfg.max_depth
    );
    // Each replica system deploys on (and never leaves) its executor
    // thread. On the generator path the corpus + indexes are built once
    // and shared (replicas are cheap views over one deployment); on the
    // snapshot path every shard loads the same on-disk snapshot, which
    // is deterministic, so the replicas still match bit-for-bit.
    let obs = gaps::serve::ServeObs::from_config(&cfg.obs);
    let server = if cfg.storage.snapshot_dir.is_empty() {
        let cfg_f = cfg.clone();
        let dep = std::sync::Arc::new(gaps::coordinator::Deployment::build(&cfg, n)?);
        gaps::serve::SearchServer::start_sharded_with_obs(queue_cfg, shards, obs, move |_shard| {
            GapsSystem::from_deployment(cfg_f.clone(), std::sync::Arc::clone(&dep))
        })?
    } else {
        let cfg_f = cfg.clone();
        eprintln!("booting from snapshot {}", cfg.storage.snapshot_dir);
        gaps::serve::SearchServer::start_sharded_with_obs(queue_cfg, shards, obs, move |_shard| {
            let dir = std::path::PathBuf::from(&cfg_f.storage.snapshot_dir);
            GapsSystem::deploy_from_snapshot(cfg_f.clone(), n, &dir)
        })?
    };
    let http = gaps::serve::HttpServer::bind_with(&addr, server.router(), http_cfg)
        .with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "serving on http://{} — POST /search, POST /search_batch, POST /ingest, \
         GET /healthz, GET /metrics, GET /debug/slow",
        http.local_addr()?
    );
    http.serve()?; // blocks until killed
    server.shutdown();
    Ok(())
}

fn cmd_sweep(args: &Args, cfg: GapsConfig) -> Result<()> {
    // Node counts: --node-counts 1,2,4,8 or the paper's default sweep.
    let counts: Vec<usize> = match args.get("node-counts") {
        Some(spec) => spec
            .split(',')
            .map(|s| s.trim().parse().context("bad --node-counts"))
            .collect::<Result<_>>()?,
        None => vec![1, 2, 3, 5, 8, 11]
            .into_iter()
            .filter(|&n| n <= cfg.grid.total_nodes())
            .collect(),
    };
    eprintln!("{}", cfg.describe());
    eprintln!("sweeping nodes: {counts:?}");
    let sweep = run_node_sweep(&cfg, &counts)?;
    let serial_gaps = sweep.serial_response_s(System::Gaps);
    let serial_trad = sweep.serial_response_s(System::Traditional);

    let mut table = Table::new(&[
        "nodes",
        "gaps_ms",
        "trad_ms",
        "gaps_speedup",
        "trad_speedup",
        "gaps_eff",
        "trad_eff",
    ]);
    for p in &sweep.points {
        table.row(vec![
            p.nodes.to_string(),
            format!("{:.1}", p.gaps.response_s * 1e3),
            format!("{:.1}", p.traditional.response_s * 1e3),
            format!("{:.2}", p.speedup(serial_gaps, System::Gaps)),
            format!("{:.2}", p.speedup(serial_trad, System::Traditional)),
            format!("{:.2}", p.efficiency(serial_gaps, System::Gaps)),
            format!("{:.2}", p.efficiency(serial_trad, System::Traditional)),
        ]);
    }
    print!("{}", table.render());
    table.write_csv("sweep");
    Ok(())
}

fn cmd_corpus(args: &Args, cfg: GapsConfig) -> Result<()> {
    let out_dir = args.get("out").unwrap_or("corpus_out");
    let n = n_nodes(args, &cfg)?;
    let dep = gaps::coordinator::Deployment::build(&cfg, n)?;
    std::fs::create_dir_all(out_dir).context("creating --out dir")?;
    for src in dep.locator.sources() {
        let shard = dep.shard(src.id).unwrap();
        let path = std::path::Path::new(out_dir).join(format!("shard_{:04}.jsonl", src.id));
        shard.save_jsonl(&path)?;
    }
    println!(
        "wrote {} shards ({} docs) to {out_dir}/",
        dep.locator.len(),
        dep.locator.total_docs()
    );
    Ok(())
}

fn cmd_snapshot(args: &Args, cfg: GapsConfig) -> Result<()> {
    let out = args.get("out").unwrap_or("snapshot_out").to_string();
    let n = n_nodes(args, &cfg)?;
    eprintln!("{}", cfg.describe());
    // `--snapshot DIR` composes: load an existing snapshot, re-write it
    // (with any ingested overlays) to --out.
    let sys = deploy_system(cfg, n)?;
    let manifest = sys.write_snapshot(std::path::Path::new(&out))?;
    println!(
        "wrote snapshot to {out}/: {} sources ({} docs), {} overlay segments, epoch {}",
        manifest.sources.len(),
        manifest.num_docs,
        manifest.overlays.len(),
        manifest.epoch
    );
    Ok(())
}

/// Minimal HTTP/1.1 POST over `std::net`. Sends `Connection: close`
/// (the serve front-end honors it even though it keep-alives by
/// default), so `read_to_string` terminates at the response's end.
fn http_post(addr: &str, path: &str, body: &str) -> Result<(u16, gaps::util::json::Json)> {
    use std::io::{Read, Write};
    let mut stream =
        std::net::TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let head = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw).context("reading response")?;
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .context("malformed HTTP response")?;
    let json_body = raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    let json = gaps::util::json::Json::parse(json_body)
        .map_err(|e| anyhow::anyhow!("response body is not JSON: {e}"))?;
    Ok((status, json))
}

fn cmd_ingest(args: &Args) -> Result<()> {
    use gaps::corpus::Publication;
    use gaps::util::json::Json;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7171").to_string();
    let path = args.get("in").context("ingest needs --in FILE.jsonl")?;
    let batch_size = args.get_parse("batch", 256usize)?.max(1);
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let mut docs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("{path}:{}: not JSON: {e}", lineno + 1))?;
        let p = Publication::from_json(&v)
            .with_context(|| format!("{path}:{}: not a publication object", lineno + 1))?;
        docs.push(p);
    }
    if docs.is_empty() {
        bail!("{path} holds no publications");
    }
    let total = docs.len();
    let batches = total.div_ceil(batch_size);
    let (mut accepted, mut sealed, mut merges) = (0usize, 0usize, 0usize);
    let mut last = None;
    for chunk in docs.chunks(batch_size) {
        let body = Json::obj(vec![(
            "docs",
            Json::Arr(chunk.iter().map(|p| p.to_json()).collect()),
        )])
        .to_string_compact();
        let (status, resp) = http_post(&addr, "/ingest", &body)?;
        if status != 200 {
            bail!("POST /ingest -> {status}: {}", resp.to_string_compact());
        }
        let report = gaps::coordinator::IngestReport::from_json(&resp)
            .context("malformed ingest report in response")?;
        accepted += report.accepted;
        sealed += report.sealed;
        merges += report.merges;
        last = Some(report);
    }
    let last = last.expect("at least one batch was sent");
    println!(
        "ingested {accepted}/{total} docs in {batches} batches: {sealed} seals, \
         {merges} merges, epoch {}, {} still buffered",
        last.epoch, last.buffered
    );
    Ok(())
}

fn cmd_info(cfg: GapsConfig) -> Result<()> {
    println!("{}", cfg.describe());
    let fabric = gaps::grid::GridFabric::build(&cfg.grid);
    for vo in &fabric.vos {
        println!("{}: broker={}", vo.id, vo.broker);
        for &m in &vo.members {
            let n = fabric.node(m);
            println!(
                "  {} speed={:.2}{}",
                n.id,
                n.speed_factor,
                if n.is_broker { " (broker+CA)" } else { "" }
            );
        }
    }
    Ok(())
}
