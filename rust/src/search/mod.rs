//! Search layer: the query language (keyword + multivariate), the
//! pure-rust BM25F scorer (baseline scorer and runtime cross-check), and
//! the per-node Search Service (the paper's SS grid service).

mod query;
mod scorer;
pub mod service;

pub use query::{ParsedQuery, QueryError, RangeFilter};
pub use scorer::score_block_rust;
pub use service::{LocalHit, Scorer, SearchOutcome, SearchService};
