//! Search layer: the typed request surface ([`SearchRequest`]), the query
//! language (recursive boolean AST + tokenizing parser, see [`query`]),
//! stable cache keys over the canonicalized AST (see [`fingerprint`]),
//! the structured error taxonomy ([`SearchError`]), the pure-rust BM25F
//! scorer (baseline scorer and runtime cross-check), and the per-node
//! Search Service (the paper's SS grid service) with batched Q>1
//! execution.

mod error;
pub mod fingerprint;
pub mod query;
mod request;
mod scorer;
pub mod service;

pub use error::SearchError;
pub use fingerprint::{query_fingerprint, request_plan_key};
pub use query::{Query, QueryNode, RangeFilter, RetrievalHint};
pub use request::{CompiledRequest, ReplicaPref, SearchRequest};
pub use scorer::{score_block_rust, topk_row};
pub use service::{LocalHit, Scorer, SearchOutcome, SearchService};

// Re-exported so request builders don't need a separate `text` import.
pub use crate::text::Field;
