//! Stable cache keys for compiled queries.
//!
//! Two keys, two caches:
//!
//! - [`query_fingerprint`] — the **normalized-AST fingerprint**: an
//!   FNV-1a 64 hash over a tagged pre-order encoding of the canonical
//!   [`QueryNode`] tree (commutative operands sorted, duplicate siblings
//!   deduped — see `search::query::simplify`), with the result-affecting
//!   request knobs folded in (`top_k`, `allow_partial`, `explain`).
//!   `ReplicaPref` and `deadline_ms` are deliberately **excluded**:
//!   replica choice only shifts *where* work runs (results are
//!   placement-invariant, property-tested since PR 2) and the deadline
//!   only affects *whether* a run completes, never what a completed run
//!   returns. This is the result-cache key (paired with the index epoch).
//!
//! - [`request_plan_key`] — the **plan-cache key**: a hash over the *raw*
//!   [`SearchRequest`] (query text + every builder knob) plus the
//!   deployment compile inputs (`features`, `default_top_k`). Probing it
//!   requires no parsing at all, which is the point: a plan-cache hit
//!   skips lex + parse + simplify + matcher compilation entirely and
//!   returns the memoized [`CompiledRequest`](super::CompiledRequest) —
//!   which carries the normalized-AST fingerprint the result cache then
//!   keys on. Every field is folded in (including `replicas` and
//!   `deadline_ms`) because the cached value embeds them verbatim.
//!
//! Both encodings are length-prefixed and type-tagged so no two distinct
//! trees or requests share an encoding by concatenation ambiguity.

use super::query::QueryNode;
use super::request::SearchRequest;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Bumped whenever the encoding changes, so stale persisted artifacts
/// (none today — caches are in-memory) can never alias a new scheme.
const ENCODING_VERSION: u8 = 1;

const TAG_AND: u8 = 0x01;
const TAG_OR: u8 = 0x02;
const TAG_NOT: u8 = 0x03;
const TAG_TERM: u8 = 0x04;
const TAG_FIELD_TERM: u8 = 0x05;
const TAG_YEAR: u8 = 0x06;

/// Incremental FNV-1a 64 over the crate's standard hash constants
/// (same parameters as `text::fnv1a`, kept separate because this one
/// streams mixed-width integers, not one byte slice).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(FNV_OFFSET)
    }

    fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(FNV_PRIME);
    }

    fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }

    fn u32(&mut self, v: u32) {
        self.bytes(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }
}

fn encode_node(h: &mut Fnv, node: &QueryNode) {
    match node {
        QueryNode::And(cs) => {
            h.byte(TAG_AND);
            h.u64(cs.len() as u64);
            for c in cs {
                encode_node(h, c);
            }
        }
        QueryNode::Or(cs) => {
            h.byte(TAG_OR);
            h.u64(cs.len() as u64);
            for c in cs {
                encode_node(h, c);
            }
        }
        QueryNode::Not(c) => {
            h.byte(TAG_NOT);
            encode_node(h, c);
        }
        QueryNode::Term(t) => {
            h.byte(TAG_TERM);
            h.str(t);
        }
        QueryNode::FieldTerm(f, t) => {
            h.byte(TAG_FIELD_TERM);
            h.byte(*f as u8);
            h.str(t);
        }
        QueryNode::YearRange(r) => {
            h.byte(TAG_YEAR);
            h.u32(r.min);
            h.u32(r.max);
        }
    }
}

/// The normalized-AST fingerprint: result-cache key material. `ast` must
/// already be canonical (every tree built by `Query::compile` is);
/// logically identical queries — `b AND a` vs `a AND b` — hash equal
/// because they *are* equal after canonicalization.
pub fn query_fingerprint(ast: &QueryNode, top_k: usize, allow_partial: bool, explain: bool) -> u64 {
    let mut h = Fnv::new();
    h.byte(ENCODING_VERSION);
    encode_node(&mut h, ast);
    h.u64(top_k as u64);
    h.byte(allow_partial as u8);
    h.byte(explain as u8);
    h.0
}

/// The plan-cache key: raw request + deployment compile inputs, no
/// parsing required to probe. Covers **every** request field because the
/// cached [`CompiledRequest`](super::CompiledRequest) embeds them all.
pub fn request_plan_key(req: &SearchRequest, features: usize, default_top_k: usize) -> u64 {
    let mut h = Fnv::new();
    h.byte(ENCODING_VERSION);
    h.str(&req.query);
    match req.top_k {
        Some(k) => {
            h.byte(1);
            h.u64(k as u64);
        }
        None => h.byte(0),
    }
    match req.year {
        Some(y) => {
            h.byte(1);
            h.u32(y.min);
            h.u32(y.max);
        }
        None => h.byte(0),
    }
    h.u64(req.require.len() as u64);
    for (f, t) in &req.require {
        h.byte(*f as u8);
        h.str(t);
    }
    h.byte(req.replicas as u8);
    match req.deadline_ms {
        Some(ms) => {
            h.byte(1);
            h.u64(ms);
        }
        None => h.byte(0),
    }
    h.byte(req.allow_partial as u8);
    h.byte(req.explain as u8);
    h.u64(features as u64);
    h.u64(default_top_k as u64);
    h.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{Field, ReplicaPref};

    fn fp(raw: &str) -> u64 {
        SearchRequest::new(raw).compile(512, 10).unwrap().fingerprint
    }

    #[test]
    fn reordered_commutative_operands_share_a_fingerprint() {
        assert_eq!(fp("storage AND replication"), fp("replication AND storage"));
        assert_eq!(fp("grid OR cloud"), fp("cloud OR grid"));
        assert_eq!(
            fp("(grid OR cloud) year:2010..2014"),
            fp("year:2010..2014 (cloud OR grid)")
        );
        // Duplicate operands dedup into the same canonical tree.
        assert_eq!(fp("grid grid computing"), fp("computing grid"));
    }

    #[test]
    fn distinct_queries_get_distinct_fingerprints() {
        let fps = [
            fp("grid"),
            fp("cloud"),
            fp("grid AND cloud"),
            fp("grid OR cloud"),
            fp("grid -cloud"),
            fp("title:grid"),
            fp("grid year:2014"),
            fp("grid year:2015"),
        ];
        for (i, a) in fps.iter().enumerate() {
            for b in &fps[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn result_knobs_fold_into_the_fingerprint() {
        let base = SearchRequest::new("grid").compile(512, 10).unwrap();
        let k20 = SearchRequest::new("grid").top_k(20).compile(512, 10).unwrap();
        let expl = SearchRequest::new("grid").explain(true).compile(512, 10).unwrap();
        let part = SearchRequest::new("grid").allow_partial(true).compile(512, 10).unwrap();
        assert_ne!(base.fingerprint, k20.fingerprint);
        assert_ne!(base.fingerprint, expl.fingerprint);
        assert_ne!(base.fingerprint, part.fingerprint);
        // Resolved default top_k hashes like an explicit equal top_k.
        let k10 = SearchRequest::new("grid").top_k(10).compile(512, 10).unwrap();
        assert_eq!(base.fingerprint, k10.fingerprint);
    }

    #[test]
    fn placement_knobs_do_not_change_the_fingerprint() {
        // Replica preference and deadline shift where/whether work runs,
        // never what a completed run returns — same result-cache entry.
        let base = SearchRequest::new("grid computing").compile(512, 10).unwrap();
        let pri = SearchRequest::new("grid computing")
            .prefer_replicas(ReplicaPref::Primary)
            .compile(512, 10)
            .unwrap();
        let dl = SearchRequest::new("grid computing").deadline_ms(250).compile(512, 10).unwrap();
        assert_eq!(base.fingerprint, pri.fingerprint);
        assert_eq!(base.fingerprint, dl.fingerprint);
    }

    #[test]
    fn plan_key_covers_every_request_field() {
        let base = SearchRequest::new("grid");
        let key = |r: &SearchRequest| request_plan_key(r, 512, 10);
        let variants = [
            SearchRequest::new("cloud"),
            base.clone().top_k(20),
            base.clone().year(2010..=2014),
            base.clone().require(Field::Title, "grid"),
            base.clone().prefer_replicas(ReplicaPref::SameVo),
            base.clone().deadline_ms(250),
            base.clone().allow_partial(true),
            base.clone().explain(true),
        ];
        for v in &variants {
            assert_ne!(key(&base), key(v), "{v:?}");
        }
        // Compile inputs are folded in too.
        assert_ne!(request_plan_key(&base, 256, 10), request_plan_key(&base, 512, 10));
        assert_ne!(request_plan_key(&base, 512, 7), request_plan_key(&base, 512, 10));
        // And the key is stable for an identical request.
        assert_eq!(key(&base), key(&base.clone()));
    }

    #[test]
    fn tagged_encoding_resists_concatenation_aliasing() {
        // Same flattened term sequence, different tree shapes.
        assert_ne!(fp("grid AND cloud"), fp("grid OR cloud"));
        assert_ne!(fp("grid -cloud"), fp("grid cloud"));
        assert_ne!(fp("title:grid"), fp("grid"));
    }
}
