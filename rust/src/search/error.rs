//! Structured search errors: the typed failure taxonomy of the public
//! search surface.
//!
//! Every `pub fn` on the `coordinator`, `search`, and `usi` boundaries
//! returns [`SearchError`] — `anyhow` is retained *internally* (runtime,
//! IO plumbing) and flattened into a variant at the boundary, so callers
//! (the CLI, the REPL, a future HTTP front-end) can branch on failure
//! kind instead of string-matching error messages.

use crate::util::json::Json;

/// Typed failure of a search request (or of deploying the system that
/// would serve it).
#[derive(Debug, Clone, PartialEq)]
pub enum SearchError {
    /// The query text failed to parse or analyze (bad grammar, unknown
    /// field, empty/invalid year range, no searchable terms, ...).
    Parse { message: String },
    /// No data sources are registered with the locator.
    NoSources,
    /// No live nodes are available to plan onto.
    NoNodes,
    /// Every replica of a data source is down: the query cannot cover
    /// the corpus (grid dynamicity exhausted the replication factor).
    NoLiveReplica { source: u32 },
    /// A job referenced a data source the deployment does not host.
    SourceUnknown { source: u32 },
    /// The scoring runtime (PJRT executor / artifacts) failed.
    ExecutorFailure { message: String },
    /// The deployment/configuration is invalid (node count out of range,
    /// corpus too small, feature-space mismatch, ...).
    InvalidConfig { message: String },
    /// An I/O failure on the interface path (REPL stream, config file).
    Io { message: String },
    /// The service cannot take the request right now (executor shutting
    /// down, injected crash, node lost mid-flight) — a retryable
    /// availability condition, not a server fault.
    Unavailable { message: String },
    /// The request's `deadline_ms` budget elapsed before a result was
    /// produced.
    DeadlineExceeded { deadline_ms: u64 },
    /// The admission queue is at its high-water depth; retry after the
    /// hinted delay.
    Overloaded { retry_after_ms: u64 },
    /// Internal invariant breach (a bug, not a user error).
    Internal { message: String },
}

impl SearchError {
    /// Build a parse error.
    pub fn parse(message: impl Into<String>) -> SearchError {
        SearchError::Parse { message: message.into() }
    }

    /// Build an executor error.
    pub fn executor(message: impl std::fmt::Display) -> SearchError {
        SearchError::ExecutorFailure { message: message.to_string() }
    }

    /// Build a config error.
    pub fn config(message: impl std::fmt::Display) -> SearchError {
        SearchError::InvalidConfig { message: message.to_string() }
    }

    /// Build an internal-invariant error.
    pub fn internal(message: impl std::fmt::Display) -> SearchError {
        SearchError::Internal { message: message.to_string() }
    }

    /// Build an availability error (retryable; not a server fault).
    pub fn unavailable(message: impl std::fmt::Display) -> SearchError {
        SearchError::Unavailable { message: message.to_string() }
    }

    /// Stable machine-readable kind tag (wire encoding + error parity
    /// checks in tests).
    pub fn kind(&self) -> &'static str {
        match self {
            SearchError::Parse { .. } => "parse",
            SearchError::NoSources => "no-sources",
            SearchError::NoNodes => "no-nodes",
            SearchError::NoLiveReplica { .. } => "no-live-replica",
            SearchError::SourceUnknown { .. } => "source-unknown",
            SearchError::ExecutorFailure { .. } => "executor-failure",
            SearchError::InvalidConfig { .. } => "invalid-config",
            SearchError::Io { .. } => "io",
            SearchError::Unavailable { .. } => "unavailable",
            SearchError::DeadlineExceeded { .. } => "deadline-exceeded",
            SearchError::Overloaded { .. } => "overloaded",
            SearchError::Internal { .. } => "internal",
        }
    }

    /// JSON wire form: `{"kind": ..., "message": ..., "source"?: n}`.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("kind", Json::str(self.kind()))];
        match self {
            SearchError::NoLiveReplica { source } | SearchError::SourceUnknown { source } => {
                pairs.push(("source", Json::from(*source as i64)));
            }
            SearchError::DeadlineExceeded { deadline_ms } => {
                pairs.push(("deadline_ms", Json::from(*deadline_ms as i64)));
            }
            SearchError::Overloaded { retry_after_ms } => {
                pairs.push(("retry_after_ms", Json::from(*retry_after_ms as i64)));
            }
            _ => {}
        }
        pairs.push(("message", Json::str(self.to_string())));
        Json::obj(pairs)
    }
}

impl std::fmt::Display for SearchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SearchError::Parse { message } => write!(f, "query error: {message}"),
            SearchError::NoSources => write!(f, "no data sources registered"),
            SearchError::NoNodes => write!(f, "no nodes available"),
            SearchError::NoLiveReplica { source } => {
                write!(f, "source {source} has no live replica")
            }
            SearchError::SourceUnknown { source } => write!(f, "unknown source {source}"),
            SearchError::ExecutorFailure { message } => write!(f, "executor failure: {message}"),
            SearchError::InvalidConfig { message } => write!(f, "invalid config: {message}"),
            SearchError::Io { message } => write!(f, "io error: {message}"),
            SearchError::Unavailable { message } => {
                write!(f, "service unavailable: {message}")
            }
            SearchError::DeadlineExceeded { deadline_ms } => {
                write!(f, "deadline of {deadline_ms} ms exceeded")
            }
            SearchError::Overloaded { retry_after_ms } => {
                write!(f, "admission queue full; retry after {retry_after_ms} ms")
            }
            SearchError::Internal { message } => write!(f, "internal error: {message}"),
        }
    }
}

impl std::error::Error for SearchError {}

impl From<std::io::Error> for SearchError {
    fn from(e: std::io::Error) -> SearchError {
        SearchError::Io { message: e.to_string() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_stable_and_distinct() {
        let all = [
            SearchError::parse("x"),
            SearchError::NoSources,
            SearchError::NoNodes,
            SearchError::NoLiveReplica { source: 3 },
            SearchError::SourceUnknown { source: 9 },
            SearchError::executor("boom"),
            SearchError::config("bad"),
            SearchError::Io { message: "eof".into() },
            SearchError::unavailable("draining"),
            SearchError::DeadlineExceeded { deadline_ms: 50 },
            SearchError::Overloaded { retry_after_ms: 25 },
            SearchError::internal("bug"),
        ];
        let mut kinds: Vec<&str> = all.iter().map(|e| e.kind()).collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert_eq!(kinds.len(), all.len(), "kind tags must be unique");
    }

    #[test]
    fn json_carries_kind_and_source() {
        let e = SearchError::NoLiveReplica { source: 7 };
        let j = e.to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("no-live-replica"));
        assert_eq!(j.get("source").unwrap().as_i64(), Some(7));
        assert!(j.get("message").unwrap().as_str().unwrap().contains("7"));
    }

    #[test]
    fn json_carries_budget_hints() {
        let d = SearchError::DeadlineExceeded { deadline_ms: 120 }.to_json();
        assert_eq!(d.get("kind").unwrap().as_str(), Some("deadline-exceeded"));
        assert_eq!(d.get("deadline_ms").unwrap().as_i64(), Some(120));
        let o = SearchError::Overloaded { retry_after_ms: 40 }.to_json();
        assert_eq!(o.get("kind").unwrap().as_str(), Some("overloaded"));
        assert_eq!(o.get("retry_after_ms").unwrap().as_i64(), Some(40));
    }

    #[test]
    fn io_errors_convert() {
        let io = std::io::Error::new(std::io::ErrorKind::BrokenPipe, "pipe");
        let e: SearchError = io.into();
        assert_eq!(e.kind(), "io");
    }

    #[test]
    fn interops_with_internal_anyhow() {
        // Internal layers keep anyhow: `?` must lift SearchError into it.
        fn inner() -> anyhow::Result<()> {
            let r: Result<(), SearchError> = Err(SearchError::NoSources);
            r?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("no data sources"));
    }
}
