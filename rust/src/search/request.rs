//! Typed search requests: the builder the USI, CLI, benches, and a
//! future HTTP front-end all construct, plus its JSON wire encoding
//! (shared with the Job Description File, so one serialization crosses
//! every boundary).
//!
//! ```
//! use gaps::search::{Field, ReplicaPref, SearchRequest};
//!
//! let req = SearchRequest::new("grid computing")
//!     .top_k(20)
//!     .year(2010..=2014)
//!     .require(Field::Title, "grid")
//!     .prefer_replicas(ReplicaPref::SameVo)
//!     .explain(true);
//! // One JSON wire form, shared with the JDF and the HTTP front-end:
//! let wire = req.to_json();
//! assert_eq!(SearchRequest::from_json(&wire), Some(req));
//! ```

use crate::text::{terms, Field};
use crate::util::json::Json;

use super::error::SearchError;
use super::query::{Query, QueryNode, RangeFilter};

/// Replica-selection preference for planning (the data itself is
/// identical on every replica, so this only shifts *where* work runs,
/// never *what* is returned).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum ReplicaPref {
    /// Planner's free choice among live replicas (default).
    #[default]
    Any,
    /// Prefer replicas in the root broker's VO (keeps dispatch on the
    /// LAN when the placement allows it).
    SameVo,
    /// Prefer each source's primary replica when it is live.
    Primary,
}

impl ReplicaPref {
    pub fn name(self) -> &'static str {
        match self {
            ReplicaPref::Any => "any",
            ReplicaPref::SameVo => "same-vo",
            ReplicaPref::Primary => "primary",
        }
    }

    pub fn parse(s: &str) -> Option<ReplicaPref> {
        match s.to_ascii_lowercase().as_str() {
            "any" => Some(ReplicaPref::Any),
            "same-vo" | "samevo" | "same_vo" => Some(ReplicaPref::SameVo),
            "primary" => Some(ReplicaPref::Primary),
            _ => None,
        }
    }
}

/// A typed search request. Build with [`SearchRequest::new`] + the
/// chainable setters; execute with `GapsSystem::search_request` /
/// `GapsSystem::search_batch`.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchRequest {
    /// Query text in the grammar of [`super::query`].
    pub query: String,
    /// Per-request result count (`None`: the deployment's configured
    /// `search.top_k`).
    pub top_k: Option<usize>,
    /// Extra hard year constraint, ANDed with the query text.
    pub year: Option<RangeFilter>,
    /// Extra hard field-scoped terms, ANDed with the query text. The
    /// text is analyzer-normalized at compile time.
    pub require: Vec<(Field, String)>,
    /// Replica-selection preference for the execution plan.
    pub replicas: ReplicaPref,
    /// Wall-clock budget for the whole request, in milliseconds. When it
    /// elapses before planning (or before a failover retry) completes,
    /// the request fails with `SearchError::DeadlineExceeded`.
    pub deadline_ms: Option<u64>,
    /// Accept a degraded response: when some sources have no live
    /// replica, return top-k over the reachable sources (with
    /// `degraded: true` and the missing-source list in the wire form)
    /// instead of failing the request.
    pub allow_partial: bool,
    /// Attach a [`crate::coordinator::Explain`] record to the response.
    pub explain: bool,
}

impl SearchRequest {
    /// A request for `query` with every knob at its default.
    pub fn new(query: impl Into<String>) -> SearchRequest {
        SearchRequest {
            query: query.into(),
            top_k: None,
            year: None,
            require: Vec::new(),
            replicas: ReplicaPref::Any,
            deadline_ms: None,
            allow_partial: false,
            explain: false,
        }
    }

    /// Results wanted (overrides the deployment default).
    pub fn top_k(mut self, k: usize) -> SearchRequest {
        self.top_k = Some(k);
        self
    }

    /// Hard inclusive year filter, ANDed with the query text.
    pub fn year(mut self, range: std::ops::RangeInclusive<u32>) -> SearchRequest {
        self.year = Some(RangeFilter { min: *range.start(), max: *range.end() });
        self
    }

    /// Require `text`'s terms to appear in `field` (ANDed with the query
    /// text; also scored).
    pub fn require(mut self, field: Field, text: impl Into<String>) -> SearchRequest {
        self.require.push((field, text.into()));
        self
    }

    /// Replica-selection preference.
    pub fn prefer_replicas(mut self, pref: ReplicaPref) -> SearchRequest {
        self.replicas = pref;
        self
    }

    /// Wall-clock budget in milliseconds (typed `DeadlineExceeded` /
    /// HTTP 504 when it elapses).
    pub fn deadline_ms(mut self, ms: u64) -> SearchRequest {
        self.deadline_ms = Some(ms);
        self
    }

    /// Accept a degraded (partial-coverage) response instead of a hard
    /// availability error when sources are unreachable.
    pub fn allow_partial(mut self, on: bool) -> SearchRequest {
        self.allow_partial = on;
        self
    }

    /// Attach plan/AST diagnostics to the response.
    pub fn explain(mut self, on: bool) -> SearchRequest {
        self.explain = on;
        self
    }

    /// Parse the query text and graft the builder constraints onto the
    /// AST, resolving `top_k` against the deployment default.
    pub fn compile(
        &self,
        features: usize,
        default_top_k: usize,
    ) -> Result<CompiledRequest, SearchError> {
        let mut extra: Vec<QueryNode> = Vec::new();
        if let Some(year) = self.year {
            if year.min > year.max {
                return Err(SearchError::parse(format!(
                    "empty year range {}..{}",
                    year.min, year.max
                )));
            }
            extra.push(QueryNode::YearRange(year));
        }
        for (field, text) in &self.require {
            let normalized = terms(text);
            if normalized.is_empty() {
                return Err(SearchError::parse(format!(
                    "required {} term {text:?} has no searchable terms",
                    field.name()
                )));
            }
            extra.extend(normalized.into_iter().map(|t| QueryNode::FieldTerm(*field, t)));
        }
        let query = if extra.is_empty() {
            Query::parse(&self.query, features)?
        } else if self.query.trim().is_empty() {
            Query::compile(&self.query, QueryNode::And(extra), features)?
        } else {
            let parsed = Query::parse(&self.query, features)?;
            extra.insert(0, parsed.ast);
            Query::compile(&self.query, QueryNode::And(extra), features)?
        };
        let top_k = self.top_k.unwrap_or(default_top_k);
        let fingerprint = super::fingerprint::query_fingerprint(
            &query.ast,
            top_k,
            self.allow_partial,
            self.explain,
        );
        Ok(CompiledRequest {
            query,
            top_k,
            replicas: self.replicas,
            deadline_ms: self.deadline_ms,
            allow_partial: self.allow_partial,
            explain: self.explain,
            fingerprint,
        })
    }

    // ------------------------------------------------------------- wire

    /// JSON wire form (shared by the JDF and the response envelope).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("query", Json::str(&self.query))];
        if let Some(k) = self.top_k {
            pairs.push(("top_k", Json::from(k)));
        }
        if let Some(y) = self.year {
            pairs.push((
                "year",
                Json::obj(vec![
                    ("min", Json::from(y.min as i64)),
                    ("max", Json::from(y.max as i64)),
                ]),
            ));
        }
        if !self.require.is_empty() {
            pairs.push((
                "require",
                Json::Arr(
                    self.require
                        .iter()
                        .map(|(f, t)| Json::Arr(vec![Json::str(f.name()), Json::str(t.clone())]))
                        .collect(),
                ),
            ));
        }
        if self.replicas != ReplicaPref::Any {
            pairs.push(("replicas", Json::str(self.replicas.name())));
        }
        if let Some(ms) = self.deadline_ms {
            pairs.push(("deadline_ms", Json::from(ms as i64)));
        }
        if self.allow_partial {
            pairs.push(("allow_partial", Json::Bool(true)));
        }
        if self.explain {
            pairs.push(("explain", Json::Bool(true)));
        }
        Json::obj(pairs)
    }

    /// Parse the JSON wire form.
    pub fn from_json(v: &Json) -> Option<SearchRequest> {
        let mut req = SearchRequest::new(v.get("query")?.as_str()?);
        if let Some(k) = v.get("top_k") {
            req.top_k = Some(k.as_i64()? as usize);
        }
        if let Some(y) = v.get("year") {
            req.year = Some(RangeFilter {
                min: y.get("min")?.as_i64()? as u32,
                max: y.get("max")?.as_i64()? as u32,
            });
        }
        if let Some(reqs) = v.get("require") {
            for pair in reqs.as_arr()? {
                let pair = pair.as_arr()?;
                let field = Field::parse(pair.first()?.as_str()?)?;
                req.require.push((field, pair.get(1)?.as_str()?.to_string()));
            }
        }
        if let Some(r) = v.get("replicas") {
            req.replicas = ReplicaPref::parse(r.as_str()?)?;
        }
        if let Some(ms) = v.get("deadline_ms") {
            req.deadline_ms = Some(ms.as_i64()? as u64);
        }
        if let Some(p) = v.get("allow_partial") {
            req.allow_partial = p.as_bool()?;
        }
        if let Some(e) = v.get("explain") {
            req.explain = e.as_bool()?;
        }
        Some(req)
    }

    /// Wire size in bytes (charged to the network model by the JDF).
    pub fn wire_bytes(&self) -> usize {
        self.to_json().to_string_compact().len()
    }
}

/// A request compiled against a deployment's feature space: the parsed
/// [`Query`] plus resolved per-request execution knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledRequest {
    pub query: Query,
    pub top_k: usize,
    pub replicas: ReplicaPref,
    pub deadline_ms: Option<u64>,
    pub allow_partial: bool,
    pub explain: bool,
    /// Normalized-AST fingerprint (see [`super::fingerprint`]): the
    /// result-cache key material. Equal for logically identical queries
    /// (commutative operands sorted, duplicates deduped) with the same
    /// result-affecting knobs; excludes placement-only knobs
    /// (`replicas`, `deadline_ms`).
    pub fingerprint: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains_and_compiles() {
        let req = SearchRequest::new("grid computing")
            .top_k(20)
            .year(2010..=2014)
            .require(Field::Title, "grid")
            .prefer_replicas(ReplicaPref::SameVo)
            .deadline_ms(500)
            .allow_partial(true)
            .explain(true);
        let c = req.compile(512, 10).unwrap();
        assert_eq!(c.top_k, 20);
        assert_eq!(c.replicas, ReplicaPref::SameVo);
        assert_eq!(c.deadline_ms, Some(500));
        assert!(c.allow_partial);
        assert!(c.explain);
        assert!(c.query.is_multivariate());
        // Builder constraints are hard conjuncts on the AST.
        let rendered = c.query.ast.to_string();
        assert!(rendered.contains("year:2010..2014"), "{rendered}");
        assert!(rendered.contains("title:grid"), "{rendered}");
    }

    #[test]
    fn default_top_k_resolves_from_deployment() {
        let c = SearchRequest::new("grid").compile(512, 7).unwrap();
        assert_eq!(c.top_k, 7);
        assert_eq!(c.replicas, ReplicaPref::Any);
    }

    #[test]
    fn builder_only_request_is_valid() {
        // No query text, but a hard year filter: legal (pure filter).
        let c = SearchRequest::new("").year(2005..=2009).compile(512, 10).unwrap();
        assert!(c.query.keywords.is_empty());
        assert!(c.query.is_multivariate());
    }

    #[test]
    fn bad_inputs_are_parse_errors() {
        assert_eq!(SearchRequest::new("").compile(512, 10).unwrap_err().kind(), "parse");
        assert_eq!(
            SearchRequest::new("grid")
                .require(Field::Venue, "the")
                .compile(512, 10)
                .unwrap_err()
                .kind(),
            "parse"
        );
        assert_eq!(
            SearchRequest::new("body:grid").compile(512, 10).unwrap_err().kind(),
            "parse"
        );
    }

    #[test]
    fn json_roundtrip() {
        let req = SearchRequest::new("\"grid computing\" -cloud")
            .top_k(5)
            .year(2000..=2003)
            .require(Field::Authors, "zhang")
            .prefer_replicas(ReplicaPref::Primary)
            .deadline_ms(250)
            .allow_partial(true)
            .explain(true);
        let parsed = SearchRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(parsed, req);
        // Defaults serialize compactly and roundtrip too.
        let bare = SearchRequest::new("grid");
        assert_eq!(SearchRequest::from_json(&bare.to_json()).unwrap(), bare);
        assert!(bare.wire_bytes() < req.wire_bytes());
    }

    #[test]
    fn replica_pref_parse_roundtrip() {
        for p in [ReplicaPref::Any, ReplicaPref::SameVo, ReplicaPref::Primary] {
            assert_eq!(ReplicaPref::parse(p.name()), Some(p));
        }
        assert_eq!(ReplicaPref::parse("bogus"), None);
    }
}
