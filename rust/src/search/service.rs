//! Search Service (SS): the per-node grid service that executes one search
//! job against its local shard.
//!
//! Paper: "The local Search Service module was a Java program installed in
//! each worker node ... responsible for performing the search process in
//! the local dataset." Here it is a rust service with a two-phase local
//! search:
//!
//! 1. **retrieve** — inverted-index OR-probe over the query buckets,
//!    producing up to `max_candidates` candidates (+ multivariate
//!    filtering: field-scoped terms and year ranges);
//! 2. **rank** — candidates are packed into dense blocks and scored by the
//!    AOT artifact on the PJRT runtime ([`Scorer::Xla`]) or the pure-rust
//!    fallback ([`Scorer::Rust`], also the traditional baseline's path).
//!
//! The returned [`SearchOutcome`] carries measured work time; fabric
//! overheads are added by the coordinator (they belong to the grid, not
//! the service).

use std::cell::RefCell;

use crate::config::SearchConfig;
use crate::index::{build_query_weights, pack_block, GlobalStats, RetrievalScratch, Shard};
#[allow(unused_imports)]
use crate::runtime::Executor;
use crate::util::clock::WallClock;

thread_local! {
    /// Reused retrieval scratch: the counting OR-merge runs against this
    /// instead of allocating a `HashMap` per query. Thread-local (not a
    /// `SearchService` field) because the coordinator fans search jobs
    /// out over scoped worker threads; each worker warms its own scratch
    /// and reuses it across every shard it serves.
    static RETRIEVAL_SCRATCH: RefCell<RetrievalScratch> =
        RefCell::new(RetrievalScratch::new());
}

use super::query::ParsedQuery;
use super::scorer::{score_block_rust, topk_row};

/// One hit from a local shard: corpus-global doc id + BM25F score.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LocalHit {
    pub global_id: u64,
    pub score: f32,
}

/// Result of one local search job.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Top hits (sorted by score descending), at most `top_k`.
    pub hits: Vec<LocalHit>,
    /// Candidates retrieved before ranking.
    pub candidates: usize,
    /// Documents in the shard (for scan-rate metrics).
    pub shard_docs: usize,
    /// Measured wall time of the local work (seconds).
    pub work_s: f64,
}

/// Scoring backend handed to the service by the coordinator.
pub enum Scorer<'a> {
    /// AOT artifact through the PJRT runtime (the production path).
    Xla(&'a mut Executor),
    /// Pure-rust scorer (baseline path / no-artifact environments).
    Rust,
}

/// The Search Service. Stateless between jobs apart from the shard it
/// serves (deployed once per node; see `grid::ServiceContainer`).
#[derive(Debug)]
pub struct SearchService {
    /// Search/scoring parameters (shared ABI constants).
    cfg: SearchConfig,
}

impl SearchService {
    pub fn new(cfg: SearchConfig) -> Self {
        SearchService { cfg }
    }

    pub fn config(&self) -> &SearchConfig {
        &self.cfg
    }

    /// Execute one search job against `shard`.
    pub fn search(
        &self,
        shard: &Shard,
        stats: &GlobalStats,
        query: &ParsedQuery,
        scorer: &mut Scorer<'_>,
    ) -> anyhow::Result<SearchOutcome> {
        let clock = WallClock::start();
        let cfg = &self.cfg;

        // ---- Phase 1: retrieval ------------------------------------
        let mut candidates: Vec<u32> = if query.buckets.is_empty() {
            // Pure-filter query (e.g. `year:2014`): all docs are candidates.
            (0..shard.len() as u32).collect()
        } else {
            RETRIEVAL_SCRATCH.with(|s| {
                let mut s = s.borrow_mut();
                shard.inverted.retrieve_into(&query.buckets, cfg.max_candidates, &mut s);
                s.hits().iter().map(|&(id, _)| id).collect()
            })
        };

        // Multivariate filters.
        if let Some(range) = query.year {
            candidates.retain(|&lid| range.contains(shard.pubs[lid as usize].year));
        }
        for (field, term) in &query.field_terms {
            let bucket = crate::text::term_feature(term, cfg.features) as u32;
            candidates.retain(|&lid| {
                shard.docs[lid as usize].field_tf[*field as usize]
                    .iter()
                    .any(|(b, _)| *b == bucket)
            });
        }
        candidates.truncate(cfg.max_candidates);

        let retrieved = candidates.len();
        if retrieved == 0 {
            return Ok(SearchOutcome {
                hits: Vec::new(),
                candidates: 0,
                shard_docs: shard.len(),
                work_s: clock.elapsed_s(),
            });
        }

        // ---- Phase 2: ranking ---------------------------------------
        let queries = vec![query.buckets.clone()];
        let mut all_hits: Vec<LocalHit> = Vec::new();

        match scorer {
            Scorer::Xla(exec) => {
                // Chunk candidates to the largest artifact block; each
                // chunk is packed by the executor's reused packer
                // (§Perf P2) into the smallest variant that fits.
                let max_d = exec
                    .manifest()
                    .max_block(1, cfg.features)
                    .map(|a| a.d)
                    .ok_or_else(|| {
                        anyhow::anyhow!("no artifact for F={}", cfg.features)
                    })?;
                let qw = build_query_weights(&queries, stats, cfg.features, 1);
                for chunk in candidates.chunks(max_d) {
                    let ranked = exec.rank_candidates(
                        shard,
                        stats,
                        chunk,
                        &qw,
                        1,
                        &cfg.field_weights,
                        cfg.b,
                    )?;
                    for &(local_idx, score) in &ranked[0] {
                        all_hits.push(LocalHit {
                            global_id: shard.docs[chunk[local_idx as usize] as usize].global_id,
                            score,
                        });
                    }
                }
            }
            Scorer::Rust => {
                let qw = build_query_weights(&queries, stats, cfg.features, 1);
                // One exact-size block (no padding needed off the ABI path).
                let block = pack_block(shard, stats, &candidates, candidates.len(), cfg.b);
                let scores =
                    score_block_rust(&block, &qw, 1, &cfg.field_weights, k1_const());
                for (local_idx, score) in topk_row(&scores, block.n_real, cfg.top_k) {
                    all_hits.push(LocalHit {
                        global_id: shard.docs[candidates[local_idx as usize] as usize].global_id,
                        score,
                    });
                }
            }
        }

        // Local top-k across chunks. total_cmp: a NaN score (corrupt
        // artifact output) must not panic the service.
        all_hits.sort_by(|a, b| {
            b.score.total_cmp(&a.score).then(a.global_id.cmp(&b.global_id))
        });
        all_hits.truncate(cfg.top_k);

        Ok(SearchOutcome {
            hits: all_hits,
            candidates: retrieved,
            shard_docs: shard.len(),
            work_s: clock.elapsed_s(),
        })
    }
}

/// BM25 k1 shared with the artifacts (python/compile/model.py DEFAULT_K1).
pub const fn k1_const() -> f32 {
    1.2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchConfig;
    use crate::corpus::{CorpusGenerator, CorpusSpec};
    use crate::index::{Shard, ShardStats};

    fn setup(n: u64) -> (Shard, GlobalStats, SearchService) {
        let spec = CorpusSpec { num_docs: n, vocab_size: 400, ..CorpusSpec::default() };
        let gen = CorpusGenerator::new(spec);
        let shard = Shard::build(0, gen.generate_range(0, n), 512);
        let mut acc = ShardStats::empty(512);
        acc.merge(&shard.stats);
        let cfg = SearchConfig { use_xla: false, ..SearchConfig::default() };
        (shard, acc.finalize(), SearchService::new(cfg))
    }

    /// A query built from an existing doc's title (guaranteed hits).
    fn title_query(shard: &Shard, local: usize) -> ParsedQuery {
        let title = shard.pubs[local].title.clone();
        ParsedQuery::parse(&title, 512).unwrap()
    }

    #[test]
    fn finds_the_source_document() {
        let (shard, stats, ss) = setup(60);
        let q = title_query(&shard, 17);
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        assert!(out.candidates > 0);
        assert!(!out.hits.is_empty());
        assert!(
            out.hits.iter().any(|h| h.global_id == 17),
            "doc 17 missing from {:?}",
            out.hits
        );
        // Scores sorted descending.
        for w in out.hits.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        assert!(out.work_s > 0.0);
    }

    #[test]
    fn respects_top_k() {
        let (shard, stats, _) = setup(80);
        let mut cfg = SearchConfig { use_xla: false, ..SearchConfig::default() };
        cfg.top_k = 3;
        let ss = SearchService::new(cfg);
        let q = ParsedQuery::parse("grid data search distributed", 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        assert!(out.hits.len() <= 3);
    }

    #[test]
    fn year_filter_is_hard() {
        let (shard, stats, ss) = setup(80);
        let year = shard.pubs[5].year;
        let raw = format!("{} year:{year}", shard.pubs[5].title);
        let q = ParsedQuery::parse(&raw, 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        for h in &out.hits {
            assert_eq!(shard.pubs[h.global_id as usize].year, year);
        }
        assert!(out.hits.iter().any(|h| h.global_id == 5));
    }

    #[test]
    fn year_only_query_scans_shard() {
        let (shard, stats, ss) = setup(50);
        let q = ParsedQuery::parse("year:2000..2014", 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        // All hits satisfy the filter; scores are 0 (no keywords).
        for h in &out.hits {
            assert!((2000..=2014).contains(&shard.pubs[h.global_id as usize].year));
        }
    }

    #[test]
    fn field_scoped_term_filters() {
        let (shard, stats, ss) = setup(80);
        // Scope to the venue of doc 3.
        let venue_word = shard.pubs[3]
            .venue
            .split_whitespace()
            .next()
            .unwrap()
            .to_string();
        let q = ParsedQuery::parse(&format!("venue:{venue_word}"), 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        let stemmed = crate::text::tokenize(&venue_word)[0].term.clone();
        for h in &out.hits {
            let venue_terms: Vec<String> = crate::text::tokenize(
                &shard.pubs[h.global_id as usize].venue,
            )
            .into_iter()
            .map(|t| t.term)
            .collect();
            assert!(
                venue_terms.contains(&stemmed),
                "hit {} venue {:?} lacks {stemmed:?}",
                h.global_id,
                venue_terms
            );
        }
    }

    #[test]
    fn no_match_query_returns_empty() {
        let (shard, stats, ss) = setup(30);
        let q = ParsedQuery::parse("qqqqzzzz xxxyyy", 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        // Terms may collide into occupied buckets, but usually empty:
        // at minimum the call must succeed and respect top_k.
        assert!(out.hits.len() <= ss.config().top_k);
    }
}
