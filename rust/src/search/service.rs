//! Search Service (SS): the per-node grid service that executes search
//! jobs against its local shard.
//!
//! Paper: "The local Search Service module was a Java program installed in
//! each worker node ... responsible for performing the search process in
//! the local dataset." Here it is a rust service with a two-phase local
//! search:
//!
//! 1. **retrieve** — per query: the galloping AND-intersection for pure
//!    conjunctions (phrases, `AND` chains), the counting OR-merge over
//!    the query buckets otherwise, followed by the compiled AST matcher
//!    for boolean structure the probes cannot express (negations, field
//!    scopes, year ranges, nested groups);
//! 2. **rank** — on the artifact path ([`Scorer::Xla`]) a batch whose
//!    queries share one candidate set is scored with Q>1 query rows per
//!    block (the ABI's batched execution); heterogeneous batches and the
//!    pure-rust fallback ([`Scorer::Rust`]) score per-query exact-size
//!    blocks — BM25F scores are per (query, doc) and independent of the
//!    other block rows, so every formulation returns identical hits.
//!
//! The returned [`SearchOutcome`]s carry measured work time; fabric
//! costs are added by the coordinator (they belong to the grid, not the
//! service).

use std::cell::RefCell;

use crate::config::SearchConfig;
use crate::index::{
    build_query_weights, GlobalStats, Packer, RetrievalCounters, RetrievalScratch, Shard,
};
#[allow(unused_imports)]
use crate::runtime::Executor;
use crate::util::clock::WallClock;

thread_local! {
    /// Reused retrieval scratch: the block-max WAND merge runs against
    /// this instead of allocating per query. Thread-local (not a
    /// `SearchService` field) because the coordinator fans search jobs
    /// out over the resident gridpool workers (`Pool::scope_map`); each
    /// worker reuses its scratch across every shard and batched query of
    /// a fan-out, and — because the pool workers are long-lived — across
    /// *batches* too: in a multi-user serving workload the scratch warms
    /// up once per deployment, not once per request round.
    static RETRIEVAL_SCRATCH: RefCell<RetrievalScratch> =
        RefCell::new(RetrievalScratch::new());

    /// Reused dense packer for the rust-scorer ranking path (same
    /// rationale): candidate tiles are sparse-cleared instead of
    /// reallocated per query.
    static PACKER: RefCell<Packer> = RefCell::new(Packer::new());
}

use super::error::SearchError;
use super::query::{Query, RetrievalHint};
use super::scorer::{score_block_rust, topk_row};

/// One hit from a local shard: corpus-global doc id + BM25F score.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LocalHit {
    pub global_id: u64,
    pub score: f32,
}

/// Result of one local search job (per query).
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Top hits (sorted by score descending), at most `top_k`.
    pub hits: Vec<LocalHit>,
    /// Candidates retrieved before ranking.
    pub candidates: usize,
    /// Documents in the shard (for scan-rate metrics).
    pub shard_docs: usize,
    /// Deterministic retrieval work counters (postings touched/skipped,
    /// blocks skipped) for this query on this shard.
    pub counters: RetrievalCounters,
    /// Measured wall time of the local work (seconds; for a batch, the
    /// per-query share of the shared pass).
    pub work_s: f64,
}

/// Scoring backend handed to the service by the coordinator.
pub enum Scorer<'a> {
    /// AOT artifact through the PJRT runtime (the production path).
    Xla(&'a mut Executor),
    /// Pure-rust scorer (baseline path / no-artifact environments).
    Rust,
}

/// The Search Service. Stateless between jobs apart from the shard it
/// serves (deployed once per node; see `grid::ServiceContainer`).
#[derive(Debug)]
pub struct SearchService {
    /// Search/scoring parameters (shared ABI constants).
    cfg: SearchConfig,
}

impl SearchService {
    pub fn new(cfg: SearchConfig) -> Self {
        SearchService { cfg }
    }

    pub fn config(&self) -> &SearchConfig {
        &self.cfg
    }

    /// Execute one query against `shard` with the configured `top_k`.
    pub fn search(
        &self,
        shard: &Shard,
        stats: &GlobalStats,
        query: &Query,
        scorer: &mut Scorer<'_>,
    ) -> Result<SearchOutcome, SearchError> {
        let top_k = self.cfg.top_k;
        let mut out = self.search_batch(shard, stats, &[(query, top_k)], scorer)?;
        Ok(out.pop().expect("one outcome per query"))
    }

    /// Execute a whole query batch against `shard` in one pass:
    /// per-query retrieval (shared scratch), then ranking — batched
    /// Q-row artifact executions where candidate sets align, per-query
    /// blocks otherwise (see [`Scorer`] and the module docs). Each
    /// `(query, top_k)` pair yields one [`SearchOutcome`], order
    /// preserved.
    pub fn search_batch(
        &self,
        shard: &Shard,
        stats: &GlobalStats,
        queries: &[(&Query, usize)],
        scorer: &mut Scorer<'_>,
    ) -> Result<Vec<SearchOutcome>, SearchError> {
        let clock = WallClock::start();
        let cfg = &self.cfg;
        let nq = queries.len();
        if nq == 0 {
            return Ok(Vec::new());
        }

        // ---- Phase 1: per-query retrieval ---------------------------
        // Dispatch on the hint compiled into the query (see
        // `query::RetrievalHint`) instead of re-deriving structure here.
        let mut cand_sets: Vec<Vec<u32>> = Vec::with_capacity(nq);
        let mut cand_counters: Vec<RetrievalCounters> = Vec::with_capacity(nq);
        for (query, _) in queries {
            let mut counters = RetrievalCounters::default();
            let mut candidates: Vec<u32> = match query.retrieval_hint() {
                RetrievalHint::GallopAnd => {
                    // Pure term conjunction: galloping AND-intersection,
                    // capped at the candidate budget.
                    shard.inverted.retrieve_all_counted(
                        &query.buckets,
                        cfg.max_candidates,
                        &mut counters,
                    )
                }
                RetrievalHint::ScanMatcher => {
                    // The OR probe cannot reach every match (pure filters
                    // like `year:2014`, or a term-free branch like
                    // `grid OR year:2014`): scan the shard with the
                    // matcher fused in, stopping at the candidate budget.
                    let scanned: Vec<u32> = (0..shard.len() as u32)
                        .filter(|&lid| query.matches(shard, lid))
                        .take(cfg.max_candidates)
                        .collect();
                    counters.candidates_emitted = scanned.len() as u64;
                    scanned
                }
                hint @ (RetrievalHint::PrunedOr | RetrievalHint::PrunedOrFiltered) => {
                    // Block-max pruned OR over the scored buckets, then
                    // the compiled AST matcher for structure beyond the
                    // probe. Candidates arrive pre-ranked by impact.
                    let mut pool: Vec<u32> = RETRIEVAL_SCRATCH.with(|s| {
                        let mut s = s.borrow_mut();
                        shard.inverted.retrieve_into(
                            &query.buckets,
                            cfg.max_candidates,
                            &mut s,
                        );
                        counters = *s.counters();
                        s.hits().iter().map(|&(id, _)| id).collect()
                    });
                    if hint == RetrievalHint::PrunedOrFiltered {
                        pool.retain(|&lid| query.matches(shard, lid));
                    }
                    pool
                }
            };
            candidates.truncate(cfg.max_candidates);
            cand_sets.push(candidates);
            cand_counters.push(counters);
        }

        // ---- Phase 2: ranking ---------------------------------------
        let mut per_query_hits: Vec<Vec<LocalHit>> = vec![Vec::new(); nq];
        match scorer {
            Scorer::Xla(exec) => {
                // Artifact path: Q>1 rows per execution when the batch
                // shares one candidate set, per-query blocks otherwise.
                let hits = &mut per_query_hits;
                self.rank_xla(exec, shard, stats, queries, &cand_sets, hits)?;
            }
            Scorer::Rust => {
                // Fallback scorer: per-query exact-size blocks + bounded
                // top-k selection (PR 1's path). BM25F scores are per
                // (query, doc) and block-independent, so this is
                // bit-identical to any shared-block formulation while
                // doing |own candidates| work per query instead of
                // |union| — the rust scorer gains nothing from Q>1 rows.
                for (qi, (query, top_k)) in queries.iter().enumerate() {
                    let cands = &cand_sets[qi];
                    if cands.is_empty() {
                        continue;
                    }
                    let qw = build_query_weights(
                        std::slice::from_ref(&query.buckets),
                        stats,
                        cfg.features,
                        1,
                    );
                    PACKER.with(|p| {
                        let mut p = p.borrow_mut();
                        let block = p.pack(shard, stats, cands, cands.len(), cfg.b);
                        let scores =
                            score_block_rust(block, &qw, 1, &cfg.field_weights, k1_const());
                        for (local_idx, score) in topk_row(&scores, block.n_real, *top_k) {
                            per_query_hits[qi].push(LocalHit {
                                global_id: shard.docs[cands[local_idx as usize] as usize]
                                    .global_id,
                                score,
                            });
                        }
                    });
                }
            }
        }

        // Per-query top-k. total_cmp: a NaN score (corrupt artifact
        // output) must not panic the service.
        let work_total = clock.elapsed_s();
        let work_each = work_total / nq as f64;
        let mut outcomes = Vec::with_capacity(nq);
        for (qi, (_, top_k)) in queries.iter().enumerate() {
            let mut hits = std::mem::take(&mut per_query_hits[qi]);
            hits.sort_by(|a, b| b.score.total_cmp(&a.score).then(a.global_id.cmp(&b.global_id)));
            hits.truncate(*top_k);
            outcomes.push(SearchOutcome {
                hits,
                candidates: cand_sets[qi].len(),
                shard_docs: shard.len(),
                counters: cand_counters[qi],
                work_s: work_each,
            });
        }
        Ok(outcomes)
    }

    /// Artifact path of the batch ranking.
    ///
    /// The artifact returns only its top `k` rows per block, computed
    /// over the whole block — so shared Q-row blocks are only exact
    /// when every query wants the same docs. Strategy:
    ///
    /// * **Homogeneous batch** (all candidate sets equal — always true
    ///   for Q = 1): feed Q>1 query rows per block over the shared
    ///   candidate list, amortizing executions across the batch. If a
    ///   request's `top_k` exceeds the artifact `k`, blocks are capped
    ///   at `k` so per-block truncation cannot drop qualifying docs.
    /// * **Heterogeneous batch**: per-query solo-style executions over
    ///   each query's own candidates (exactly the pre-batch path) —
    ///   exact, and strictly cheaper than scoring every query against
    ///   the whole union in `k`-sized blocks.
    fn rank_xla(
        &self,
        exec: &mut Executor,
        shard: &Shard,
        stats: &GlobalStats,
        queries: &[(&Query, usize)],
        cand_sets: &[Vec<u32>],
        per_query_hits: &mut [Vec<LocalHit>],
    ) -> Result<(), SearchError> {
        let cfg = &self.cfg;
        let no_artifact =
            || SearchError::executor(format!("no artifact for F={}", cfg.features));
        let heterogeneous = cand_sets.windows(2).any(|w| w[0] != w[1]);

        if heterogeneous {
            let (max_d, k_min) = {
                let m = exec.manifest();
                let d = m
                    .max_block(1, cfg.features)
                    .map(|a| a.d)
                    .ok_or_else(no_artifact)?;
                let k = m
                    .artifacts
                    .iter()
                    .filter(|a| a.f == cfg.features)
                    .map(|a| a.k)
                    .min()
                    .ok_or_else(no_artifact)?;
                (d, k)
            };
            for (qi, (query, top_k)) in queries.iter().enumerate() {
                if cand_sets[qi].is_empty() {
                    continue;
                }
                // Same exactness guard as the homogeneous branch: if the
                // request wants more hits than the artifact returns per
                // block, shrink blocks to k so truncation cannot drop
                // qualifying docs.
                let chunk_cap = if *top_k > k_min { max_d.min(k_min.max(1)) } else { max_d };
                let qw = build_query_weights(
                    std::slice::from_ref(&query.buckets),
                    stats,
                    cfg.features,
                    1,
                );
                for chunk in cand_sets[qi].chunks(chunk_cap) {
                    let ranked = exec
                        .rank_candidates(shard, stats, chunk, &qw, 1, &cfg.field_weights, cfg.b)
                        .map_err(SearchError::executor)?;
                    for &(local_idx, score) in &ranked[0] {
                        per_query_hits[qi].push(LocalHit {
                            global_id: shard.docs[chunk[local_idx as usize] as usize].global_id,
                            score,
                        });
                    }
                }
            }
            return Ok(());
        }

        // Homogeneous: one shared candidate list (kept in retrieval
        // order, matching the solo path's chunk partitioning exactly).
        let shared = &cand_sets[0];
        if shared.is_empty() {
            return Ok(());
        }
        let rows: Vec<Vec<u32>> = queries.iter().map(|(q, _)| q.buckets.clone()).collect();
        let q_cap = {
            let m = exec.manifest();
            m.artifacts
                .iter()
                .filter(|a| a.f == cfg.features)
                .map(|a| a.q)
                .max()
                .ok_or_else(no_artifact)?
        };
        let max_top_k = queries.iter().map(|(_, k)| *k).max().unwrap_or(0);
        for (chunk_idx, q_chunk) in rows.chunks(q_cap).enumerate() {
            let q_base = chunk_idx * q_cap;
            // Block capacity for *this* query count (the largest-D
            // artifact may only support Q = 1).
            let max_d = {
                let m = exec.manifest();
                let d = m
                    .max_block(q_chunk.len(), cfg.features)
                    .map(|a| a.d)
                    .ok_or_else(no_artifact)?;
                let k_min = m
                    .artifacts
                    .iter()
                    .filter(|a| a.f == cfg.features && a.q >= q_chunk.len())
                    .map(|a| a.k)
                    .min()
                    .ok_or_else(no_artifact)?;
                if max_top_k > k_min {
                    d.min(k_min.max(1))
                } else {
                    d
                }
            };
            let qw = build_query_weights(q_chunk, stats, cfg.features, q_cap.max(q_chunk.len()));
            for d_chunk in shared.chunks(max_d) {
                let ranked = exec
                    .rank_candidates(
                        shard,
                        stats,
                        d_chunk,
                        &qw,
                        q_chunk.len(),
                        &cfg.field_weights,
                        cfg.b,
                    )
                    .map_err(SearchError::executor)?;
                for (qi_local, row) in ranked.iter().enumerate() {
                    let qi = q_base + qi_local;
                    for &(local_idx, score) in row {
                        per_query_hits[qi].push(LocalHit {
                            global_id: shard.docs[d_chunk[local_idx as usize] as usize].global_id,
                            score,
                        });
                    }
                }
            }
        }
        Ok(())
    }
}

/// BM25 k1 shared with the artifacts (python/compile/model.py DEFAULT_K1).
pub const fn k1_const() -> f32 {
    1.2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchConfig;
    use crate::corpus::{CorpusGenerator, CorpusSpec};
    use crate::index::{Shard, ShardStats};

    fn setup(n: u64) -> (Shard, GlobalStats, SearchService) {
        let spec = CorpusSpec { num_docs: n, vocab_size: 400, ..CorpusSpec::default() };
        let gen = CorpusGenerator::new(spec);
        let shard = Shard::build(0, gen.generate_range(0, n), 512);
        let mut acc = ShardStats::empty(512);
        acc.merge(&shard.stats);
        let cfg = SearchConfig { use_xla: false, ..SearchConfig::default() };
        (shard, acc.finalize(), SearchService::new(cfg))
    }

    /// A query built from an existing doc's title (guaranteed hits).
    fn title_query(shard: &Shard, local: usize) -> Query {
        let title = shard.pubs[local].title.clone();
        Query::parse(&title, 512).unwrap()
    }

    #[test]
    fn finds_the_source_document() {
        let (shard, stats, ss) = setup(60);
        let q = title_query(&shard, 17);
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        assert!(out.candidates > 0);
        assert!(!out.hits.is_empty());
        assert!(
            out.hits.iter().any(|h| h.global_id == 17),
            "doc 17 missing from {:?}",
            out.hits
        );
        // Scores sorted descending.
        for w in out.hits.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        assert!(out.work_s > 0.0);
    }

    #[test]
    fn respects_top_k() {
        let (shard, stats, _) = setup(80);
        let mut cfg = SearchConfig { use_xla: false, ..SearchConfig::default() };
        cfg.top_k = 3;
        let ss = SearchService::new(cfg);
        let q = Query::parse("grid data search distributed", 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        assert!(out.hits.len() <= 3);
    }

    #[test]
    fn year_filter_is_hard() {
        let (shard, stats, ss) = setup(80);
        let year = shard.pubs[5].year;
        let raw = format!("{} year:{year}", shard.pubs[5].title);
        let q = Query::parse(&raw, 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        for h in &out.hits {
            assert_eq!(shard.pubs[h.global_id as usize].year, year);
        }
        assert!(out.hits.iter().any(|h| h.global_id == 5));
    }

    #[test]
    fn year_only_query_scans_shard() {
        let (shard, stats, ss) = setup(50);
        let q = Query::parse("year:2000..2014", 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        // All hits satisfy the filter; scores are 0 (no keywords).
        for h in &out.hits {
            assert!((2000..=2014).contains(&shard.pubs[h.global_id as usize].year));
        }
    }

    #[test]
    fn field_scoped_term_filters() {
        let (shard, stats, ss) = setup(80);
        // Scope to the venue of doc 3.
        let venue_word = shard.pubs[3]
            .venue
            .split_whitespace()
            .next()
            .unwrap()
            .to_string();
        let q = Query::parse(&format!("venue:{venue_word}"), 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        let stemmed = crate::text::tokenize(&venue_word)[0].term.clone();
        for h in &out.hits {
            let venue_terms: Vec<String> = crate::text::tokenize(
                &shard.pubs[h.global_id as usize].venue,
            )
            .into_iter()
            .map(|t| t.term)
            .collect();
            assert!(
                venue_terms.contains(&stemmed),
                "hit {} venue {:?} lacks {stemmed:?}",
                h.global_id,
                venue_terms
            );
        }
    }

    #[test]
    fn no_match_query_returns_empty() {
        let (shard, stats, ss) = setup(30);
        let q = Query::parse("qqqqzzzz xxxyyy", 512).unwrap();
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        // Terms may collide into occupied buckets, but usually empty:
        // at minimum the call must succeed and respect top_k.
        assert!(out.hits.len() <= ss.config().top_k);
    }

    #[test]
    fn phrase_requires_every_term() {
        let (shard, stats, ss) = setup(80);
        let title = shard.pubs[9].title.clone();
        let q = Query::parse(&format!("\"{title}\""), 512).unwrap();
        assert!(q.is_conjunctive());
        let out = ss.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        assert!(
            out.hits.iter().any(|h| h.global_id == 9),
            "doc 9 missing from phrase search {:?}",
            out.hits
        );
        // Every hit carries every phrase bucket somewhere.
        for h in &out.hits {
            for b in &q.buckets {
                let has = shard.docs[h.global_id as usize]
                    .field_tf
                    .iter()
                    .any(|tf| tf.iter().any(|(bb, _)| bb == b));
                assert!(has, "hit {} lacks phrase bucket {b}", h.global_id);
            }
        }
    }

    #[test]
    fn negation_excludes_matching_docs() {
        let (shard, stats, ss) = setup(80);
        let w = shard.pubs[4].title.split_whitespace().next().unwrap().to_string();
        let stemmed = crate::text::terms(&w);
        if stemmed.is_empty() {
            return; // the word was a stopword: nothing to assert
        }
        let b = crate::text::term_feature(&stemmed[0], 512) as u32;
        let neg = Query::parse(&format!("year:1990..2030 -{w}"), 512).unwrap();
        let out = ss.search(&shard, &stats, &neg, &mut Scorer::Rust).unwrap();
        assert!(
            !out.hits.iter().any(|h| h.global_id == 4),
            "doc 4 must be excluded by -{w}"
        );
        for h in &out.hits {
            let has = shard.docs[h.global_id as usize]
                .field_tf
                .iter()
                .any(|tf| tf.iter().any(|(bb, _)| *bb == b));
            assert!(!has, "hit {} matches excluded bucket", h.global_id);
        }
    }

    #[test]
    fn year_branch_of_an_or_is_reachable() {
        // `x OR year:Y` must return docs matching only the year branch —
        // the OR probe alone cannot see them, so retrieval falls back to
        // a shard scan + matcher.
        let (shard, stats, _ss) = setup(60);
        let year = shard.pubs[11].year;
        let q = Query::parse(&format!("qqqqzzzz OR year:{year}"), 512).unwrap();
        assert!(!q.or_pool_covers());
        let mut cfg = SearchConfig { use_xla: false, ..SearchConfig::default() };
        cfg.top_k = 60;
        let ss_wide = SearchService::new(cfg);
        let out = ss_wide.search(&shard, &stats, &q, &mut Scorer::Rust).unwrap();
        assert!(
            out.hits.iter().any(|h| h.global_id == 11),
            "doc 11 (year {year}) missing from OR-with-year query"
        );
    }

    #[test]
    fn batch_outcomes_match_solo_searches() {
        let (shard, stats, ss) = setup(100);
        let queries: Vec<Query> = vec![
            title_query(&shard, 3),
            Query::parse("grid data search", 512).unwrap(),
            Query::parse("year:2000..2014 distributed", 512).unwrap(),
        ];
        let batch_input: Vec<(&Query, usize)> = queries.iter().map(|q| (q, 10)).collect();
        let batch = ss
            .search_batch(&shard, &stats, &batch_input, &mut Scorer::Rust)
            .unwrap();
        assert_eq!(batch.len(), 3);
        for (q, b) in queries.iter().zip(&batch) {
            let solo = ss.search(&shard, &stats, q, &mut Scorer::Rust).unwrap();
            assert_eq!(solo.hits, b.hits, "batch diverged for {:?}", q.raw);
            assert_eq!(solo.candidates, b.candidates);
        }
    }

    #[test]
    fn duplicate_terms_match_dedup_results() {
        let (shard, stats, ss) = setup(100);
        let a = Query::parse("grid grid data", 512).unwrap();
        let b = Query::parse("grid data", 512).unwrap();
        let oa = ss.search(&shard, &stats, &a, &mut Scorer::Rust).unwrap();
        let ob = ss.search(&shard, &stats, &b, &mut Scorer::Rust).unwrap();
        assert_eq!(oa.hits, ob.hits, "duplicate term changed hits/scores");
        assert_eq!(oa.candidates, ob.candidates);
    }
}
