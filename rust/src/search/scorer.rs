//! Pure-rust BM25F block scorer.
//!
//! Mirrors the Layer-1 Pallas kernel math exactly (see
//! python/compile/kernels/ref.py for the canonical formulation):
//!
//! ```text
//! ctf[d,t]   = sum_f field_w[f] * doc_tf[f,d,t] * len_norm[f,d]
//! sat[d,t]   = ctf * (k1+1) / (ctf + k1)
//! score[q,d] = sum_t qw[q,t] * sat[d,t]
//! ```
//!
//! Three uses: (1) the traditional-search baseline scores through this
//! path (no grid, no artifacts); (2) `use_xla = false` environments;
//! (3) integration tests cross-check the PJRT runtime against it — rust
//! scorer vs AOT artifact must agree to float tolerance.

use crate::index::PackedBlock;
use crate::text::NUM_FIELDS;

/// Score a packed block against `q_count` query rows of `qw` (row-major
/// `[q_capacity, F]`, only the first `q_count` rows are scored).
/// Returns row-major `[q_count, d]` scores.
pub fn score_block_rust(
    block: &PackedBlock,
    qw: &[f32],
    q_count: usize,
    field_w: &[f32; NUM_FIELDS],
    k1: f32,
) -> Vec<f32> {
    let (d, f) = (block.d, block.f);
    assert!(qw.len() >= q_count * f, "qw too small");
    let mut scores = vec![0.0f32; q_count * d];
    // sat tile reused across queries: compute once per doc row.
    let mut sat = vec![0.0f32; f];
    for row in 0..d {
        // ctf for this doc row.
        sat.iter_mut().for_each(|x| *x = 0.0);
        for fi in 0..NUM_FIELDS {
            let ln = block.len_norm[fi * d + row];
            if ln == 0.0 {
                continue;
            }
            let w = field_w[fi] * ln;
            let base = fi * d * f + row * f;
            let tf_row = &block.doc_tf[base..base + f];
            for (s, &tf) in sat.iter_mut().zip(tf_row) {
                *s += w * tf;
            }
        }
        // Saturate in place.
        for s in sat.iter_mut() {
            let ctf = *s;
            *s = ctf * (k1 + 1.0) / (ctf + k1);
        }
        // Dot with each query row.
        for q in 0..q_count {
            let qrow = &qw[q * f..(q + 1) * f];
            let mut acc = 0.0f32;
            for (a, b) in qrow.iter().zip(sat.iter()) {
                acc += a * b;
            }
            scores[q * d + row] = acc;
        }
    }
    scores
}

/// Exact top-k over one query's score row: (index, score) sorted by score
/// descending, ties by index ascending. Skips padding rows >= `n_real`.
/// Partial selection first: with max_candidates-sized rows and small k,
/// O(n + k log k) instead of sorting the whole row.
pub fn topk_row(scores: &[f32], n_real: usize, k: usize) -> Vec<(u32, f32)> {
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..n_real.min(scores.len()) as u32).collect();
    // total_cmp: NaN scores sort deterministically instead of panicking.
    let better = |a: &u32, b: &u32| {
        scores[*b as usize].total_cmp(&scores[*a as usize]).then(a.cmp(b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k, better);
        idx.truncate(k);
    }
    idx.sort_unstable_by(better);
    idx.into_iter().map(|i| (i, scores[i as usize])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusGenerator, CorpusSpec};
    use crate::index::{build_query_weights, pack_block, Shard, ShardStats};

    fn setup(n: u64, features: usize) -> (Shard, crate::index::GlobalStats) {
        let spec = CorpusSpec { num_docs: n, vocab_size: 400, ..CorpusSpec::default() };
        let gen = CorpusGenerator::new(spec);
        let shard = Shard::build(0, gen.generate_range(0, n), features);
        let mut acc = ShardStats::empty(features);
        acc.merge(&shard.stats);
        (shard, acc.finalize())
    }

    #[test]
    fn padding_scores_zero() {
        let (shard, stats) = setup(8, 64);
        let block = pack_block(&shard, &stats, &[0, 1], 4, 0.75);
        let qw = build_query_weights(&[vec![1, 2, 3]], &stats, 64, 1);
        let scores = score_block_rust(&block, &qw, 1, &[2.0, 1.0, 1.5, 0.5], 1.2);
        assert_eq!(scores.len(), 4);
        assert_eq!(scores[2], 0.0);
        assert_eq!(scores[3], 0.0);
    }

    #[test]
    fn matching_doc_outscores_nonmatching() {
        let (shard, stats) = setup(16, 128);
        // Query = title terms of doc 3: doc 3 must be among top scorers.
        let doc3_buckets: Vec<u32> =
            shard.docs[3].field_tf[0].iter().map(|(b, _)| *b).collect();
        let cands: Vec<u32> = (0..16).collect();
        let block = pack_block(&shard, &stats, &cands, 16, 0.75);
        let qw = build_query_weights(&[doc3_buckets], &stats, 128, 1);
        let scores = score_block_rust(&block, &qw, 1, &[2.0, 1.0, 1.5, 0.5], 1.2);
        let top = topk_row(&scores, 16, 1);
        assert!(scores[3] > 0.0);
        // doc 3 should rank at or near the top (others can share terms).
        let rank = topk_row(&scores, 16, 16)
            .iter()
            .position(|&(i, _)| i == 3)
            .unwrap();
        assert!(rank <= 2, "doc3 ranked {rank}, top was {top:?}");
    }

    #[test]
    fn scores_bounded_by_saturation() {
        let (shard, stats) = setup(8, 64);
        let cands: Vec<u32> = (0..8).collect();
        let block = pack_block(&shard, &stats, &cands, 8, 0.75);
        let buckets = vec![1u32, 5, 9];
        let qw = build_query_weights(&[buckets.clone()], &stats, 64, 1);
        let k1 = 1.2f32;
        let scores = score_block_rust(&block, &qw, 1, &[1.0; 4], k1);
        let qw_sum: f32 = qw[..64].iter().sum();
        for &s in &scores {
            assert!(s >= 0.0 && s <= (k1 + 1.0) * qw_sum + 1e-4);
        }
    }

    #[test]
    fn multi_query_rows_independent() {
        let (shard, stats) = setup(8, 64);
        let cands: Vec<u32> = (0..8).collect();
        let block = pack_block(&shard, &stats, &cands, 8, 0.75);
        let q1 = vec![3u32];
        let q2 = vec![7u32, 9];
        let qw_both = build_query_weights(&[q1.clone(), q2.clone()], &stats, 64, 2);
        let both = score_block_rust(&block, &qw_both, 2, &[1.0; 4], 1.2);
        let qw1 = build_query_weights(&[q1], &stats, 64, 1);
        let solo1 = score_block_rust(&block, &qw1, 1, &[1.0; 4], 1.2);
        let qw2 = build_query_weights(&[q2], &stats, 64, 1);
        let solo2 = score_block_rust(&block, &qw2, 1, &[1.0; 4], 1.2);
        assert_eq!(&both[..8], &solo1[..]);
        assert_eq!(&both[8..], &solo2[..]);
    }

    #[test]
    fn topk_row_orders_and_breaks_ties_by_index() {
        let scores = [1.0f32, 3.0, 3.0, 0.5, 2.0];
        let top = topk_row(&scores, 5, 3);
        assert_eq!(top, vec![(1, 3.0), (2, 3.0), (4, 2.0)]);
        // n_real cuts off the tail.
        let top2 = topk_row(&scores, 2, 3);
        assert_eq!(top2, vec![(1, 3.0), (0, 1.0)]);
    }

    #[test]
    fn zero_query_gives_zero_scores() {
        let (shard, stats) = setup(4, 64);
        let block = pack_block(&shard, &stats, &[0, 1, 2, 3], 4, 0.75);
        let qw = vec![0.0f32; 64];
        let scores = score_block_rust(&block, &qw, 1, &[1.0; 4], 1.2);
        assert!(scores.iter().all(|&s| s == 0.0));
    }
}
