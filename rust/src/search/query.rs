//! Query language: recursive AST + tokenizing parser + compiled query.
//!
//! The paper's USI "provides keyword-based and multivariate-based search
//! types". The seed's flat keyword/field-term vectors have been replaced
//! by a real boolean AST ([`QueryNode`]) produced by a tokenizing parser.
//! Grammar:
//!
//! ```text
//! query    := or_expr
//! or_expr  := seq ('OR' seq)*          explicit disjunction
//! seq      := unary+                   whitespace sequence (see below)
//! unary    := ('-' | 'NOT') unary      negation (hard exclusion)
//!           | atom
//! atom     := '(' or_expr ')'          grouping
//!           | '"' word* '"'            phrase: every term required (AND)
//!           | word 'AND' word ...      explicit conjunction
//!           | field ':' word           field-scoped required term
//!           | 'year' ':' y ('..' y)?   hard year filter (inclusive)
//!           | word                     free keyword (scored)
//! field    := title | abstract | authors | venue
//! ```
//!
//! Sequence semantics: inside one whitespace sequence, the bare keywords
//! form a single *should* group — a document must match **at least one**
//! of them — while every other clause (phrases, `AND` chains, field
//! terms, year ranges, negations, parenthesized groups) must **all**
//! hold. `AND`/`OR`/`NOT` are operators only in full uppercase;
//! lowercase `and`/`or`/`not` flow through the analyzer like any word.
//!
//! Examples: `grid computing`, `"grid computing" scheduling`,
//! `title:grid venue:conference`, `scheduling -cloud year:2010..2014`,
//! `storage AND replication OR archive`.
//!
//! Compilation dedups scored terms (so `grid grid computing` ranks and
//! retrieves exactly like `grid computing`) and lowers the AST onto the
//! CSR retrieval primitives: a pure conjunction uses the galloping
//! AND-intersection; trees the OR probe can fully reach use the counting
//! OR-merge plus a per-candidate matcher pass; trees satisfiable through
//! a term-free branch (`year:2014`, `grid OR year:2014`) fall back to a
//! shard scan with the matcher (see [`Query::or_pool_covers`]).

use crate::index::Shard;
use crate::text::{term_feature, terms, Field};

use super::error::SearchError;

/// Retrieval strategy chosen at compile time: which index primitive the
/// Search Service should drive, and whether the matcher pass is needed.
/// Computed once per query so the per-shard hot loop branches on a
/// precomputed tag instead of re-deriving structure from the AST.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetrievalHint {
    /// Pure term conjunction (phrase / `AND` chain): the galloping
    /// AND-intersection, no matcher pass.
    GallopAnd,
    /// Pure term disjunction: block-max pruned OR retrieval alone is
    /// exact — no matcher pass.
    PrunedOr,
    /// The OR probe reaches every match but the tree carries structure
    /// the probe cannot express: pruned OR + per-candidate matcher.
    PrunedOrFiltered,
    /// A term-free branch can satisfy the tree (`year:2014`,
    /// `grid OR year:2014`): scan the shard with the matcher fused in.
    ScanMatcher,
}

/// Inclusive year range filter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RangeFilter {
    pub min: u32,
    pub max: u32,
}

impl RangeFilter {
    pub fn contains(&self, y: u32) -> bool {
        (self.min..=self.max).contains(&y)
    }
}

/// A node of the parsed query tree. Terms are normalized (lowercased,
/// stemmed) exactly like document text, so `QueryNode` equality is
/// analyzer-level equality.
#[derive(Debug, Clone, PartialEq)]
pub enum QueryNode {
    /// Every child must match.
    And(Vec<QueryNode>),
    /// At least one child must match.
    Or(Vec<QueryNode>),
    /// The child must not match.
    Not(Box<QueryNode>),
    /// Normalized term, matched in any field (and scored).
    Term(String),
    /// Normalized term that must appear in a specific field (and scored).
    FieldTerm(Field, String),
    /// Hard publication-year filter.
    YearRange(RangeFilter),
}

impl std::fmt::Display for QueryNode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryNode::And(cs) => write_joined(f, cs, " AND "),
            QueryNode::Or(cs) => write_joined(f, cs, " OR "),
            QueryNode::Not(c) => write!(f, "-{c}"),
            QueryNode::Term(t) => write!(f, "{t}"),
            QueryNode::FieldTerm(field, t) => write!(f, "{}:{t}", field.name()),
            QueryNode::YearRange(r) => write!(f, "year:{}..{}", r.min, r.max),
        }
    }
}

fn write_joined(
    f: &mut std::fmt::Formatter<'_>,
    cs: &[QueryNode],
    sep: &str,
) -> std::fmt::Result {
    write!(f, "(")?;
    for (i, c) in cs.iter().enumerate() {
        if i > 0 {
            write!(f, "{sep}")?;
        }
        write!(f, "{c}")?;
    }
    write!(f, ")")
}

/// Bucket-level matcher compiled from the AST: term strings are hashed
/// into the feature space once, so per-candidate evaluation is
/// allocation-free integer comparisons.
#[derive(Debug, Clone, PartialEq)]
enum Matcher {
    And(Vec<Matcher>),
    Or(Vec<Matcher>),
    Not(Box<Matcher>),
    AnyField(u32),
    InField(Field, u32),
    Year(RangeFilter),
}

impl Matcher {
    fn eval(&self, shard: &Shard, lid: u32) -> bool {
        match self {
            Matcher::And(cs) => cs.iter().all(|c| c.eval(shard, lid)),
            Matcher::Or(cs) => cs.iter().any(|c| c.eval(shard, lid)),
            Matcher::Not(c) => !c.eval(shard, lid),
            Matcher::AnyField(b) => shard.docs[lid as usize]
                .field_tf
                .iter()
                .any(|tf| tf.iter().any(|(bb, _)| bb == b)),
            Matcher::InField(field, b) => shard.docs[lid as usize].field_tf[*field as usize]
                .iter()
                .any(|(bb, _)| bb == b),
            Matcher::Year(r) => r.contains(shard.pubs[lid as usize].year),
        }
    }
}

/// A parsed, analyzed, compiled query ready for retrieval + ranking.
#[derive(Debug, Clone, PartialEq)]
pub struct Query {
    /// Original query text (logging / JDF / responses).
    pub raw: String,
    /// The parsed boolean tree.
    pub ast: QueryNode,
    /// Scored keyword terms (normalized, **deduplicated**, canonical AST
    /// order — commutative operands are sorted during [`Query::compile`],
    /// so reordered-but-equal queries share one keyword sequence): every
    /// positive `Term`/`FieldTerm` in the tree.
    pub keywords: Vec<String>,
    /// Feature buckets of `keywords` in the artifact space (parallel).
    pub buckets: Vec<u32>,
    /// Compiled per-candidate matcher.
    matcher: Matcher,
    /// Whether candidates need a matcher pass beyond the OR-probe.
    needs_filter: bool,
    /// Whether the whole positive structure is a pure term conjunction
    /// (phrase / `AND` chain): retrieval can use the galloping
    /// AND-intersection and skip the matcher pass entirely.
    conjunctive: bool,
    /// Whether the counting-OR probe over `buckets` reaches every
    /// matching document. False when the tree can be satisfied without
    /// any positive term — e.g. `year:2014`, or `grid OR year:2014`
    /// whose year branch alone matches — in which case retrieval must
    /// scan the shard and rely on the matcher.
    pool_complete: bool,
    /// Precomputed retrieval strategy (see [`RetrievalHint`]).
    hint: RetrievalHint,
}

impl Query {
    /// Parse + analyze + compile a query string into the `features`-bucket
    /// space.
    pub fn parse(raw: &str, features: usize) -> Result<Query, SearchError> {
        let tokens = lex(raw)?;
        let mut p = Parser { tokens, pos: 0 };
        let ast = p.or_expr()?;
        if p.pos != p.tokens.len() {
            return Err(SearchError::parse(format!(
                "unexpected '{}' after query",
                p.tokens[p.pos]
            )));
        }
        Query::compile(raw, ast, features)
    }

    /// Compile an AST (from the parser or built programmatically by the
    /// request builder) into a runnable query.
    pub fn compile(raw: &str, ast: QueryNode, features: usize) -> Result<Query, SearchError> {
        let ast = simplify(ast);
        let mut keywords: Vec<String> = Vec::new();
        collect_scored(&ast, false, &mut keywords);
        // Dedup scored terms: a repeated term must not inflate OR match
        // counts or double its BM25F query weight.
        let mut seen = std::collections::BTreeSet::new();
        keywords.retain(|t| seen.insert(t.clone()));
        if keywords.is_empty() && !has_positive_year(&ast) {
            return Err(SearchError::parse("query has no searchable terms"));
        }
        let buckets: Vec<u32> =
            keywords.iter().map(|t| term_feature(t, features) as u32).collect();
        let matcher = build_matcher(&ast, features);
        let conjunctive = is_term_conjunction(&ast);
        let needs_filter = !conjunctive && !is_term_disjunction(&ast);
        let pool_complete = requires_term(&ast);
        let hint = if conjunctive {
            RetrievalHint::GallopAnd
        } else if !pool_complete {
            RetrievalHint::ScanMatcher
        } else if needs_filter {
            RetrievalHint::PrunedOrFiltered
        } else {
            RetrievalHint::PrunedOr
        };
        Ok(Query {
            raw: raw.to_string(),
            ast,
            keywords,
            buckets,
            matcher,
            needs_filter,
            conjunctive,
            pool_complete,
            hint,
        })
    }

    /// Whether this query uses multivariate constraints (field scopes,
    /// year ranges, boolean structure beyond a keyword group).
    pub fn is_multivariate(&self) -> bool {
        fn walk(n: &QueryNode) -> bool {
            match n {
                QueryNode::Term(_) => false,
                QueryNode::Or(cs) => cs.iter().any(walk),
                QueryNode::FieldTerm(..) | QueryNode::YearRange(_) => true,
                QueryNode::And(_) | QueryNode::Not(_) => true,
            }
        }
        walk(&self.ast)
    }

    /// Whether the positive structure is a pure term conjunction —
    /// retrieval should use the galloping AND-intersection over
    /// [`buckets`](Query::buckets).
    pub fn is_conjunctive(&self) -> bool {
        self.conjunctive
    }

    /// Whether OR-probe candidates still need [`Query::matches`]
    /// (boolean structure the probe cannot express).
    pub fn needs_filter(&self) -> bool {
        self.needs_filter
    }

    /// Whether the counting-OR probe over [`buckets`](Query::buckets)
    /// reaches every matching document. When false (pure filters like
    /// `year:2014`, or trees satisfiable through a term-free branch like
    /// `grid OR year:2014`), retrieval must scan the shard and rely on
    /// the matcher instead.
    pub fn or_pool_covers(&self) -> bool {
        self.pool_complete
    }

    /// The retrieval strategy compiled for this query (see
    /// [`RetrievalHint`]). Consistent with [`Query::is_conjunctive`],
    /// [`Query::needs_filter`], and [`Query::or_pool_covers`].
    pub fn retrieval_hint(&self) -> RetrievalHint {
        self.hint
    }

    /// Evaluate the compiled matcher against one shard-local document.
    pub fn matches(&self, shard: &Shard, lid: u32) -> bool {
        self.matcher.eval(shard, lid)
    }
}

/// Flatten nested same-kind combinators, unwrap singleton groups, and
/// canonicalize: `And`/`Or` are commutative, so their operands are sorted
/// into a stable structural order ([`compare_nodes`]) and exact-duplicate
/// siblings are dropped. Logically identical trees (`b AND a` vs
/// `a AND b`, `grid OR grid`) therefore compile to one canonical AST —
/// one keyword order, one execution, one cache fingerprint.
fn simplify(node: QueryNode) -> QueryNode {
    match node {
        QueryNode::And(cs) => {
            let mut flat = Vec::with_capacity(cs.len());
            for c in cs {
                match simplify(c) {
                    QueryNode::And(inner) => flat.extend(inner),
                    other => flat.push(other),
                }
            }
            canonicalize(&mut flat);
            if flat.len() == 1 { flat.pop().unwrap() } else { QueryNode::And(flat) }
        }
        QueryNode::Or(cs) => {
            let mut flat = Vec::with_capacity(cs.len());
            for c in cs {
                match simplify(c) {
                    QueryNode::Or(inner) => flat.extend(inner),
                    other => flat.push(other),
                }
            }
            canonicalize(&mut flat);
            if flat.len() == 1 { flat.pop().unwrap() } else { QueryNode::Or(flat) }
        }
        QueryNode::Not(c) => QueryNode::Not(Box::new(simplify(*c))),
        leaf => leaf,
    }
}

/// Sort commutative operands into canonical order and drop exact
/// duplicates. Children are already simplified, so recursive comparison
/// sees canonical subtrees and equal subtrees land adjacent.
fn canonicalize(children: &mut Vec<QueryNode>) {
    children.sort_by(compare_nodes);
    children.dedup();
}

/// Variant rank for the canonical operand order: filters first, then
/// negations, then scored leaves, then nested groups. Chosen so common
/// shapes read naturally (`year:.. AND term`, `-cloud AND grid`).
fn node_rank(n: &QueryNode) -> u8 {
    match n {
        QueryNode::YearRange(_) => 0,
        QueryNode::Not(_) => 1,
        QueryNode::FieldTerm(..) => 2,
        QueryNode::Term(_) => 3,
        QueryNode::Or(_) => 4,
        QueryNode::And(_) => 5,
    }
}

/// Total structural order over query nodes: variant rank, then content
/// (terms lexicographically, ranges by bounds, groups element-wise).
/// `Equal` here is exactly `PartialEq` equality, so sort + dedup removes
/// every duplicate sibling.
fn compare_nodes(a: &QueryNode, b: &QueryNode) -> std::cmp::Ordering {
    match (a, b) {
        (QueryNode::YearRange(x), QueryNode::YearRange(y)) => {
            (x.min, x.max).cmp(&(y.min, y.max))
        }
        (QueryNode::Not(x), QueryNode::Not(y)) => compare_nodes(x, y),
        (QueryNode::FieldTerm(fa, ta), QueryNode::FieldTerm(fb, tb)) => {
            (*fa as u8).cmp(&(*fb as u8)).then_with(|| ta.cmp(tb))
        }
        (QueryNode::Term(x), QueryNode::Term(y)) => x.cmp(y),
        (QueryNode::Or(x), QueryNode::Or(y)) | (QueryNode::And(x), QueryNode::And(y)) => {
            compare_node_lists(x, y)
        }
        _ => node_rank(a).cmp(&node_rank(b)),
    }
}

fn compare_node_lists(a: &[QueryNode], b: &[QueryNode]) -> std::cmp::Ordering {
    for (x, y) in a.iter().zip(b.iter()) {
        match compare_nodes(x, y) {
            std::cmp::Ordering::Equal => continue,
            other => return other,
        }
    }
    a.len().cmp(&b.len())
}

/// Collect scored (positive) terms in tree order.
fn collect_scored(node: &QueryNode, negated: bool, out: &mut Vec<String>) {
    match node {
        QueryNode::And(cs) | QueryNode::Or(cs) => {
            for c in cs {
                collect_scored(c, negated, out);
            }
        }
        QueryNode::Not(c) => collect_scored(c, !negated, out),
        QueryNode::Term(t) | QueryNode::FieldTerm(_, t) => {
            if !negated {
                out.push(t.clone());
            }
        }
        QueryNode::YearRange(_) => {}
    }
}

fn has_positive_year(node: &QueryNode) -> bool {
    match node {
        QueryNode::And(cs) | QueryNode::Or(cs) => cs.iter().any(has_positive_year),
        QueryNode::Not(_) => false,
        QueryNode::YearRange(_) => true,
        _ => false,
    }
}

fn build_matcher(node: &QueryNode, features: usize) -> Matcher {
    match node {
        QueryNode::And(cs) => Matcher::And(cs.iter().map(|c| build_matcher(c, features)).collect()),
        QueryNode::Or(cs) => Matcher::Or(cs.iter().map(|c| build_matcher(c, features)).collect()),
        QueryNode::Not(c) => Matcher::Not(Box::new(build_matcher(c, features))),
        QueryNode::Term(t) => Matcher::AnyField(term_feature(t, features) as u32),
        QueryNode::FieldTerm(f, t) => Matcher::InField(*f, term_feature(t, features) as u32),
        QueryNode::YearRange(r) => Matcher::Year(*r),
    }
}

/// `Term` or `And[Term...]`: exact galloping-intersection shape.
fn is_term_conjunction(node: &QueryNode) -> bool {
    match node {
        QueryNode::And(cs) => cs.iter().all(|c| matches!(c, QueryNode::Term(_))),
        _ => false,
    }
}

/// `Term` or `Or[Term...]`: exact counting-OR shape (no filter needed).
fn is_term_disjunction(node: &QueryNode) -> bool {
    match node {
        QueryNode::Term(_) => true,
        QueryNode::Or(cs) => cs.iter().all(|c| matches!(c, QueryNode::Term(_))),
        _ => false,
    }
}

/// Whether every document matching `node` necessarily carries at least
/// one positive scored term — i.e. whether the counting-OR probe over
/// the scored buckets is a complete candidate generator for this tree.
fn requires_term(node: &QueryNode) -> bool {
    match node {
        QueryNode::Term(_) | QueryNode::FieldTerm(..) => true,
        QueryNode::YearRange(_) | QueryNode::Not(_) => false,
        QueryNode::And(cs) => cs.iter().any(requires_term),
        QueryNode::Or(cs) => cs.iter().all(requires_term),
    }
}

// ------------------------------------------------------------------ lexer

#[derive(Debug, Clone, PartialEq)]
enum Token {
    LParen,
    RParen,
    Or,
    And,
    Not,
    Phrase(String),
    Word(String),
}

impl std::fmt::Display for Token {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Token::LParen => write!(f, "("),
            Token::RParen => write!(f, ")"),
            Token::Or => write!(f, "OR"),
            Token::And => write!(f, "AND"),
            Token::Not => write!(f, "-"),
            Token::Phrase(p) => write!(f, "\"{p}\""),
            Token::Word(w) => write!(f, "{w}"),
        }
    }
}

fn lex(raw: &str) -> Result<Vec<Token>, SearchError> {
    let mut out = Vec::new();
    let mut chars = raw.chars().peekable();
    while let Some(&c) = chars.peek() {
        match c {
            c if c.is_whitespace() => {
                chars.next();
            }
            '(' => {
                chars.next();
                out.push(Token::LParen);
            }
            ')' => {
                chars.next();
                out.push(Token::RParen);
            }
            '"' => {
                chars.next();
                let mut body = String::new();
                loop {
                    match chars.next() {
                        Some('"') => break,
                        Some(ch) => body.push(ch),
                        None => return Err(SearchError::parse("unterminated phrase quote")),
                    }
                }
                out.push(Token::Phrase(body));
            }
            '-' => {
                chars.next();
                out.push(Token::Not);
            }
            _ => {
                let mut word = String::new();
                while let Some(&ch) = chars.peek() {
                    if ch.is_whitespace() || matches!(ch, '(' | ')' | '"') {
                        break;
                    }
                    word.push(ch);
                    chars.next();
                }
                // Uppercase-only operator keywords; anything else flows
                // through the analyzer below.
                match word.as_str() {
                    "OR" => out.push(Token::Or),
                    "AND" => out.push(Token::And),
                    "NOT" => out.push(Token::Not),
                    _ => out.push(Token::Word(word)),
                }
            }
        }
    }
    Ok(out)
}

// ----------------------------------------------------------------- parser

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Token> {
        self.tokens.get(self.pos)
    }

    fn or_expr(&mut self) -> Result<QueryNode, SearchError> {
        // An arm that dissolves entirely in analysis (all stopwords) is
        // dropped, not fatal: `grid OR the` is `grid`. Only a query
        // whose every arm dissolves has no searchable terms.
        let mut arms: Vec<QueryNode> = Vec::new();
        if let Some(arm) = self.sequence()? {
            arms.push(arm);
        }
        while self.peek() == Some(&Token::Or) {
            self.pos += 1;
            if let Some(arm) = self.sequence()? {
                arms.push(arm);
            }
        }
        if arms.is_empty() {
            return Err(SearchError::parse("query has no searchable terms"));
        }
        Ok(if arms.len() == 1 { arms.pop().unwrap() } else { QueryNode::Or(arms) })
    }

    /// A whitespace sequence: bare keywords coalesce into one should
    /// (`Or`) group; every other clause is a hard conjunct. `AND` binds
    /// the clause immediately to its left (in token order) and the next
    /// unary into an explicit conjunction (a hard clause).
    ///
    /// `Ok(None)` means the whole sequence dissolved in analysis (every
    /// token was a stopword) — the caller decides whether that is fatal.
    fn sequence(&mut self) -> Result<Option<QueryNode>, SearchError> {
        // Clauses in token order; the flag marks bare keywords (should
        // semantics). Kept as one list so `AND` always grabs its true
        // left neighbour, whatever kind it was.
        let mut clauses: Vec<(bool, QueryNode)> = Vec::new();
        let mut parsed_any = false;
        loop {
            match self.peek() {
                None | Some(Token::RParen) | Some(Token::Or) => break,
                Some(Token::And) => {
                    self.pos += 1;
                    match clauses.pop() {
                        Some((prev_kind, prev)) => match self.unary()? {
                            Some(next) => {
                                let joined = match prev {
                                    QueryNode::And(mut cs) => {
                                        cs.push(next);
                                        QueryNode::And(cs)
                                    }
                                    other => QueryNode::And(vec![other, next]),
                                };
                                clauses.push((false, joined));
                            }
                            // Right operand dissolved (`grid AND the`):
                            // the conjunction is a no-op, keep the left
                            // clause as it was.
                            None => clauses.push((prev_kind, prev)),
                        },
                        // Left operand dissolved (`the AND grid`): the
                        // conjunction is a no-op prefix; the right
                        // operand joins the sequence normally.
                        None if parsed_any => {
                            if let Some(clause) = self.unary()? {
                                let is_should = matches!(clause, QueryNode::Term(_));
                                clauses.push((is_should, clause));
                            }
                        }
                        None => return Err(SearchError::parse("dangling AND")),
                    }
                }
                _ => {
                    parsed_any = true;
                    if let Some(clause) = self.unary()? {
                        let is_should = matches!(clause, QueryNode::Term(_));
                        clauses.push((is_should, clause));
                    }
                    // `None`: the clause dissolved in analysis (stopword,
                    // empty after stemming) — legal, just skipped.
                }
            }
        }
        if !parsed_any && clauses.is_empty() {
            return Err(SearchError::parse("empty query clause"));
        }
        let mut shoulds: Vec<QueryNode> = Vec::new();
        let mut musts: Vec<QueryNode> = Vec::new();
        for (is_should, clause) in clauses {
            if is_should {
                shoulds.push(clause);
            } else {
                musts.push(clause);
            }
        }
        if shoulds.len() > 1 {
            musts.push(QueryNode::Or(shoulds));
        } else {
            musts.extend(shoulds);
        }
        if musts.is_empty() {
            // Every token dissolved (e.g. all stopwords).
            return Ok(None);
        }
        Ok(Some(if musts.len() == 1 { musts.pop().unwrap() } else { QueryNode::And(musts) }))
    }

    /// One negation-prefixed atom. `Ok(None)` means the atom dissolved
    /// during analysis (stopword-only word or phrase under a `-`).
    fn unary(&mut self) -> Result<Option<QueryNode>, SearchError> {
        if self.peek() == Some(&Token::Not) {
            self.pos += 1;
            let inner = self.unary()?;
            return Ok(inner.map(|n| QueryNode::Not(Box::new(n))));
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<Option<QueryNode>, SearchError> {
        match self.peek().cloned() {
            Some(Token::LParen) => {
                self.pos += 1;
                let inner = self.or_expr()?;
                if self.peek() != Some(&Token::RParen) {
                    return Err(SearchError::parse("missing ')'"));
                }
                self.pos += 1;
                Ok(Some(inner))
            }
            Some(Token::Phrase(body)) => {
                self.pos += 1;
                let ts = terms(&body);
                if ts.is_empty() {
                    return Err(SearchError::parse(format!(
                        "phrase \"{body}\" has no searchable terms"
                    )));
                }
                if ts.len() == 1 {
                    return Ok(Some(QueryNode::Term(ts.into_iter().next().unwrap())));
                }
                Ok(Some(QueryNode::And(ts.into_iter().map(QueryNode::Term).collect())))
            }
            Some(Token::Word(w)) => {
                self.pos += 1;
                if let Some((head, rest)) = w.split_once(':') {
                    let head_lc = head.to_ascii_lowercase();
                    if head_lc == "year" {
                        return Ok(Some(QueryNode::YearRange(parse_year_filter(rest)?)));
                    }
                    if let Some(field) = Field::parse(&head_lc) {
                        let normalized = terms(rest);
                        if normalized.is_empty() {
                            return Err(SearchError::parse(format!("empty term in '{w}'")));
                        }
                        let mut nodes: Vec<QueryNode> = normalized
                            .into_iter()
                            .map(|t| QueryNode::FieldTerm(field, t))
                            .collect();
                        return Ok(Some(if nodes.len() == 1 {
                            nodes.pop().unwrap()
                        } else {
                            QueryNode::And(nodes)
                        }));
                    }
                    return Err(SearchError::parse(format!("unknown field '{head}' in '{w}'")));
                }
                let ts = terms(&w);
                match ts.len() {
                    0 => Ok(None), // stopword / empty after analysis
                    1 => Ok(Some(QueryNode::Term(ts.into_iter().next().unwrap()))),
                    // A word that analyzes into several terms (e.g.
                    // hyphenated): treat like an unquoted mini-phrase.
                    _ => Ok(Some(QueryNode::And(ts.into_iter().map(QueryNode::Term).collect()))),
                }
            }
            Some(tok @ (Token::RParen | Token::Or | Token::And)) => {
                Err(SearchError::parse(format!("unexpected '{tok}'")))
            }
            Some(Token::Not) => unreachable!("handled by unary"),
            None => Err(SearchError::parse("unexpected end of query")),
        }
    }
}

pub(crate) fn parse_year_filter(spec: &str) -> Result<RangeFilter, SearchError> {
    let parse_y = |s: &str| -> Result<u32, SearchError> {
        s.parse::<u32>().map_err(|_| SearchError::parse(format!("bad year '{s}'")))
    };
    if let Some((lo, hi)) = spec.split_once("..") {
        let (min, max) = (parse_y(lo)?, parse_y(hi)?);
        if min > max {
            return Err(SearchError::parse(format!("empty year range {min}..{max}")));
        }
        Ok(RangeFilter { min, max })
    } else {
        let y = parse_y(spec)?;
        Ok(RangeFilter { min: y, max: y })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keyword_query() {
        let q = Query::parse("grid computing publications", 512).unwrap();
        // Commutative operands sort into canonical order at compile time.
        assert_eq!(q.keywords, vec!["comput", "grid", "publication"]);
        assert_eq!(q.buckets.len(), 3);
        assert!(!q.is_multivariate());
        assert!(!q.is_conjunctive());
        assert!(!q.needs_filter());
        assert_eq!(
            q.ast,
            QueryNode::Or(vec![
                QueryNode::Term("comput".into()),
                QueryNode::Term("grid".into()),
                QueryNode::Term("publication".into()),
            ])
        );
    }

    #[test]
    fn field_scoped_terms() {
        let q = Query::parse("title:grid venue:conference", 512).unwrap();
        assert_eq!(
            q.ast,
            QueryNode::And(vec![
                QueryNode::FieldTerm(Field::Title, "grid".into()),
                QueryNode::FieldTerm(Field::Venue, "conference".into()),
            ])
        );
        // Field terms are also scored keywords.
        assert_eq!(q.keywords.len(), 2);
        assert!(q.is_multivariate());
        assert!(q.needs_filter());
    }

    #[test]
    fn year_filters() {
        let q = Query::parse("scheduling year:2010..2014", 512).unwrap();
        assert_eq!(
            q.ast,
            QueryNode::And(vec![
                QueryNode::YearRange(RangeFilter { min: 2010, max: 2014 }),
                QueryNode::Term("schedul".into()),
            ])
        );
        let q1 = Query::parse("x year:2005", 512).unwrap();
        let y2005 = QueryNode::YearRange(RangeFilter { min: 2005, max: 2005 });
        assert!(matches!(q1.ast, QueryNode::And(ref cs) if cs.contains(&y2005)));
    }

    #[test]
    fn errors() {
        for bad in [
            "",
            "the of and",     // all stopwords
            "body:grid",      // unknown field
            "year:20x4",      // bad year
            "year:2014..2010",// empty range
            "title:",         // empty field term
            "\"grid",         // unterminated phrase
            "(grid",          // missing paren
            "grid AND",       // dangling AND
            "AND grid",       // dangling AND
            "grid OR",        // dangling OR
        ] {
            assert!(Query::parse(bad, 512).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn year_only_query_is_valid() {
        let q = Query::parse("year:2014", 512).unwrap();
        assert!(q.keywords.is_empty());
        assert!(q.is_multivariate());
        assert!(q.needs_filter());
    }

    #[test]
    fn buckets_in_feature_space() {
        let q = Query::parse("massive academic publications", 128).unwrap();
        assert!(q.buckets.iter().all(|&b| b < 128));
    }

    #[test]
    fn query_terms_normalized_like_documents() {
        let q = Query::parse("Searching PUBLICATIONS", 512).unwrap();
        assert_eq!(q.keywords, vec!["publication", "search"]);
    }

    #[test]
    fn duplicate_terms_dedup() {
        let a = Query::parse("grid grid computing", 512).unwrap();
        let b = Query::parse("grid computing", 512).unwrap();
        assert_eq!(a.keywords, b.keywords);
        assert_eq!(a.buckets, b.buckets);
    }

    #[test]
    fn phrase_is_a_conjunction() {
        let q = Query::parse("\"grid computing\"", 512).unwrap();
        assert_eq!(
            q.ast,
            QueryNode::And(vec![
                QueryNode::Term("comput".into()),
                QueryNode::Term("grid".into()),
            ])
        );
        assert!(q.is_conjunctive());
        assert!(!q.needs_filter());
        assert_eq!(q.keywords, vec!["comput", "grid"]);
    }

    #[test]
    fn and_binds_its_left_neighbour() {
        // `AND` must capture the clause directly to its left (the
        // phrase), not a distant bare keyword: grid/cloud stay a
        // should group.
        let q = Query::parse("grid cloud \"data replication\" AND storage", 512).unwrap();
        match &q.ast {
            QueryNode::And(cs) => {
                let should_group = QueryNode::Or(vec![
                    QueryNode::Term("cloud".into()),
                    QueryNode::Term("grid".into()),
                ]);
                assert!(cs.contains(&should_group), "should group lost: {:?}", q.ast);
                assert!(cs.contains(&QueryNode::Term("storage".into())));
            }
            other => panic!("expected And root, got {other:?}"),
        }
    }

    #[test]
    fn explicit_and_chain() {
        let q = Query::parse("storage AND replication AND archive", 512).unwrap();
        assert!(q.is_conjunctive());
        assert_eq!(q.keywords.len(), 3);
    }

    #[test]
    fn explicit_or_groups_sequences() {
        let q = Query::parse("grid computing OR archive year:2000..2005", 512).unwrap();
        match &q.ast {
            // The left sequence's should group flattens into the root Or;
            // the right sequence stays a hard conjunction.
            QueryNode::Or(arms) => {
                assert_eq!(arms.len(), 3);
                assert!(matches!(arms[2], QueryNode::And(_)));
            }
            other => panic!("expected Or root, got {other:?}"),
        }
    }

    #[test]
    fn negation_excludes() {
        let q = Query::parse("grid -cloud", 512).unwrap();
        assert_eq!(
            q.ast,
            QueryNode::And(vec![
                QueryNode::Not(Box::new(QueryNode::Term("cloud".into()))),
                QueryNode::Term("grid".into()),
            ])
        );
        // Negated terms are not scored.
        assert_eq!(q.keywords, vec!["grid"]);
        assert!(q.needs_filter());
    }

    #[test]
    fn stopword_operands_dissolve_gracefully() {
        // A stopword right operand makes the AND a no-op instead of a
        // fatal "dangling AND"; a stopword-only OR arm is dropped.
        let a = Query::parse("grid AND the cloud", 512).unwrap();
        assert_eq!(a.keywords, vec!["cloud", "grid"]);
        assert!(!a.is_conjunctive(), "no-op AND must not force a conjunction");
        let b = Query::parse("grid OR the", 512).unwrap();
        assert_eq!(b.ast, QueryNode::Term("grid".into()));
        // Symmetric: a stopword left operand also dissolves the AND.
        let c = Query::parse("the AND grid", 512).unwrap();
        assert_eq!(c.ast, QueryNode::Term("grid".into()));
        // But a truly empty arm (nothing to analyze) is still an error.
        assert!(Query::parse("grid OR", 512).is_err());
    }

    #[test]
    fn commutative_operands_share_one_canonical_ast() {
        // `b AND a` and `a AND b` must compile to one canonical tree —
        // same AST, same keyword order, same buckets — so they execute
        // identically and share one cache fingerprint.
        let a = Query::parse("storage AND replication", 512).unwrap();
        let b = Query::parse("replication AND storage", 512).unwrap();
        assert_eq!(a.ast, b.ast);
        assert_eq!(a.keywords, b.keywords);
        assert_eq!(a.buckets, b.buckets);
        let c = Query::parse("(grid OR cloud) year:2010..2014", 512).unwrap();
        let d = Query::parse("(cloud OR grid) year:2010..2014", 512).unwrap();
        assert_eq!(c.ast, d.ast);
        // Exact-duplicate siblings collapse to one operand.
        let e = Query::parse("grid OR grid", 512).unwrap();
        assert_eq!(e.ast, QueryNode::Term("grid".into()));
    }

    #[test]
    fn not_keyword_is_negation() {
        let a = Query::parse("grid NOT cloud", 512).unwrap();
        let b = Query::parse("grid -cloud", 512).unwrap();
        assert_eq!(a.ast, b.ast);
    }

    #[test]
    fn lowercase_operators_are_words() {
        // `and`/`or` are stopwords: they dissolve instead of operating.
        let q = Query::parse("grid and computing", 512).unwrap();
        assert_eq!(q.keywords, vec!["comput", "grid"]);
        assert!(!q.is_conjunctive());
    }

    #[test]
    fn retrieval_hints_match_structure() {
        let cases = [
            ("\"grid computing\"", RetrievalHint::GallopAnd),
            ("storage AND replication", RetrievalHint::GallopAnd),
            ("grid computing publications", RetrievalHint::PrunedOr),
            ("grid OR cloud", RetrievalHint::PrunedOr),
            ("grid -cloud", RetrievalHint::PrunedOrFiltered),
            ("title:grid venue:conference", RetrievalHint::PrunedOrFiltered),
            ("grid year:2014", RetrievalHint::PrunedOrFiltered),
            ("year:2014", RetrievalHint::ScanMatcher),
            ("grid OR year:2014", RetrievalHint::ScanMatcher),
        ];
        for (raw, want) in cases {
            assert_eq!(Query::parse(raw, 512).unwrap().retrieval_hint(), want, "{raw}");
        }
    }

    #[test]
    fn pool_coverage_detection() {
        // OR probe complete: every match carries a scored term.
        for raw in ["grid computing", "grid AND cloud", "title:grid", "grid year:2014"] {
            assert!(Query::parse(raw, 512).unwrap().or_pool_covers(), "{raw}");
        }
        // OR probe incomplete: a term-free branch can satisfy the tree.
        for raw in ["year:2014", "(grid OR year:2014)", "grid OR year:2014", "year:2014 -grid"]
        {
            assert!(!Query::parse(raw, 512).unwrap().or_pool_covers(), "{raw}");
        }
    }

    #[test]
    fn parens_group() {
        let q = Query::parse("(grid OR cloud) year:2010..2014", 512).unwrap();
        match &q.ast {
            QueryNode::And(cs) => {
                assert!(cs.iter().any(|c| matches!(c, QueryNode::Or(_))));
                assert!(cs.iter().any(|c| matches!(c, QueryNode::YearRange(_))));
            }
            other => panic!("expected And root, got {other:?}"),
        }
        assert!(q.needs_filter());
    }

    #[test]
    fn display_roundtrips_through_parser() {
        for raw in [
            "grid computing",
            "\"grid computing\" -cloud year:2010..2014",
            "(grid OR cloud) title:scheduling",
            "storage AND replication",
        ] {
            let q = Query::parse(raw, 512).unwrap();
            let rendered = q.ast.to_string();
            let q2 = Query::parse(&rendered, 512).unwrap();
            assert_eq!(q.ast, q2.ast, "display of {raw:?} -> {rendered:?} reparsed differently");
        }
    }

    #[test]
    fn matcher_evaluates_against_shard() {
        use crate::corpus::{CorpusGenerator, CorpusSpec};
        let gen = CorpusGenerator::new(CorpusSpec {
            num_docs: 40,
            vocab_size: 300,
            ..CorpusSpec::default()
        });
        let shard = Shard::build(0, gen.generate_range(0, 40), 256);
        let year = shard.pubs[7].year;
        let q = Query::parse(&format!("year:{year}"), 256).unwrap();
        assert!(q.matches(&shard, 7));
        let q2 = Query::parse(&format!("year:{}", year + 1000), 256).unwrap();
        assert!(!q2.matches(&shard, 7));
        // Negation flips.
        let title_word = shard.pubs[7].title.split_whitespace().next().unwrap().to_string();
        let with = Query::parse(&title_word, 256);
        if let Ok(with) = with {
            let without = Query::parse(&format!("year:{year} -{title_word}"), 256).unwrap();
            assert!(with.matches(&shard, 7));
            assert!(!without.matches(&shard, 7));
        }
    }
}
