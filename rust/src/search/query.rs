//! Query language: keyword and multivariate search.
//!
//! The paper's USI "provides keyword-based and multivariate-based search
//! types". Grammar:
//!
//! ```text
//! query      := clause+
//! clause     := word                  free keyword (scored, any field)
//!             | field ':' word        field-scoped keyword (scored + must
//!                                     appear in that field)
//!             | 'year' ':' y ('..' y)?   hard year filter
//! field      := title | abstract | authors | venue
//! ```
//!
//! Examples: `grid computing`, `title:grid venue:conference`,
//! `scheduling year:2010..2014`.

use crate::text::{term_feature, terms, Field};

/// Inclusive year range filter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RangeFilter {
    pub min: u32,
    pub max: u32,
}

impl RangeFilter {
    pub fn contains(&self, y: u32) -> bool {
        (self.min..=self.max).contains(&y)
    }
}

/// Parse failure.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryError(pub String);

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "query error: {}", self.0)
    }
}

impl std::error::Error for QueryError {}

/// A parsed, analyzed query ready for retrieval + ranking.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedQuery {
    /// Original query text (for logging / JDF).
    pub raw: String,
    /// Scored keyword terms (normalized).
    pub keywords: Vec<String>,
    /// Feature buckets of `keywords` in the artifact space.
    pub buckets: Vec<u32>,
    /// Field-scoped required terms: (field, normalized term).
    pub field_terms: Vec<(Field, String)>,
    /// Optional hard year filter.
    pub year: Option<RangeFilter>,
}

impl ParsedQuery {
    /// Parse + analyze a query string into the `features`-bucket space.
    pub fn parse(raw: &str, features: usize) -> Result<ParsedQuery, QueryError> {
        let mut keywords = Vec::new();
        let mut field_terms = Vec::new();
        let mut year = None;

        for tok in raw.split_whitespace() {
            if let Some((head, rest)) = tok.split_once(':') {
                let head_lc = head.to_ascii_lowercase();
                if head_lc == "year" {
                    year = Some(parse_year_filter(rest)?);
                    continue;
                }
                if let Some(field) = Field::parse(&head_lc) {
                    let normalized = terms(rest);
                    if normalized.is_empty() {
                        return Err(QueryError(format!("empty term in '{tok}'")));
                    }
                    for t in normalized {
                        keywords.push(t.clone());
                        field_terms.push((field, t));
                    }
                    continue;
                }
                return Err(QueryError(format!("unknown field '{head}' in '{tok}'")));
            }
            keywords.extend(terms(tok));
        }

        if keywords.is_empty() && year.is_none() {
            return Err(QueryError("query has no searchable terms".into()));
        }
        let buckets = keywords.iter().map(|t| term_feature(t, features) as u32).collect();
        Ok(ParsedQuery { raw: raw.to_string(), keywords, buckets, field_terms, year })
    }

    /// Whether this query uses multivariate constraints.
    pub fn is_multivariate(&self) -> bool {
        !self.field_terms.is_empty() || self.year.is_some()
    }
}

fn parse_year_filter(spec: &str) -> Result<RangeFilter, QueryError> {
    let parse_y = |s: &str| -> Result<u32, QueryError> {
        s.parse::<u32>().map_err(|_| QueryError(format!("bad year '{s}'")))
    };
    if let Some((lo, hi)) = spec.split_once("..") {
        let (min, max) = (parse_y(lo)?, parse_y(hi)?);
        if min > max {
            return Err(QueryError(format!("empty year range {min}..{max}")));
        }
        Ok(RangeFilter { min, max })
    } else {
        let y = parse_y(spec)?;
        Ok(RangeFilter { min: y, max: y })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keyword_query() {
        let q = ParsedQuery::parse("grid computing publications", 512).unwrap();
        assert_eq!(q.keywords, vec!["grid", "comput", "publication"]);
        assert_eq!(q.buckets.len(), 3);
        assert!(!q.is_multivariate());
        assert!(q.year.is_none());
    }

    #[test]
    fn field_scoped_terms() {
        let q = ParsedQuery::parse("title:grid venue:conference", 512).unwrap();
        assert_eq!(q.field_terms.len(), 2);
        assert_eq!(q.field_terms[0].0, Field::Title);
        assert_eq!(q.field_terms[1], (Field::Venue, "conference".to_string()));
        // Field terms are also scored keywords.
        assert_eq!(q.keywords.len(), 2);
        assert!(q.is_multivariate());
    }

    #[test]
    fn year_filters() {
        let q = ParsedQuery::parse("scheduling year:2010..2014", 512).unwrap();
        assert_eq!(q.year, Some(RangeFilter { min: 2010, max: 2014 }));
        assert!(q.year.unwrap().contains(2012));
        assert!(!q.year.unwrap().contains(2009));
        let q1 = ParsedQuery::parse("x year:2005", 512).unwrap();
        assert_eq!(q1.year, Some(RangeFilter { min: 2005, max: 2005 }));
    }

    #[test]
    fn errors() {
        assert!(ParsedQuery::parse("", 512).is_err());
        assert!(ParsedQuery::parse("the of and", 512).is_err()); // all stopwords
        assert!(ParsedQuery::parse("body:grid", 512).is_err()); // unknown field
        assert!(ParsedQuery::parse("year:20x4", 512).is_err());
        assert!(ParsedQuery::parse("year:2014..2010", 512).is_err());
        assert!(ParsedQuery::parse("title:", 512).is_err());
    }

    #[test]
    fn year_only_query_is_valid() {
        let q = ParsedQuery::parse("year:2014", 512).unwrap();
        assert!(q.keywords.is_empty());
        assert!(q.is_multivariate());
    }

    #[test]
    fn buckets_in_feature_space() {
        let q = ParsedQuery::parse("massive academic publications", 128).unwrap();
        assert!(q.buckets.iter().all(|&b| b < 128));
    }

    #[test]
    fn query_terms_normalized_like_documents() {
        let q = ParsedQuery::parse("Searching PUBLICATIONS", 512).unwrap();
        assert_eq!(q.keywords, vec!["search", "publication"]);
    }
}
