//! Distributed top-k merge.
//!
//! Node-local top-k lists flow node -> VO broker -> root broker; each hop
//! merges sorted lists into one sorted top-k. Scores are comparable
//! across nodes because every Search Service ranks with the corpus-global
//! statistics distributed by the locator.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::search::LocalHit;

/// Heap entry: (list index, position within list).
struct HeapItem {
    score: f32,
    global_id: u64,
    list: usize,
    pos: usize,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap by score, ties broken by smaller global_id first
        // (deterministic merges regardless of list order). total_cmp so a
        // NaN score orders consistently instead of collapsing to Equal
        // and destabilising the merge.
        self.score
            .total_cmp(&other.score)
            .then_with(|| other.global_id.cmp(&self.global_id))
    }
}

/// K-way merge of per-node top-k lists (each sorted descending) into one
/// top-k, deduplicating by `global_id` (keeps the higher score — replicas
/// can only produce identical scores, so either is correct).
pub fn merge_topk(lists: &[Vec<LocalHit>], k: usize) -> Vec<LocalHit> {
    let mut heap = BinaryHeap::new();
    for (li, list) in lists.iter().enumerate() {
        debug_assert!(
            // total_cmp, matching the producers' sort order: a NaN score
            // (ranked first by the service) must not trip this assert.
            list.windows(2).all(|w| w[0].score.total_cmp(&w[1].score).is_ge()),
            "merge input {li} not sorted"
        );
        if let Some(h) = list.first() {
            heap.push(HeapItem { score: h.score, global_id: h.global_id, list: li, pos: 0 });
        }
    }
    let mut out: Vec<LocalHit> = Vec::with_capacity(k);
    let mut seen = std::collections::HashSet::new();
    while out.len() < k {
        let Some(top) = heap.pop() else { break };
        if seen.insert(top.global_id) {
            out.push(LocalHit { global_id: top.global_id, score: top.score });
        }
        let next_pos = top.pos + 1;
        if let Some(h) = lists[top.list].get(next_pos) {
            heap.push(HeapItem {
                score: h.score,
                global_id: h.global_id,
                list: top.list,
                pos: next_pos,
            });
        }
    }
    out
}

/// Wire size of a result list in bytes (charged to the network model):
/// id + score + a small envelope per hit.
pub fn result_wire_bytes(hits: usize) -> usize {
    32 + hits * 24
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hits(pairs: &[(u64, f32)]) -> Vec<LocalHit> {
        pairs.iter().map(|&(global_id, score)| LocalHit { global_id, score }).collect()
    }

    #[test]
    fn merges_sorted_lists() {
        let a = hits(&[(1, 9.0), (2, 5.0), (3, 1.0)]);
        let b = hits(&[(4, 7.0), (5, 3.0)]);
        let merged = merge_topk(&[a, b], 4);
        assert_eq!(
            merged,
            hits(&[(1, 9.0), (4, 7.0), (2, 5.0), (5, 3.0)])
        );
    }

    #[test]
    fn truncates_to_k() {
        let a = hits(&[(1, 9.0), (2, 8.0)]);
        let b = hits(&[(3, 7.0), (4, 6.0)]);
        assert_eq!(merge_topk(&[a, b], 2), hits(&[(1, 9.0), (2, 8.0)]));
    }

    #[test]
    fn dedups_by_global_id() {
        let a = hits(&[(1, 9.0), (2, 5.0)]);
        let b = hits(&[(1, 9.0), (3, 4.0)]);
        let merged = merge_topk(&[a, b], 10);
        assert_eq!(merged, hits(&[(1, 9.0), (2, 5.0), (3, 4.0)]));
    }

    #[test]
    fn handles_empty_inputs() {
        assert!(merge_topk(&[], 5).is_empty());
        assert!(merge_topk(&[vec![], vec![]], 5).is_empty());
        let a = hits(&[(1, 1.0)]);
        assert_eq!(merge_topk(&[a, vec![]], 5).len(), 1);
    }

    #[test]
    fn deterministic_under_list_permutation() {
        let a = hits(&[(1, 5.0), (3, 2.0)]);
        let b = hits(&[(2, 5.0), (4, 2.0)]);
        let m1 = merge_topk(&[a.clone(), b.clone()], 4);
        let m2 = merge_topk(&[b, a], 4);
        assert_eq!(m1, m2);
    }

    #[test]
    fn equals_flat_sort() {
        // Property: merge == sort(concat) with dedup, for sorted inputs.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let nlists = rng.range(1, 5);
            let lists: Vec<Vec<LocalHit>> = (0..nlists)
                .map(|li| {
                    let n = rng.range(0, 8);
                    let mut l: Vec<LocalHit> = (0..n)
                        .map(|i| LocalHit {
                            global_id: (li * 100 + i) as u64,
                            score: (rng.below(50) as f32) / 10.0,
                        })
                        .collect();
                    l.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
                    l
                })
                .collect();
            let k = rng.range(1, 12);
            let merged = merge_topk(&lists, k);
            let mut flat: Vec<LocalHit> = lists.concat();
            flat.sort_by(|a, b| {
                b.score
                    .partial_cmp(&a.score)
                    .unwrap()
                    .then(a.global_id.cmp(&b.global_id))
            });
            flat.truncate(k);
            assert_eq!(merged.len(), flat.len());
            for (m, f) in merged.iter().zip(&flat) {
                assert_eq!(m.score, f.score);
            }
        }
    }
}
