//! Query Manager (QM): JDF creation, job tracking, perf recording.
//!
//! Paper: "the QM creates the Job Description File (JDF) ... keeps track
//! of all job execution in the system by keeping the job information in
//! the database. After the search task is completed, the QM sends the
//! information about resource performance to the database to be used in
//! the future search tasks."

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::grid::NodeId;
use crate::search::SearchRequest;

use super::jdf::{JobDescription, JobId};
use super::perf::PerfDb;
use super::qee::ExecutionPlan;

/// Job lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Created,
    Dispatched,
    Completed,
    Failed,
}

/// Job-table entry.
#[derive(Debug, Clone)]
struct JobRecord {
    jdf: JobDescription,
    status: JobStatus,
    /// Docs searched (filled at completion).
    docs: u64,
    /// Accounted node-local work seconds (filled at completion).
    work_s: f64,
}

/// The Query Manager.
#[derive(Debug, Default)]
pub struct QueryManager {
    jobs: BTreeMap<JobId, JobRecord>,
    next_id: u64,
}

impl QueryManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Materialize an execution plan into JDFs (one job per node, each
    /// carrying the whole request batch behind the caller's shared
    /// `Arc` — no copy per node or per retained job record).
    /// `reply_to_of` names the broker collecting each node's results.
    pub fn create_jobs(
        &mut self,
        requests: &Arc<Vec<SearchRequest>>,
        plan: &ExecutionPlan,
        reply_to_of: impl Fn(NodeId) -> NodeId,
    ) -> Vec<JobDescription> {
        let mut out = Vec::with_capacity(plan.assignments.len());
        for (node, sources) in &plan.assignments {
            let id = JobId(self.next_id);
            self.next_id += 1;
            let jdf = JobDescription {
                id,
                requests: Arc::clone(requests),
                node: *node,
                sources: sources.clone(),
                reply_to: reply_to_of(*node),
            };
            self.jobs.insert(
                id,
                JobRecord { jdf: jdf.clone(), status: JobStatus::Created, docs: 0, work_s: 0.0 },
            );
            out.push(jdf);
        }
        out
    }

    /// Mark a job dispatched to its node.
    pub fn mark_dispatched(&mut self, id: JobId) {
        if let Some(r) = self.jobs.get_mut(&id) {
            r.status = JobStatus::Dispatched;
        }
    }

    /// Record a completed job and feed the perf database.
    pub fn complete(&mut self, id: JobId, docs: u64, work_s: f64, perf: &mut PerfDb) {
        if let Some(r) = self.jobs.get_mut(&id) {
            r.status = JobStatus::Completed;
            r.docs = docs;
            r.work_s = work_s;
            perf.record(r.jdf.node, docs, work_s);
        }
    }

    /// Record a failed job (node died mid-flight).
    pub fn fail(&mut self, id: JobId) {
        if let Some(r) = self.jobs.get_mut(&id) {
            r.status = JobStatus::Failed;
        }
    }

    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.jobs.get(&id).map(|r| r.status)
    }

    /// Jobs ever created (the paper's job database size).
    pub fn total_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Completed-job count (metrics).
    pub fn completed_jobs(&self) -> usize {
        self.jobs.values().filter(|r| r.status == JobStatus::Completed).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn plan(pairs: &[(u32, &[u32])]) -> ExecutionPlan {
        let mut assignments = BTreeMap::new();
        for (node, sources) in pairs {
            assignments.insert(NodeId(*node), sources.to_vec());
        }
        ExecutionPlan { assignments }
    }

    fn reqs(queries: &[&str]) -> Arc<Vec<SearchRequest>> {
        Arc::new(queries.iter().map(|q| SearchRequest::new(*q)).collect())
    }

    #[test]
    fn creates_one_job_per_node() {
        let mut qm = QueryManager::new();
        let p = plan(&[(0, &[0, 1]), (3, &[2])]);
        let jobs = qm.create_jobs(&reqs(&["grid"]), &p, |_| NodeId(0));
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].node, NodeId(0));
        assert_eq!(jobs[1].sources, vec![2]);
        assert_eq!(qm.total_jobs(), 2);
        assert_ne!(jobs[0].id, jobs[1].id);
        for j in &jobs {
            assert_eq!(qm.status(j.id), Some(JobStatus::Created));
        }
    }

    #[test]
    fn batched_requests_ride_one_job() {
        let mut qm = QueryManager::new();
        let p = plan(&[(0, &[0, 1])]);
        let jobs = qm.create_jobs(&reqs(&["grid", "cloud storage", "archive"]), &p, |_| NodeId(0));
        assert_eq!(jobs.len(), 1, "a batch still dispatches once per node");
        assert_eq!(jobs[0].requests.len(), 3);
    }

    #[test]
    fn lifecycle_and_perf_recording() {
        let mut qm = QueryManager::new();
        let mut perf = PerfDb::default();
        let p = plan(&[(1, &[0])]);
        let jobs = qm.create_jobs(&reqs(&["q"]), &p, |_| NodeId(0));
        let id = jobs[0].id;
        qm.mark_dispatched(id);
        assert_eq!(qm.status(id), Some(JobStatus::Dispatched));
        qm.complete(id, 500, 0.25, &mut perf);
        assert_eq!(qm.status(id), Some(JobStatus::Completed));
        assert_eq!(qm.completed_jobs(), 1);
        assert!((perf.estimate(NodeId(1)) - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn failed_jobs_tracked() {
        let mut qm = QueryManager::new();
        let p = plan(&[(1, &[0])]);
        let jobs = qm.create_jobs(&reqs(&["q"]), &p, |_| NodeId(0));
        qm.fail(jobs[0].id);
        assert_eq!(qm.status(jobs[0].id), Some(JobStatus::Failed));
        assert_eq!(qm.completed_jobs(), 0);
    }

    #[test]
    fn ids_monotone_across_queries() {
        let mut qm = QueryManager::new();
        let p = plan(&[(0, &[0])]);
        let a = qm.create_jobs(&reqs(&["q1"]), &p, |_| NodeId(0))[0].id;
        let b = qm.create_jobs(&reqs(&["q2"]), &p, |_| NodeId(0))[0].id;
        assert!(b > a);
    }
}
