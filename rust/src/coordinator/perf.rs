//! Performance-history database.
//!
//! Paper: "After the search task is completed, the QM sends the
//! information about resource performance to the database to be used in
//! the future search tasks" and "the execution plan ... depends on the
//! previous performance and produces the best combination to handle the
//! query." This is the database: per-node EWMA of observed search
//! throughput (docs/second). Unknown nodes get the prior 1.0 relative
//! estimate, so the first plan is uniform and later plans adapt — exactly
//! the adaptive behaviour the GAPS speedup curves rely on.

use std::collections::BTreeMap;

use crate::grid::NodeId;

/// EWMA throughput record for one node.
#[derive(Debug, Clone, Copy)]
struct Record {
    docs_per_s: f64,
    samples: u64,
}

/// The performance database (lives with the QM on the broker).
#[derive(Debug)]
pub struct PerfDb {
    records: BTreeMap<NodeId, Record>,
    /// EWMA smoothing factor for new observations.
    alpha: f64,
    /// Prior throughput estimate for unobserved nodes (docs/s). Relative
    /// scale only — plans normalize across nodes.
    prior: f64,
}

impl Default for PerfDb {
    fn default() -> Self {
        PerfDb::new(0.4, 1.0)
    }
}

impl PerfDb {
    pub fn new(alpha: f64, prior: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha) && prior > 0.0);
        PerfDb { records: BTreeMap::new(), alpha, prior }
    }

    /// Record one completed job: `docs` searched in `seconds`.
    pub fn record(&mut self, node: NodeId, docs: u64, seconds: f64) {
        if seconds <= 0.0 || docs == 0 {
            return; // degenerate sample, ignore
        }
        let obs = docs as f64 / seconds;
        self.records
            .entry(node)
            .and_modify(|r| {
                r.docs_per_s = (1.0 - self.alpha) * r.docs_per_s + self.alpha * obs;
                r.samples += 1;
            })
            .or_insert(Record { docs_per_s: obs, samples: 1 });
    }

    /// Throughput estimate for a node. Unobserved nodes get the mean of
    /// observed throughputs (so a newly joined node is assumed average and
    /// receives work — its first samples then calibrate it), or the
    /// configured prior when nothing has been observed yet.
    pub fn estimate(&self, node: NodeId) -> f64 {
        if let Some(r) = self.records.get(&node) {
            return r.docs_per_s;
        }
        if self.records.is_empty() {
            self.prior
        } else {
            self.records.values().map(|r| r.docs_per_s).sum::<f64>() / self.records.len() as f64
        }
    }

    /// Number of samples recorded for a node.
    pub fn samples(&self, node: NodeId) -> u64 {
        self.records.get(&node).map(|r| r.samples).unwrap_or(0)
    }

    /// Whether any history exists (first-query detection in the plans).
    pub fn has_history(&self) -> bool {
        !self.records.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_for_unknown_nodes() {
        let db = PerfDb::default();
        assert_eq!(db.estimate(NodeId(5)), 1.0);
        assert_eq!(db.samples(NodeId(5)), 0);
        assert!(!db.has_history());
    }

    #[test]
    fn record_and_estimate() {
        let mut db = PerfDb::default();
        db.record(NodeId(0), 1000, 1.0);
        assert!((db.estimate(NodeId(0)) - 1000.0).abs() < 1e-9);
        assert_eq!(db.samples(NodeId(0)), 1);
        assert!(db.has_history());
    }

    #[test]
    fn ewma_converges_toward_new_rate() {
        let mut db = PerfDb::new(0.5, 1.0);
        db.record(NodeId(0), 100, 1.0); // 100 docs/s
        for _ in 0..20 {
            db.record(NodeId(0), 400, 1.0); // drifts to 400
        }
        let est = db.estimate(NodeId(0));
        assert!((est - 400.0).abs() < 1.0, "est={est}");
    }

    #[test]
    fn degenerate_samples_ignored() {
        let mut db = PerfDb::default();
        db.record(NodeId(0), 0, 1.0);
        db.record(NodeId(0), 100, 0.0);
        assert_eq!(db.samples(NodeId(0)), 0);
    }

    #[test]
    fn fast_node_estimated_faster() {
        let mut db = PerfDb::default();
        for _ in 0..5 {
            db.record(NodeId(0), 1000, 1.0); // 1000 docs/s
            db.record(NodeId(1), 1000, 2.0); // 500 docs/s
        }
        assert!(db.estimate(NodeId(0)) > 1.8 * db.estimate(NodeId(1)));
    }
}
