//! The deployed GAPS system: fabric + data + services + the typed search
//! surface (`search` / `search_request` / `search_batch`).
//!
//! Execution topology (paper Fig 1 + §III):
//!
//! ```text
//! USI -> root broker QEE
//!          |-- ResourceManager (node status)
//!          |-- DataSourceLocator (sources + global stats)
//!          |-- QEE.plan (perf-history LPT)  -> QM.create_jobs (JDFs)
//!          |-- per VO (parallel, WAN):   VO broker QEE
//!          |        dispatches its jobs serially (LAN), nodes run the
//!          |        Search Service on their sources, reply to the broker
//!          |        which merges its VO's lists
//!          `-- root merges VO lists -> user
//! ```
//!
//! **Batching:** a request batch is planned once, materialized as one JDF
//! per node carrying every request, and fanned out in one round — the
//! per-job dispatch slots, container acquisitions, and worker threads are
//! paid once for the whole batch instead of once per query, and the
//! Search Services feed all Q query rows through the artifact scoring
//! path (`SearchService::search_batch`). Every
//! response in a batch reports the shared batch critical path as its
//! timeline (all queries complete when the batch completes). Hits and
//! scores are bit-identical to sequential execution (enforced by
//! `tests/prop_batch_parity.rs`).
//!
//! **Fan-out substrate:** node jobs execute on the system's *resident*
//! gridpool ([`crate::util::pool::Pool::scope_map`]) — workers are
//! spawned once at deployment and reused for every batch, so a serving
//! workload (see [`crate::serve`]) pays no per-batch thread spawns and
//! keeps per-worker retrieval scratches warm across batches.
//!
//! Timing: real measured compute (`work_s`, scaled by the node's simulated
//! speed factor) + accounted fabric costs (`net_s`, `overhead_s`). See
//! ARCHITECTURE.md §Substitutions for why this composition is faithful.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::GapsConfig;
use crate::corpus::{CorpusGenerator, CorpusSpec, Publication};
use crate::fault::{ChaosPlan, FaultDecision, FaultInjector};
use crate::grid::{GridFabric, NodeId};
use crate::index::{GlobalStats, RetrievalCounters, Shard, ShardStats};
use crate::obs::TraceSpan;
use crate::storage::{
    merge_shards, read_shard_snapshot, write_shard_snapshot, ManifestOverlay, ManifestSource,
    SnapshotManifest,
};
use crate::runtime::Executor;
use crate::search::{
    CompiledRequest, LocalHit, Query, ReplicaPref, Scorer, SearchError, SearchRequest,
    SearchService,
};
use crate::util::json::Json;
use crate::util::pool::Pool;

use crate::util::clock::{TaskTimeline, WallClock};

use super::jdf::JobDescription;
use super::locator::{DataSource, DataSourceLocator};
use super::merge::{merge_topk, result_wire_bytes};
use super::perf::PerfDb;
use super::qee::QueryExecutionEngine;
use super::qm::QueryManager;
use super::resource_manager::ResourceManager;

/// Analyzed corpus data: the expensive, node-count-independent half of a
/// deployment (generation + tokenization + indexing of every sub-shard).
/// Built once and shared across sweep points / systems via `Arc`.
#[derive(Debug)]
pub struct CorpusData {
    /// source id -> analyzed sub-shard.
    pub shards: BTreeMap<u32, Shard>,
    /// (doc_start, doc_count) per source id, in id order (doc_start is
    /// strictly increasing — the binary search in
    /// [`Deployment::publication`] relies on it).
    pub ranges: Vec<(u64, u64)>,
    /// The corpus generator (query sampling, record lookups).
    pub generator: CorpusGenerator,
    /// Feature-space size the shards were analyzed with.
    pub features: usize,
}

impl CorpusData {
    /// Generate + analyze the corpus as `num_sources` contiguous shards.
    pub fn build(cfg: &GapsConfig, num_sources: u64) -> Result<CorpusData, SearchError> {
        let spec = CorpusSpec {
            seed: cfg.workload.seed,
            num_docs: cfg.workload.num_docs,
            ..CorpusSpec::default()
        };
        let generator = CorpusGenerator::new(spec);
        let num_sources = num_sources.max(1);
        let docs_per = cfg.workload.num_docs / num_sources;
        if docs_per == 0 {
            return Err(SearchError::config(format!(
                "corpus too small: {} docs over {num_sources} sources",
                cfg.workload.num_docs
            )));
        }
        let mut shards = BTreeMap::new();
        let mut ranges = Vec::with_capacity(num_sources as usize);
        for sid in 0..num_sources {
            let start = sid * docs_per;
            let count = if sid == num_sources - 1 {
                cfg.workload.num_docs - start // last source takes the tail
            } else {
                docs_per
            };
            let shard =
                Shard::build(sid as u32, generator.generate_range(start, count), cfg.search.features);
            shards.insert(sid as u32, shard);
            ranges.push((start, count));
        }
        Ok(CorpusData { shards, ranges, generator, features: cfg.search.features })
    }
}

/// Immutable deployment: fabric + analyzed data + replica placement,
/// shared by GAPS and the traditional baseline so comparisons run over
/// identical bits.
#[derive(Debug)]
pub struct Deployment {
    pub fabric: GridFabric,
    /// Nodes participating in this experiment (first n, VO-balanced).
    pub active: Vec<NodeId>,
    /// The analyzed corpus (shared across deployments).
    pub data: Arc<CorpusData>,
    pub locator: DataSourceLocator,
    pub stats: GlobalStats,
}

impl Deployment {
    /// Build a deployment from scratch (corpus + placement). Sweeps that
    /// reuse one corpus across node counts should call [`CorpusData::
    /// build`] once and [`Deployment::assemble`] per point instead.
    pub fn build(cfg: &GapsConfig, n_nodes: usize) -> Result<Deployment, SearchError> {
        let num_sources = cfg.workload.sub_shards.max(n_nodes).max(1) as u64;
        let data = Arc::new(CorpusData::build(cfg, num_sources)?);
        Deployment::assemble(cfg, n_nodes, data)
    }

    /// Place an analyzed corpus onto `n_nodes` nodes: each source gets a
    /// primary (round-robin over active nodes) plus a replica — same-VO
    /// when the VO has another active member (cheap LAN replication),
    /// any other active node otherwise.
    pub fn assemble(
        cfg: &GapsConfig,
        n_nodes: usize,
        data: Arc<CorpusData>,
    ) -> Result<Deployment, SearchError> {
        let fabric = GridFabric::build(&cfg.grid);
        if n_nodes == 0 || n_nodes > fabric.nodes.len() {
            return Err(SearchError::config(format!(
                "n_nodes {} out of range 1..={}",
                n_nodes,
                fabric.nodes.len()
            )));
        }
        if data.features != cfg.search.features {
            return Err(SearchError::config(format!(
                "corpus analyzed with F={}, config wants F={}",
                data.features, cfg.search.features
            )));
        }
        let active = fabric.first_nodes_balanced(n_nodes);

        let mut locator = DataSourceLocator::new();
        for (sid, &(start, count)) in data.ranges.iter().enumerate() {
            let primary = active[sid % n_nodes];
            let primary_vo = fabric.node(primary).vo;
            let same_vo = active
                .iter()
                .copied()
                .filter(|&n| n != primary && fabric.node(n).vo == primary_vo)
                .min_by_key(|n| (n.0 + fabric.nodes.len() as u32 - primary.0) % fabric.nodes.len() as u32);
            let secondary = same_vo.or_else(|| (n_nodes > 1).then(|| active[(sid + 1) % n_nodes]));
            let mut replicas = vec![primary];
            replicas.extend(secondary);
            locator.register(
                DataSource { id: sid as u32, doc_start: start, doc_count: count, replicas },
                &data.shards[&(sid as u32)].stats,
            );
        }
        let stats = locator
            .global_stats()
            .ok_or_else(|| SearchError::config("no sources registered"))?;
        Ok(Deployment { fabric, active, data, locator, stats })
    }

    /// Shard behind a source id.
    pub fn shard(&self, source_id: u32) -> Option<&Shard> {
        self.data.shards.get(&source_id)
    }

    /// The corpus generator (query sampling).
    pub fn generator(&self) -> &CorpusGenerator {
        &self.data.generator
    }

    /// Look up the publication record behind a corpus-global doc id.
    /// Binary search over the sorted `(doc_start, doc_count)` ranges —
    /// this runs once per returned hit per query, so the seed's linear
    /// scan over all sources was O(sources) on the response hot path.
    pub fn publication(&self, global_id: u64) -> Option<&Publication> {
        let ranges = &self.data.ranges;
        let idx = ranges.partition_point(|&(start, _)| start <= global_id).checked_sub(1)?;
        let (start, count) = ranges[idx];
        if global_id >= start + count {
            return None;
        }
        self.data
            .shards
            .get(&(idx as u32))
            .map(|s| &s.pubs[(global_id - start) as usize])
    }
}

/// One search hit as returned to the user.
#[derive(Debug, Clone, PartialEq)]
pub struct Hit {
    pub global_id: u64,
    pub score: f32,
    pub title: String,
}

/// Diagnostics attached to a response when the request asked for
/// `explain(true)`: the parsed AST, the scored terms, the execution
/// plan the batch ran under, and the aggregated retrieval work counters
/// (block-max pruning effectiveness) for this query across every shard.
#[derive(Debug, Clone)]
pub struct Explain {
    /// Canonical rendering of the parsed boolean tree.
    pub ast: String,
    /// Deduplicated scored keywords.
    pub keywords: Vec<String>,
    /// Requests sharing this plan/fan-out round.
    pub batch_size: usize,
    /// (node, assigned sources) of the shared execution plan.
    pub plan: Vec<(String, usize)>,
    /// Retrieval counters summed over every shard this query touched.
    pub counters: RetrievalCounters,
    /// Index epoch the response was computed at: bumped by every
    /// ingestion seal and overlay merge, 0 for a never-ingested
    /// deployment. Lets clients (and a future result cache) detect that
    /// the searchable corpus changed between two responses.
    pub epoch: u64,
    /// Per-stage monotonic timings for this request's fan-out round
    /// (compile / plan / execute+jobs / merge). Absent in wire forms
    /// produced before tracing existed.
    pub stages: Option<TraceSpan>,
}

/// Equality deliberately ignores `stages`: timings are measured per
/// execution and never reproduce, while everything else is a
/// deterministic function of the query and the index (the cache-parity
/// suites compare whole `Explain`s between a cached response and a
/// fresh oracle run).
impl PartialEq for Explain {
    fn eq(&self, other: &Explain) -> bool {
        self.ast == other.ast
            && self.keywords == other.keywords
            && self.batch_size == other.batch_size
            && self.plan == other.plan
            && self.counters == other.counters
            && self.epoch == other.epoch
    }
}

impl Explain {
    fn to_json(&self) -> Json {
        let mut out = Json::obj(vec![
            ("ast", Json::str(&self.ast)),
            ("keywords", Json::Arr(self.keywords.iter().map(|k| Json::str(k.clone())).collect())),
            ("batch_size", Json::from(self.batch_size)),
            (
                "plan",
                Json::Arr(
                    self.plan
                        .iter()
                        .map(|(n, s)| Json::Arr(vec![Json::str(n.clone()), Json::from(*s)]))
                        .collect(),
                ),
            ),
            ("counters", counters_to_json(&self.counters)),
            ("epoch", Json::from(self.epoch)),
        ]);
        if let Some(s) = &self.stages {
            if let Json::Obj(map) = &mut out {
                map.insert("stages".to_string(), s.to_json());
            }
        }
        out
    }

    fn from_json(v: &Json) -> Option<Explain> {
        Some(Explain {
            ast: v.get("ast")?.as_str()?.to_string(),
            keywords: v
                .get("keywords")?
                .as_arr()?
                .iter()
                .map(|k| k.as_str().map(str::to_string))
                .collect::<Option<Vec<_>>>()?,
            batch_size: v.get("batch_size")?.as_i64()? as usize,
            plan: v
                .get("plan")?
                .as_arr()?
                .iter()
                .map(|p| {
                    let p = p.as_arr()?;
                    Some((p.first()?.as_str()?.to_string(), p.get(1)?.as_i64()? as usize))
                })
                .collect::<Option<Vec<_>>>()?,
            counters: counters_from_json(v.get("counters")?)?,
            // Absent in pre-persistence wire forms: default to epoch 0.
            epoch: v.get("epoch").and_then(Json::as_i64).unwrap_or(0) as u64,
            // Absent in pre-tracing wire forms (and in cached entries
            // stored before the upgrade): tolerated as None.
            stages: v.get("stages").and_then(TraceSpan::from_json),
        })
    }
}

/// JSON encoding of [`RetrievalCounters`] (shared by the explain record
/// and the bench counter reports).
pub fn counters_to_json(c: &RetrievalCounters) -> Json {
    Json::obj(vec![
        ("postings_touched", Json::from(c.postings_touched)),
        ("postings_total", Json::from(c.postings_total)),
        ("blocks_skipped", Json::from(c.blocks_skipped)),
        ("blocks_total", Json::from(c.blocks_total)),
        ("candidates_emitted", Json::from(c.candidates_emitted)),
        ("skipped_fraction", Json::from(c.skipped_fraction())),
    ])
}

/// Parse the JSON encoding produced by [`counters_to_json`].
pub fn counters_from_json(v: &Json) -> Option<RetrievalCounters> {
    Some(RetrievalCounters {
        postings_touched: v.get("postings_touched")?.as_i64()? as u64,
        postings_total: v.get("postings_total")?.as_i64()? as u64,
        blocks_skipped: v.get("blocks_skipped")?.as_i64()? as u64,
        blocks_total: v.get("blocks_total")?.as_i64()? as u64,
        candidates_emitted: v.get("candidates_emitted")?.as_i64()? as u64,
    })
}

/// End-to-end response: hits + the composed timeline.
#[derive(Debug, Clone)]
pub struct SearchResponse {
    pub query: String,
    pub hits: Vec<Hit>,
    /// Composed critical-path timeline (work / net / overhead split).
    /// For a batched request this is the shared batch critical path.
    pub timeline: TaskTimeline,
    /// Jobs dispatched for this query's batch.
    pub jobs: usize,
    /// Candidates retrieved across all nodes (this query only).
    pub candidates: usize,
    /// Documents in all searched sources.
    pub docs_scanned: u64,
    /// True when the request allowed partial coverage and some sources
    /// were unreachable: `hits` ranks only the reachable corpus.
    pub degraded: bool,
    /// The unreachable source ids behind a degraded response (sorted;
    /// empty when `degraded` is false).
    pub missing_sources: Vec<u32>,
    /// Plan/AST diagnostics (present when the request set `explain`).
    pub explain: Option<Explain>,
    /// Stage-timing tree for this request's fan-out round. Always
    /// populated by a live execution regardless of `explain`; not part
    /// of the JSON wire form (the serving layer consumes it for
    /// histograms and the slow-query log, and surfaces it to clients
    /// only through `explain.stages`).
    pub trace: Option<TraceSpan>,
}

impl SearchResponse {
    /// The paper's response-time metric.
    pub fn response_s(&self) -> f64 {
        self.timeline.total_s()
    }

    /// JSON wire form — the envelope a front-end would return. Shares
    /// the `util::json` substrate with [`SearchRequest`] and the JDF.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("query", Json::str(&self.query)),
            (
                "hits",
                Json::Arr(
                    self.hits
                        .iter()
                        .map(|h| {
                            Json::obj(vec![
                                ("id", Json::from(h.global_id)),
                                ("score", Json::from(h.score as f64)),
                                ("title", Json::str(&h.title)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "timeline",
                Json::obj(vec![
                    ("work_s", Json::from(self.timeline.work_s)),
                    ("net_s", Json::from(self.timeline.net_s)),
                    ("overhead_s", Json::from(self.timeline.overhead_s)),
                ]),
            ),
            ("jobs", Json::from(self.jobs)),
            ("candidates", Json::from(self.candidates)),
            ("docs_scanned", Json::from(self.docs_scanned)),
        ];
        if self.degraded {
            pairs.push(("degraded", Json::Bool(true)));
            pairs.push((
                "missing_sources",
                Json::Arr(self.missing_sources.iter().map(|&s| Json::from(s as i64)).collect()),
            ));
        }
        if let Some(e) = &self.explain {
            pairs.push(("explain", e.to_json()));
        }
        Json::obj(pairs)
    }

    /// Parse the JSON wire form.
    pub fn from_json(v: &Json) -> Option<SearchResponse> {
        let tl = v.get("timeline")?;
        Some(SearchResponse {
            query: v.get("query")?.as_str()?.to_string(),
            hits: v
                .get("hits")?
                .as_arr()?
                .iter()
                .map(|h| {
                    Some(Hit {
                        global_id: h.get("id")?.as_i64()? as u64,
                        score: h.get("score")?.as_f64()? as f32,
                        title: h.get("title")?.as_str()?.to_string(),
                    })
                })
                .collect::<Option<Vec<_>>>()?,
            timeline: TaskTimeline {
                work_s: tl.get("work_s")?.as_f64()?,
                net_s: tl.get("net_s")?.as_f64()?,
                overhead_s: tl.get("overhead_s")?.as_f64()?,
            },
            jobs: v.get("jobs")?.as_i64()? as usize,
            candidates: v.get("candidates")?.as_i64()? as usize,
            docs_scanned: v.get("docs_scanned")?.as_i64()? as u64,
            degraded: match v.get("degraded") {
                Some(d) => d.as_bool()?,
                None => false,
            },
            missing_sources: match v.get("missing_sources") {
                Some(m) => m
                    .as_arr()?
                    .iter()
                    .map(|s| s.as_i64().map(|x| x as u32))
                    .collect::<Option<Vec<_>>>()?,
                None => Vec::new(),
            },
            explain: match v.get("explain") {
                Some(e) => Some(Explain::from_json(e)?),
                None => None,
            },
            // Process-local diagnostic; never crosses the wire.
            trace: None,
        })
    }
}

/// Pure compute result of one batched search job (fabric costs are
/// accounted by the caller): per-query merged local hits + measured work
/// + scan counters.
struct JobOutput {
    /// Per query (batch order): top hits merged across the job's sources.
    per_query_hits: Vec<Vec<LocalHit>>,
    /// Per query: candidates retrieved across the job's sources.
    per_query_candidates: Vec<usize>,
    /// Per query: retrieval work counters summed across the job's sources.
    per_query_counters: Vec<RetrievalCounters>,
    work_measured: f64,
    /// Docs in the job's sources (scanned once *per query*).
    docs: u64,
    /// Monotonic wall seconds this job spent executing (fault delays
    /// included) — the `job` span duration in the request trace.
    wall_s: f64,
}

/// Execute one job's search work over its sources for the whole query
/// batch. Free function (not a `GapsSystem` method) so the parallel
/// fan-out can call it from worker threads while the coordinator keeps
/// its `&mut self` bookkeeping.
///
/// `stats` is the global statistics snapshot the batch scores against
/// (the deployment's base stats, or the live stats including sealed
/// ingestion overlays), `overlays` the sealed-segment map: a source's
/// overlay segments are searched right after its base shard on the same
/// node, and their hits enter the same placement-invariant merge.
///
/// `faults` is the executor-path fail-point: a chaos-scheduled node
/// crashes before its first source, crashes halfway through its source
/// list (partial work is discarded — re-searching a source on another
/// replica is idempotent), or sleeps an injected delay before running
/// normally.
fn run_job(
    service: &SearchService,
    dep: &Deployment,
    stats: &GlobalStats,
    overlays: &BTreeMap<u32, SourceOverlay>,
    queries: &[(&Query, usize)],
    job: &JobDescription,
    scorer: &mut Scorer<'_>,
    faults: Option<&FaultInjector>,
) -> Result<JobOutput, SearchError> {
    let job_clock = WallClock::start();
    let decision = faults.map_or(FaultDecision::Proceed, |f| f.decide(job.node));
    match decision {
        FaultDecision::CrashBefore => {
            return Err(SearchError::unavailable(format!(
                "injected fault: node {} crashed before executing job {:?}",
                job.node, job.id
            )));
        }
        FaultDecision::Delay(d) => std::thread::sleep(d),
        FaultDecision::Proceed | FaultDecision::CrashMid => {}
    }
    let crash_after =
        matches!(decision, FaultDecision::CrashMid).then(|| job.sources.len() / 2);
    let nq = queries.len();
    let mut work_measured = 0.0f64;
    let mut per_query_candidates = vec![0usize; nq];
    let mut per_query_counters = vec![RetrievalCounters::default(); nq];
    let mut docs = 0u64;
    let mut hits_lists: Vec<Vec<Vec<LocalHit>>> = vec![Vec::with_capacity(job.sources.len()); nq];
    for (si, sid) in job.sources.iter().enumerate() {
        if crash_after == Some(si) {
            return Err(SearchError::unavailable(format!(
                "injected fault: node {} crashed mid-batch in job {:?}",
                job.node, job.id
            )));
        }
        let shard = dep.shard(*sid).ok_or(SearchError::SourceUnknown { source: *sid })?;
        let outs = service.search_batch(shard, stats, queries, scorer)?;
        docs += shard.len() as u64;
        for (qi, out) in outs.into_iter().enumerate() {
            work_measured += out.work_s;
            per_query_candidates[qi] += out.candidates;
            per_query_counters[qi].merge(&out.counters);
            hits_lists[qi].push(out.hits);
        }
        // Sealed ingestion overlays ride with their base source: an
        // overlay segment is just another (small) shard, searched with
        // the same stats and merged through the same top-k path.
        if let Some(ov) = overlays.get(sid) {
            for seg in &ov.sealed {
                let outs = service.search_batch(seg, stats, queries, scorer)?;
                docs += seg.len() as u64;
                for (qi, out) in outs.into_iter().enumerate() {
                    work_measured += out.work_s;
                    per_query_candidates[qi] += out.candidates;
                    per_query_counters[qi].merge(&out.counters);
                    hits_lists[qi].push(out.hits);
                }
            }
        }
    }
    let per_query_hits = hits_lists
        .into_iter()
        .zip(queries)
        .map(|(lists, (_, top_k))| merge_topk(&lists, *top_k))
        .collect();
    Ok(JobOutput {
        per_query_hits,
        per_query_candidates,
        per_query_counters,
        work_measured,
        docs,
        wall_s: job_clock.elapsed_s(),
    })
}

/// Counters for the fault-tolerance machinery: how often jobs failed
/// mid-flight, how many re-planning rounds ran, and how the probation /
/// recovery cycle behaved. Cumulative over the system's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FailoverStats {
    /// Per-node jobs that failed during a fan-out round.
    pub jobs_failed: u64,
    /// Re-planning rounds triggered by failed jobs.
    pub replans: u64,
    /// Nodes marked Down because one of their jobs failed.
    pub nodes_marked_down: u64,
    /// Health probes issued to downed nodes whose probation elapsed.
    pub probes: u64,
    /// Probes that came back healthy (node rejoined).
    pub recoveries: u64,
    /// Responses returned with `degraded: true`.
    pub degraded_responses: u64,
}

/// Per-source live-ingestion overlay: sealed immutable overlay segments
/// (searchable, each an independently analyzed [`Shard`]) plus the
/// unsealed buffer (accepted but not yet searchable).
#[derive(Debug, Default)]
struct SourceOverlay {
    sealed: Vec<Shard>,
    buffer: Vec<Publication>,
}

/// Live-ingestion state layered over the immutable base deployment.
/// Tombstone-free and additive: publications only ever arrive, so the
/// overlay model is append + seal + merge — no deletes to reconcile.
#[derive(Debug)]
struct IngestState {
    /// source id -> its ingestion overlay (only sources that received
    /// ingested docs have an entry).
    overlays: BTreeMap<u32, SourceOverlay>,
    /// Next corpus-global doc id ingestion will assign.
    next_global_id: u64,
    /// Index epoch: bumped by every seal and every overlay merge.
    epoch: u64,
    /// Cumulative seal / merge counts (health reporting).
    seals: u64,
    merges: u64,
    /// Global stats covering base + sealed overlays, recomputed in
    /// canonical (source id, segment) order on every seal/merge so a
    /// snapshot-restored system reproduces them bit for bit. `None`
    /// until the first seal — the no-ingest path scores against exactly
    /// the deployment's own stats.
    live_stats: Option<GlobalStats>,
}

impl IngestState {
    fn new(next_global_id: u64) -> IngestState {
        IngestState {
            overlays: BTreeMap::new(),
            next_global_id,
            epoch: 0,
            seals: 0,
            merges: 0,
            live_stats: None,
        }
    }
}

/// What one [`GapsSystem::ingest`] / [`GapsSystem::flush_ingest`] call
/// did to the index.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IngestReport {
    /// Publications accepted (assigned global ids) by this call.
    pub accepted: usize,
    /// Publications still buffered (unsearchable) across all sources.
    pub buffered: usize,
    /// Overlay segments sealed by this call.
    pub sealed: usize,
    /// Overlay compaction merges performed by this call.
    pub merges: usize,
    /// Index epoch after this call.
    pub epoch: u64,
}

impl IngestReport {
    /// JSON wire form (the `POST /ingest` response body).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("accepted", Json::from(self.accepted)),
            ("buffered", Json::from(self.buffered)),
            ("sealed", Json::from(self.sealed)),
            ("merges", Json::from(self.merges)),
            ("epoch", Json::from(self.epoch)),
        ])
    }

    /// Parse the wire form produced by [`IngestReport::to_json`].
    pub fn from_json(v: &Json) -> Option<IngestReport> {
        Some(IngestReport {
            accepted: v.get("accepted")?.as_i64()? as usize,
            buffered: v.get("buffered")?.as_i64()? as usize,
            sealed: v.get("sealed")?.as_i64()? as usize,
            merges: v.get("merges")?.as_i64()? as usize,
            epoch: v.get("epoch")?.as_i64()? as u64,
        })
    }
}

/// Index-level health: the persistence/ingestion view `/healthz`
/// reports next to the serving-queue statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IndexHealth {
    /// Index epoch (0 = never ingested).
    pub epoch: u64,
    /// Searchable docs: base corpus + sealed overlay segments.
    pub searchable_docs: u64,
    /// Ingested docs still buffered (unsearchable until their seal).
    pub buffered_docs: u64,
    /// (source id, sealed overlay segment count), sources with at least
    /// one sealed segment only, ascending by source id.
    pub segments: Vec<(u32, usize)>,
    /// Cumulative seal / merge counts.
    pub seals: u64,
    pub merges: u64,
}

impl IndexHealth {
    /// JSON wire form (the `index` object of `/healthz`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::from(self.epoch)),
            ("searchable_docs", Json::from(self.searchable_docs)),
            ("buffered_docs", Json::from(self.buffered_docs)),
            (
                "segments",
                Json::Arr(
                    self.segments
                        .iter()
                        .map(|&(sid, n)| {
                            Json::Arr(vec![Json::from(sid as i64), Json::from(n)])
                        })
                        .collect(),
                ),
            ),
            ("seals", Json::from(self.seals)),
            ("merges", Json::from(self.merges)),
        ])
    }

    /// Parse the wire form produced by [`IndexHealth::to_json`].
    pub fn from_json(v: &Json) -> Option<IndexHealth> {
        Some(IndexHealth {
            epoch: v.get("epoch")?.as_i64()? as u64,
            searchable_docs: v.get("searchable_docs")?.as_i64()? as u64,
            buffered_docs: v.get("buffered_docs")?.as_i64()? as u64,
            segments: v
                .get("segments")?
                .as_arr()?
                .iter()
                .map(|p| {
                    let p = p.as_arr()?;
                    Some((p.first()?.as_i64()? as u32, p.get(1)?.as_i64()? as usize))
                })
                .collect::<Option<Vec<_>>>()?,
            seals: v.get("seals")?.as_i64()? as u64,
            merges: v.get("merges")?.as_i64()? as u64,
        })
    }
}

/// Compiled-plan cache: raw-request key ([`crate::search::request_plan_key`])
/// -> memoized [`CompiledRequest`]. A hit skips lex + parse + simplify +
/// matcher compilation and hands back the plan (with its normalized-AST
/// fingerprint) by clone. FIFO eviction — deterministic, and plans are
/// cheap enough that recency tracking isn't worth the bookkeeping. The
/// full request is stored next to each entry and compared on probe, so a
/// 64-bit key collision degrades to a miss, never a wrong plan. Parse
/// *errors* are not cached: they are rare, cheap to recompute, and an
/// error entry would evict a useful plan.
struct PlanCache {
    capacity: usize,
    map: HashMap<u64, (SearchRequest, CompiledRequest)>,
    order: VecDeque<u64>,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    fn new(capacity: usize) -> PlanCache {
        PlanCache {
            capacity,
            map: HashMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
        }
    }

    fn get(&mut self, key: u64, req: &SearchRequest) -> Option<CompiledRequest> {
        match self.map.get(&key) {
            Some((stored, compiled)) if stored == req => {
                self.hits += 1;
                Some(compiled.clone())
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    fn insert(&mut self, key: u64, req: SearchRequest, compiled: CompiledRequest) {
        if self.capacity == 0 {
            return;
        }
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            if let Some(oldest) = self.order.pop_front() {
                self.map.remove(&oldest);
            }
        }
        if self.map.insert(key, (req, compiled)).is_none() {
            self.order.push_back(key);
        }
    }
}

/// The deployed GAPS system.
pub struct GapsSystem {
    pub cfg: GapsConfig,
    dep: Arc<Deployment>,
    rm: ResourceManager,
    perf: PerfDb,
    qm: QueryManager,
    qee: QueryExecutionEngine,
    service: SearchService,
    executor: Option<Executor>,
    /// Per-node service containers (globus-container analogue). Owned by
    /// the system (not the shared deployment) so acquisition counters and
    /// residency ablations stay per-system.
    containers: BTreeMap<NodeId, crate::grid::ServiceContainer>,
    /// The broker the USI talks to (broker of the first active node's VO).
    root_broker: NodeId,
    /// Resident gridpool the batch fan-out runs on (`None` when the
    /// `search.workers` knob resolves to serial dispatch). Long-lived:
    /// workers — and their thread-local retrieval scratches / packers —
    /// survive across batches, so a multi-user serving workload pays the
    /// thread spawn and scratch warm-up once per deployment instead of
    /// once per batch.
    pool: Option<Pool>,
    /// Deterministic fault injection on the executor path (`None` in
    /// production; see [`crate::fault`]).
    injector: Option<Arc<FaultInjector>>,
    /// Failover/probation counters.
    fstats: FailoverStats,
    /// Live-ingestion overlays + epoch (see [`crate::storage`]).
    ingest: IngestState,
    /// Compiled-plan cache (`cache.*` knobs; see [`PlanCache`]).
    plan_cache: PlanCache,
}

impl std::fmt::Debug for GapsSystem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GapsSystem")
            .field("active_nodes", &self.dep.active.len())
            .field("sources", &self.dep.locator.len())
            .field("xla", &self.executor.is_some())
            .finish()
    }
}

impl GapsSystem {
    /// Deploy GAPS on `n_nodes` nodes (builds fabric + data).
    pub fn deploy(cfg: GapsConfig, n_nodes: usize) -> Result<GapsSystem, SearchError> {
        let dep = Arc::new(Deployment::build(&cfg, n_nodes)?);
        Self::from_deployment(cfg, dep)
    }

    /// Deploy over an existing (shared) deployment.
    pub fn from_deployment(
        cfg: GapsConfig,
        dep: Arc<Deployment>,
    ) -> Result<GapsSystem, SearchError> {
        let mut rm = ResourceManager::new(3);
        for &n in &dep.active {
            rm.register(dep.fabric.node(n).clone());
        }
        let executor = if cfg.search.use_xla {
            Some(
                Executor::new(std::path::Path::new(&cfg.search.artifact_dir))
                    .map_err(SearchError::executor)?,
            )
        } else {
            None
        };
        let root_broker = dep.fabric.vo_of(dep.active[0]).broker;
        let mut containers = BTreeMap::new();
        for &n in &dep.active {
            let mut c = crate::grid::ServiceContainer::new(
                n.to_string(),
                cfg.grid.resident_services,
                cfg.grid.cold_start_ms * 1e-3,
            );
            c.deploy("search-service");
            containers.insert(n, c);
        }
        // The resident gridpool is sized once from the workers knob; a
        // serial configuration (workers = 1) keeps dispatch on the
        // coordinator thread, which the figure sweeps rely on for clean
        // per-job wall-time measurement. The XLA path serializes through
        // the coordinator thread regardless (PJRT handles are !Send), so
        // an executor-backed system skips the pool entirely instead of
        // parking idle workers.
        let workers = cfg.search.effective_workers();
        let pool = (workers > 1 && executor.is_none()).then(|| Pool::new(workers));
        let dep_total_docs = dep.locator.total_docs();
        let plan_capacity = if cfg.cache.enabled { cfg.cache.plan_capacity } else { 0 };
        Ok(GapsSystem {
            service: SearchService::new(cfg.search.clone()),
            cfg,
            dep,
            rm,
            perf: PerfDb::default(),
            qm: QueryManager::new(),
            qee: QueryExecutionEngine,
            executor,
            containers,
            root_broker,
            pool,
            injector: None,
            fstats: FailoverStats::default(),
            // Base ids are contiguous from 0: ingestion continues where
            // the generator stopped.
            ingest: IngestState::new(dep_total_docs),
            plan_cache: PlanCache::new(plan_capacity),
        })
    }

    pub fn deployment(&self) -> &Deployment {
        &self.dep
    }

    /// A shareable handle to the deployment (what
    /// [`GapsSystem::from_deployment`] consumes). Executor shards clone
    /// this to stamp out cheap replica systems over the one corpus,
    /// fabric and index set — replicas fed identical ingest streams in
    /// identical order stay bit-identical ([`GapsSystem::ingest`] is
    /// deterministic), which is what keeps sharded serving
    /// indistinguishable from a single executor.
    pub fn deployment_handle(&self) -> Arc<Deployment> {
        Arc::clone(&self.dep)
    }

    pub fn perf_db(&self) -> &PerfDb {
        &self.perf
    }

    pub fn query_manager(&self) -> &QueryManager {
        &self.qm
    }

    /// Inject a node failure (resource dynamicity). The node stays Down
    /// until an explicit [`GapsSystem::recover_node`] or until its
    /// probation window (`grid.probe_after_ticks` batches) elapses and a
    /// health probe succeeds.
    pub fn fail_node(&mut self, node: NodeId) {
        self.rm.mark_down(node);
    }

    /// Heartbeat a node back into the grid.
    pub fn recover_node(&mut self, node: NodeId) {
        self.rm.heartbeat(node);
    }

    /// Arm deterministic fault injection: every subsequent batch consults
    /// the plan's schedule at the `run_job` fail-point and for probation
    /// health probes. Replayable — same plan, same requests, same
    /// behavior.
    pub fn set_fault_injector(&mut self, plan: ChaosPlan) {
        self.injector = Some(Arc::new(FaultInjector::new(plan)));
    }

    /// Cumulative fault-tolerance counters.
    pub fn failover_stats(&self) -> FailoverStats {
        self.fstats
    }

    // ---- Live ingestion + persistence ---------------------------------

    /// Corpus-global publication lookup across the base deployment and
    /// every ingestion overlay (sealed segments and still-buffered
    /// docs: a caller that just ingested can always resolve the ids it
    /// was handed, searchable or not).
    pub fn publication(&self, global_id: u64) -> Option<&Publication> {
        if let Some(p) = self.dep.publication(global_id) {
            return Some(p);
        }
        for ov in self.ingest.overlays.values() {
            // Ids ascend within a segment and within the buffer (they
            // are assigned sequentially at ingest), so binary search
            // applies per segment.
            for seg in &ov.sealed {
                if let Ok(i) = seg.pubs.binary_search_by_key(&global_id, |p| p.id) {
                    return Some(&seg.pubs[i]);
                }
            }
            if let Ok(i) = ov.buffer.binary_search_by_key(&global_id, |p| p.id) {
                return Some(&ov.buffer[i]);
            }
        }
        None
    }

    /// Ingest publications while serving. Each is assigned the next
    /// corpus-global id (any incoming id is overwritten) and routed to
    /// the least-loaded source's buffer; buffers seal into immutable,
    /// *searchable* overlay segments once they reach
    /// `storage.seal_docs`, and a source's sealed segments compact into
    /// one when `storage.merge_fanout` of them accumulate. Every seal
    /// and merge bumps the index epoch. Buffered docs are not
    /// searchable until their seal — [`GapsSystem::flush_ingest`]
    /// forces one.
    ///
    /// Ingestion is fully deterministic in the stream order: id
    /// assignment, least-loaded routing (ties to the smallest source
    /// id), seal points and merge points depend only on prior ingests.
    /// Replica systems built from one shared deployment and fed the
    /// same batches in the same order therefore produce identical
    /// overlays *and identical epochs* — the property the serve-layer
    /// shard router's lockstep ingest fan-out relies on.
    pub fn ingest(&mut self, pubs: Vec<Publication>) -> IngestReport {
        let accepted = pubs.len();
        let source_ids: Vec<u32> =
            self.dep.locator.sources().iter().map(|s| s.id).collect();
        for mut p in pubs {
            p.id = self.ingest.next_global_id;
            self.ingest.next_global_id += 1;
            // Least-loaded routing: fewest overlay docs (sealed +
            // buffered), ties to the smallest source id — deterministic,
            // so replayed ingest streams rebuild identical overlays.
            let target = source_ids
                .iter()
                .copied()
                .min_by_key(|sid| {
                    let docs = self.ingest.overlays.get(sid).map_or(0, |o| {
                        o.buffer.len() + o.sealed.iter().map(|s| s.len()).sum::<usize>()
                    });
                    (docs, *sid)
                })
                .expect("deployment has at least one source");
            self.ingest.overlays.entry(target).or_default().buffer.push(p);
        }
        let (sealed, merges) = self.roll_overlays(self.cfg.storage.seal_docs.max(1));
        IngestReport {
            accepted,
            buffered: self.buffered_docs() as usize,
            sealed,
            merges,
            epoch: self.ingest.epoch,
        }
    }

    /// Force-seal every non-empty ingest buffer regardless of
    /// `storage.seal_docs` (before a snapshot, or to make a small tail
    /// of ingested docs searchable immediately).
    pub fn flush_ingest(&mut self) -> IngestReport {
        let (sealed, merges) = self.roll_overlays(1);
        IngestReport {
            accepted: 0,
            buffered: self.buffered_docs() as usize,
            sealed,
            merges,
            epoch: self.ingest.epoch,
        }
    }

    /// Seal every buffer holding at least `threshold` docs, then run
    /// the per-source compaction policy. Returns (seals, merges).
    fn roll_overlays(&mut self, threshold: usize) -> (usize, usize) {
        let fanout = self.cfg.storage.merge_fanout;
        let features = self.cfg.search.features;
        let mut sealed = 0usize;
        let mut merges = 0usize;
        for (&sid, ov) in self.ingest.overlays.iter_mut() {
            if ov.buffer.len() >= threshold.max(1) {
                // Seal: analyze the buffer into an immutable segment.
                // From here on it is searchable and snapshot-persistable.
                let seg = Shard::build(sid, std::mem::take(&mut ov.buffer), features);
                ov.sealed.push(seg);
                self.ingest.epoch += 1;
                self.ingest.seals += 1;
                sealed += 1;
            }
            while fanout >= 2 && ov.sealed.len() >= fanout {
                // Compact the oldest `fanout` segments into one (doc ids
                // stay ascending: seals happen in id order per source,
                // and merge_shards concatenates without re-analyzing).
                let parts: Vec<Shard> = ov.sealed.drain(..fanout).collect();
                let merged = merge_shards(sid, parts);
                ov.sealed.insert(0, merged);
                self.ingest.epoch += 1;
                self.ingest.merges += 1;
                merges += 1;
            }
        }
        if sealed > 0 || merges > 0 {
            self.recompute_live_stats();
        }
        (sealed, merges)
    }

    /// Recompute the live global stats in canonical order — base shards
    /// ascending by source id, then overlay segments ascending by
    /// (source id, segment index). A snapshot-restored system folds the
    /// identical sequence, so restored scores are bit-identical.
    fn recompute_live_stats(&mut self) {
        let mut acc = ShardStats::empty(self.cfg.search.features);
        for shard in self.dep.data.shards.values() {
            acc.merge(&shard.stats);
        }
        let mut any = false;
        for ov in self.ingest.overlays.values() {
            for seg in &ov.sealed {
                acc.merge(&seg.stats);
                any = true;
            }
        }
        self.ingest.live_stats = any.then(|| acc.finalize());
    }

    fn buffered_docs(&self) -> u64 {
        self.ingest.overlays.values().map(|o| o.buffer.len() as u64).sum()
    }

    /// Current index epoch (bumped by every seal/merge; 0 = never
    /// ingested). `Explain` carries the same value per response.
    pub fn index_epoch(&self) -> u64 {
        self.ingest.epoch
    }

    /// Index-level health: epoch, searchable/buffered doc counts, and
    /// per-source overlay segment counts (`/healthz` reports this).
    pub fn index_health(&self) -> IndexHealth {
        let overlay_docs: u64 = self
            .ingest
            .overlays
            .values()
            .flat_map(|o| o.sealed.iter())
            .map(|s| s.len() as u64)
            .sum();
        IndexHealth {
            epoch: self.ingest.epoch,
            searchable_docs: self.dep.locator.total_docs() + overlay_docs,
            buffered_docs: self.buffered_docs(),
            segments: self
                .ingest
                .overlays
                .iter()
                .filter(|(_, o)| !o.sealed.is_empty())
                .map(|(&sid, o)| (sid, o.sealed.len()))
                .collect(),
            seals: self.ingest.seals,
            merges: self.ingest.merges,
        }
    }

    /// Persist the deployment into `dir`: one checksummed `.gsnap` per
    /// base source, one per sealed overlay segment, then the manifest
    /// (written last, so a directory with a readable manifest is
    /// complete). Buffered, unsealed docs are *not* captured — call
    /// [`GapsSystem::flush_ingest`] first to include them.
    pub fn write_snapshot(&self, dir: &Path) -> Result<SnapshotManifest, SearchError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| SearchError::Io { message: format!("{}: {e}", dir.display()) })?;
        let mut sources = Vec::new();
        for src in self.dep.locator.sources() {
            let shard = self
                .dep
                .shard(src.id)
                .ok_or(SearchError::SourceUnknown { source: src.id })?;
            let file = format!("shard_{:04}.gsnap", src.id);
            write_shard_snapshot(shard, &dir.join(&file))?;
            sources.push(ManifestSource {
                id: src.id,
                doc_start: src.doc_start,
                doc_count: src.doc_count,
                file,
            });
        }
        let mut overlays = Vec::new();
        for (&sid, ov) in &self.ingest.overlays {
            for (k, seg) in ov.sealed.iter().enumerate() {
                let file = format!("overlay_{sid:04}_{k:04}.gsnap");
                write_shard_snapshot(seg, &dir.join(&file))?;
                overlays.push(ManifestOverlay { source: sid, file });
            }
        }
        let manifest = SnapshotManifest {
            features: self.cfg.search.features,
            epoch: self.ingest.epoch,
            num_docs: self.dep.locator.total_docs(),
            next_global_id: self.ingest.next_global_id,
            sources,
            overlays,
        };
        manifest.write(dir)?;
        Ok(manifest)
    }

    /// Boot a system from a snapshot directory instead of generating
    /// and re-analyzing the corpus: read the manifest, load every base
    /// source and overlay segment (bounds-checked, checksummed,
    /// invariant-validated), and place them on `n_nodes` exactly as
    /// [`Deployment::assemble`] would. Retrieval is bit-identical to
    /// the system the snapshot was taken from
    /// (`tests/integration_persistence.rs`).
    pub fn deploy_from_snapshot(
        cfg: GapsConfig,
        n_nodes: usize,
        dir: &Path,
    ) -> Result<GapsSystem, SearchError> {
        let manifest = SnapshotManifest::read(dir)?;
        if manifest.features != cfg.search.features {
            return Err(SearchError::config(format!(
                "snapshot analyzed with F={}, config wants F={}",
                manifest.features, cfg.search.features
            )));
        }
        let mut shards = BTreeMap::new();
        let mut ranges = Vec::with_capacity(manifest.sources.len());
        let mut base_docs = 0u64;
        for (i, src) in manifest.sources.iter().enumerate() {
            if src.id as usize != i {
                return Err(SearchError::config(format!(
                    "manifest sources must be contiguous by id: slot {i} holds id {}",
                    src.id
                )));
            }
            let shard = read_shard_snapshot(&dir.join(&src.file))?;
            if shard.len() as u64 != src.doc_count {
                return Err(SearchError::config(format!(
                    "source {} holds {} docs, manifest promises {}",
                    src.id,
                    shard.len(),
                    src.doc_count
                )));
            }
            base_docs += src.doc_count;
            shards.insert(src.id, shard);
            ranges.push((src.doc_start, src.doc_count));
        }
        if base_docs != manifest.num_docs {
            return Err(SearchError::config(format!(
                "manifest num_docs {} != sum of source doc_counts {base_docs}",
                manifest.num_docs
            )));
        }
        // The generator is rebuilt from the config spec: it only drives
        // query sampling / REPL lookups, never the restored shards.
        let spec = CorpusSpec {
            seed: cfg.workload.seed,
            num_docs: cfg.workload.num_docs,
            ..CorpusSpec::default()
        };
        let data = Arc::new(CorpusData {
            shards,
            ranges,
            generator: CorpusGenerator::new(spec),
            features: manifest.features,
        });
        let dep = Arc::new(Deployment::assemble(&cfg, n_nodes, data)?);
        let mut sys = GapsSystem::from_deployment(cfg, dep)?;
        for ov in &manifest.overlays {
            if sys.dep.locator.source(ov.source).is_none() {
                return Err(SearchError::config(format!(
                    "manifest overlay references unknown source {}",
                    ov.source
                )));
            }
            let seg = read_shard_snapshot(&dir.join(&ov.file))?;
            sys.ingest.overlays.entry(ov.source).or_default().sealed.push(seg);
        }
        sys.recompute_live_stats();
        sys.ingest.epoch = manifest.epoch;
        sys.ingest.next_global_id = manifest.next_global_id.max(sys.ingest.next_global_id);
        Ok(sys)
    }

    /// Probe downed nodes whose probation window elapsed; healthy ones
    /// rejoin the grid (runs once per batch, before planning).
    fn probe_downed(&mut self) {
        for node in self.rm.probe_due(self.cfg.grid.probe_after_ticks) {
            self.fstats.probes += 1;
            let healthy =
                self.injector.as_deref().map(|i| i.probe_healthy(node)).unwrap_or(true);
            self.rm.record_probe(node, healthy);
            if healthy {
                self.fstats.recoveries += 1;
            }
        }
    }

    /// Execute one raw query string with default request knobs.
    pub fn search(&mut self, raw: &str) -> Result<SearchResponse, SearchError> {
        self.search_request(&SearchRequest::new(raw))
    }

    /// Compile one request against this deployment, through the
    /// compiled-plan cache: a repeat of a previously compiled request
    /// skips lex + parse + simplify + matcher compilation and returns
    /// the memoized plan (carrying the normalized-AST `fingerprint` the
    /// result cache keys on). Public so the serving layer can compile
    /// first, probe its result cache, and execute only the misses —
    /// the miss path re-enters [`GapsSystem::search_batch`], whose own
    /// compile loop then hits this same cache, so a cold request is
    /// compiled exactly once.
    pub fn compile_request(
        &mut self,
        request: &SearchRequest,
    ) -> Result<CompiledRequest, SearchError> {
        let features = self.cfg.search.features;
        let default_top_k = self.cfg.search.top_k;
        if !self.cfg.cache.enabled || self.cfg.cache.plan_capacity == 0 {
            return request.compile(features, default_top_k);
        }
        let key = crate::search::request_plan_key(request, features, default_top_k);
        if let Some(compiled) = self.plan_cache.get(key, request) {
            return Ok(compiled);
        }
        let compiled = request.compile(features, default_top_k)?;
        self.plan_cache.insert(key, request.clone(), compiled.clone());
        Ok(compiled)
    }

    /// Plan-cache effectiveness counters since deployment: `(hits,
    /// misses)`. Surfaced through the serving layer's `/healthz`.
    pub fn plan_cache_stats(&self) -> (u64, u64) {
        (self.plan_cache.hits, self.plan_cache.misses)
    }

    /// Execute one typed request end to end.
    pub fn search_request(
        &mut self,
        request: &SearchRequest,
    ) -> Result<SearchResponse, SearchError> {
        self.search_batch(std::slice::from_ref(request))
            .pop()
            .expect("one result per request")
    }

    /// Execute a request batch: plan once, dispatch one JDF per node
    /// carrying every query, fan out once over the resident gridpool,
    /// and feed Q>1 rows through the scoring path. Results come back in
    /// request order; per-request failures (e.g. parse errors) do not
    /// fail the rest of the batch. Compilation goes through the
    /// compiled-plan cache (see [`GapsSystem::compile_request`]), so hot
    /// queries skip parse + plan on repeats.
    ///
    /// Requests with different [`ReplicaPref`]s, `allow_partial` modes,
    /// or deadlines cannot share an execution plan; they are planned and
    /// fanned out per group (a homogeneous batch — the common case — is
    /// exactly one plan + one fan-out round).
    ///
    /// **Fault tolerance:** a per-node job that fails mid-flight marks
    /// its node Down and the affected sources are re-planned onto
    /// surviving replicas (`search.failover_retries` rounds). Because
    /// the node → VO → root merges are placement-invariant, a failover
    /// round returns hits bit-identical to the fault-free run whenever
    /// live replicas still cover every source. Requests with
    /// `allow_partial` degrade gracefully (top-k over reachable sources,
    /// `degraded: true`) when coverage is impossible; others fail with a
    /// typed availability error. Downed nodes re-enter through probation
    /// (see [`crate::coordinator::ResourceManager`]).
    ///
    /// ```
    /// use gaps::config::GapsConfig;
    /// use gaps::coordinator::GapsSystem;
    /// use gaps::search::SearchRequest;
    ///
    /// let mut cfg = GapsConfig::default();
    /// cfg.workload.num_docs = 400;
    /// cfg.workload.sub_shards = 4;
    /// cfg.search.use_xla = false;
    /// let mut sys = GapsSystem::deploy(cfg, 2)?;
    /// let results = sys.search_batch(&[
    ///     SearchRequest::new("grid computing"),
    ///     SearchRequest::new("data retrieval").top_k(3),
    /// ]);
    /// assert_eq!(results.len(), 2); // one result per request, in order
    /// for r in results {
    ///     assert!(r?.jobs >= 1);
    /// }
    /// # Ok::<(), gaps::search::SearchError>(())
    /// ```
    pub fn search_batch(
        &mut self,
        requests: &[SearchRequest],
    ) -> Vec<Result<SearchResponse, SearchError>> {
        let started = Instant::now();
        let mut results: Vec<Option<Result<SearchResponse, SearchError>>> =
            (0..requests.len()).map(|_| None).collect();

        // Compile every request; parse failures settle immediately. The
        // compile time is measured and folded into each group's timeline
        // (the seed accounted parse time inside `search()`, and the
        // traditional baseline still does — the figures must compare
        // symmetric accountings).
        let compile_clock = WallClock::start();
        let mut compiled: Vec<Option<CompiledRequest>> = Vec::with_capacity(requests.len());
        for (i, req) in requests.iter().enumerate() {
            // Through the plan cache: hot queries skip parse + plan.
            match self.compile_request(req) {
                Ok(c) => compiled.push(Some(c)),
                Err(e) => {
                    results[i] = Some(Err(e));
                    compiled.push(None);
                }
            }
        }
        let compile_s = compile_clock.elapsed_s();
        let valid_total = compiled.iter().filter(|c| c.is_some()).count().max(1);

        // One grid round per batch: Up nodes heartbeat, stale nodes
        // expire, and downed nodes whose probation window elapsed get
        // health-probed back into the available set.
        self.rm.begin_round();
        self.probe_downed();

        // Group by (replica preference, degradation mode, deadline):
        // requests in a group share one plan and one failover policy
        // (usually the whole batch is one group).
        let mut groups: BTreeMap<(ReplicaPref, bool, Option<u64>), Vec<usize>> = BTreeMap::new();
        for (i, c) in compiled.iter().enumerate() {
            if let Some(c) = c {
                groups.entry((c.replicas, c.allow_partial, c.deadline_ms)).or_default().push(i);
            }
        }

        for ((pref, _, _), indices) in groups {
            let group_requests: Arc<Vec<SearchRequest>> =
                Arc::new(indices.iter().map(|&i| requests[i].clone()).collect());
            let group_compiled: Vec<&CompiledRequest> =
                indices.iter().map(|&i| compiled[i].as_ref().expect("compiled")).collect();
            // This group's proportional share of the batch compile time.
            let compile_share = compile_s * indices.len() as f64 / valid_total as f64;
            match self.run_group(pref, &group_requests, &group_compiled, compile_share, started) {
                Ok(responses) => {
                    for (slot, resp) in indices.iter().zip(responses) {
                        results[*slot] = Some(Ok(resp));
                    }
                }
                Err(e) => {
                    for slot in &indices {
                        results[*slot] = Some(Err(e.clone()));
                    }
                }
            }
        }

        results.into_iter().map(|r| r.expect("every request settled")).collect()
    }

    /// Plan + dispatch + execute + merge one request group, with
    /// mid-flight failover. This is the paper's GAPS flow, generalized
    /// to Q >= 1 queries and to a grid where nodes can crash under us: a
    /// failed per-node job marks its node Down and only that job's
    /// sources are re-planned onto surviving replicas in the next
    /// attempt; completed jobs are never re-run. Because every merge
    /// level is placement-invariant, the final top-k is bit-identical to
    /// a fault-free run whenever live replicas still cover every source.
    fn run_group(
        &mut self,
        pref: ReplicaPref,
        requests: &Arc<Vec<SearchRequest>>,
        compiled: &[&CompiledRequest],
        compile_s: f64,
        started: Instant,
    ) -> Result<Vec<SearchResponse>, SearchError> {
        let nq = compiled.len();
        // Trace clock for this group's round: everything after compile
        // (plan, fan-out, merges) happens inside this window.
        let group_clock = WallClock::start();
        // Group invariants (the batch grouping keys on these).
        let allow_partial = compiled[0].allow_partial;
        let deadline = compiled[0].deadline_ms;
        let queries: Vec<(&Query, usize)> =
            compiled.iter().map(|c| (&c.query, c.top_k)).collect();
        let home_vo = self.dep.fabric.node(self.root_broker).vo;
        let faults = self.injector.clone();

        // Sources still awaiting a successful job: drained by completed
        // jobs, refilled by failed ones, abandoned into `missing` when no
        // live replica can host them.
        let mut pending: Vec<u32> =
            self.dep.locator.sources().iter().map(|s| s.id).collect();
        let mut missing: Vec<u32> = Vec::new();
        // Completed jobs across all attempts: (vo, job, startup_s, output).
        let mut done: Vec<(u32, JobDescription, f64, JobOutput)> = Vec::new();
        let mut last_err: Option<SearchError> = None;
        let mut plan_s = 0.0f64;
        // Wall time spent inside the fan-out rounds (all attempts) and
        // inside the VO/root merges — the `execute` and `merge` stage
        // spans of the request trace.
        let mut execute_s = 0.0f64;
        let mut merge_s = 0.0f64;
        let mut job_spans: Vec<TraceSpan> = Vec::new();
        // Simulated backoff between failover attempts (accounted on the
        // root timeline, not slept).
        let mut retry_backoff_s = 0.0f64;

        for attempt in 0..=self.cfg.search.failover_retries {
            if pending.is_empty() {
                break;
            }
            if let Some(ms) = deadline {
                if started.elapsed() >= Duration::from_millis(ms) {
                    return Err(SearchError::DeadlineExceeded { deadline_ms: ms });
                }
            }
            if attempt > 0 {
                self.fstats.replans += 1;
                retry_backoff_s += self.cfg.search.retry_backoff_ms * 1e-3 * attempt as f64;
            }

            // Plan: resources + the still-pending sources -> node
            // assignments (QEE). Sources with no live replica drop out of
            // the attempt loop here.
            let available = self.rm.available();
            if available.is_empty() {
                if attempt == 0 {
                    return Err(SearchError::NoNodes);
                }
                missing.append(&mut pending);
                break;
            }
            let plan_clock = WallClock::start();
            let all_sources = self.dep.locator.sources();
            let sources: Vec<_> =
                all_sources.into_iter().filter(|s| pending.contains(&s.id)).collect();
            let (plan, uncovered) = self.qee.plan_partial(
                &sources,
                &available,
                &self.perf,
                self.cfg.search.policy,
                pref,
                Some(home_vo),
            )?;
            if !uncovered.is_empty() {
                pending.retain(|s| !uncovered.contains(s));
                missing.extend(uncovered);
            }
            if plan.assignments.is_empty() {
                continue;
            }

            // QM materializes the JDFs (reply-to = each node's VO broker),
            // every JDF carrying the whole request batch.
            let fabric = &self.dep.fabric;
            let jobs = self.qm.create_jobs(requests, &plan, |n| fabric.vo_of(n).broker);
            plan_s += plan_clock.elapsed_s();

            // ---- Dispatch bookkeeping (serial: QM + containers) -------
            // One container acquisition + dispatch slot per *job*, not
            // per query: the batch amortizes startup accounting. Flatten
            // jobs in (vo, j_idx) order; the fan-out below returns
            // outcomes in the same order, keeping merges deterministic.
            let mut attempt_by_vo: BTreeMap<u32, Vec<JobDescription>> = BTreeMap::new();
            for j in jobs {
                attempt_by_vo.entry(self.dep.fabric.node(j.node).vo.0).or_default().push(j);
            }
            let mut flat: Vec<(u32, JobDescription)> = Vec::new();
            let mut startups: Vec<f64> = Vec::new();
            for (vo, vo_jobs) in attempt_by_vo {
                for job in vo_jobs {
                    self.qm.mark_dispatched(job.id);
                    let handle = self
                        .containers
                        .get_mut(&job.node)
                        .ok_or_else(|| SearchError::internal("node has no container"))?
                        .acquire("search-service")
                        .ok_or_else(|| SearchError::internal("search-service not deployed"))?;
                    startups.push(handle.startup_s);
                    flat.push((vo, job));
                }
            }

            // ---- Execute every node's job (parallel shard fan-out) ----
            // Real concurrent work on the *resident* gridpool, one round
            // per attempt: jobs are scope-submitted to the long-lived
            // workers (`Pool::scope_map`), so no threads are spawned per
            // batch and worker thread-locals (retrieval scratches,
            // packers) stay warm from batch to batch. Per-job wall time
            // is measured inside each job; under contention that
            // measurement inflates, so the figure sweeps pin workers = 1
            // (see metrics::run_node_sweep, which leaves `pool` unbuilt)
            // while serving paths default to all cores. A job failure
            // does NOT abort the round: surviving nodes' outputs are kept
            // and only the failed job's sources re-enter `pending`.
            // Sealed ingestion overlays and the stats they score under:
            // `live_stats` is `None` until the first seal, so a
            // never-ingested system scores against exactly the
            // deployment's own stats (bit-identical to pre-ingestion
            // behavior).
            let stats: &GlobalStats =
                self.ingest.live_stats.as_ref().unwrap_or(&self.dep.stats);
            let overlays = &self.ingest.overlays;
            let fanout_clock = WallClock::start();
            let outcomes: Vec<Result<JobOutput, SearchError>> =
                match (self.executor.as_mut(), self.pool.as_ref()) {
                    (Some(exec), _) => {
                        // PJRT handles are !Send: artifact execution stays
                        // on the coordinator thread (see runtime::mod docs).
                        let mut outs = Vec::with_capacity(flat.len());
                        for (_, job) in &flat {
                            let mut scorer = Scorer::Xla(&mut *exec);
                            outs.push(run_job(
                                &self.service,
                                &self.dep,
                                stats,
                                overlays,
                                &queries,
                                job,
                                &mut scorer,
                                faults.as_deref(),
                            ));
                        }
                        outs
                    }
                    (None, Some(pool)) if flat.len() > 1 => {
                        let service = &self.service;
                        let dep: &Deployment = &self.dep;
                        let qs = &queries;
                        let inj = faults.as_deref();
                        pool.scope_map(&flat, |(_, job)| {
                            run_job(service, dep, stats, overlays, qs, job, &mut Scorer::Rust, inj)
                        })
                    }
                    _ => {
                        let mut outs = Vec::with_capacity(flat.len());
                        for (_, job) in &flat {
                            outs.push(run_job(
                                &self.service,
                                &self.dep,
                                stats,
                                overlays,
                                &queries,
                                job,
                                &mut Scorer::Rust,
                                faults.as_deref(),
                            ));
                        }
                        outs
                    }
                };
            execute_s += fanout_clock.elapsed_s();

            // ---- Triage outcomes: keep successes, refill `pending` ----
            let mut retry: Vec<u32> = Vec::new();
            for (((vo, job), startup_s), outcome) in
                flat.into_iter().zip(startups).zip(outcomes)
            {
                match outcome {
                    Ok(out) => {
                        // One `job` child span per completed per-node
                        // job, carrying its aggregated retrieval
                        // counters across the batch.
                        let mut agg = RetrievalCounters::default();
                        for c in &out.per_query_counters {
                            agg.merge(c);
                        }
                        job_spans.push(
                            TraceSpan::new("job", out.wall_s)
                                .with_meta("node", job.node.to_string())
                                .with_meta("sources", job.sources.len().to_string())
                                .with_meta("postings_touched", agg.postings_touched.to_string())
                                .with_meta("blocks_skipped", agg.blocks_skipped.to_string())
                                .with_meta("candidates", agg.candidates_emitted.to_string()),
                        );
                        done.push((vo, job, startup_s, out));
                    }
                    Err(e) => {
                        self.fstats.jobs_failed += 1;
                        self.fstats.nodes_marked_down += 1;
                        self.qm.fail(job.id);
                        self.rm.mark_down(job.node);
                        retry.extend(job.sources.iter().copied());
                        last_err = Some(e);
                    }
                }
            }
            retry.sort_unstable();
            pending = retry;
        }

        // Coverage verdict: strict requests fail loudly, partial requests
        // degrade truthfully.
        if !allow_partial {
            if let Some(&source) = missing.first() {
                return Err(SearchError::NoLiveReplica { source });
            }
            if !pending.is_empty() {
                return Err(last_err
                    .unwrap_or_else(|| SearchError::unavailable("failover retries exhausted")));
            }
        } else {
            missing.append(&mut pending);
        }
        missing.sort_unstable();
        missing.dedup();
        let degraded = !missing.is_empty();
        if degraded {
            self.fstats.degraded_responses += nq as u64;
        }

        // ---- Assemble per-VO timelines from the completed jobs --------
        // Jobs regroup by VO across attempts (a failover re-run lands in
        // its node's VO like any other job). JDF wire sizes are
        // serialized once per job (the JSON rendering covers the whole
        // request batch, so re-serializing at every accounting site would
        // cost O(jobs x batch) twice over).
        let mut by_vo: BTreeMap<u32, Vec<(JobDescription, f64, JobOutput)>> = BTreeMap::new();
        for (vo, job, startup_s, out) in done {
            by_vo.entry(vo).or_default().push((job, startup_s, out));
        }
        let wire_of: BTreeMap<super::jdf::JobId, usize> =
            by_vo.values().flatten().map(|(j, _, _)| (j.id, j.wire_bytes())).collect();
        let jobs_done: usize = by_vo.values().map(|v| v.len()).sum();
        let plan_view: Vec<(String, usize)> = by_vo
            .values()
            .flatten()
            .map(|(j, _, _)| (j.node.to_string(), j.sources.len()))
            .collect();

        let dispatch_s = self.cfg.grid.dispatch_ms * 1e-3;
        let net = &self.dep.fabric.net;
        let root_info = self.dep.fabric.node(self.root_broker).clone();
        let mut vo_timelines: Vec<TaskTimeline> = Vec::new();
        // [query][vo] -> merged VO list.
        let mut vo_lists: Vec<Vec<Vec<LocalHit>>> = vec![Vec::new(); nq];
        let mut total_candidates = vec![0usize; nq];
        let mut total_counters = vec![RetrievalCounters::default(); nq];
        let mut total_docs = 0u64;
        let mut completions: Vec<(super::jdf::JobId, u64, f64)> = Vec::new();

        for (vo_idx, (vo, vo_jobs)) in by_vo.into_iter().enumerate() {
            let vo_broker = self.dep.fabric.vos[vo as usize].broker;
            let vo_broker_info = self.dep.fabric.node(vo_broker).clone();
            // Root QEE hands this VO's QEE its slice (serial at root).
            let jdf_bytes: usize = vo_jobs.iter().map(|(j, _, _)| wire_of[&j.id]).sum();
            let mut vo_tl = TaskTimeline {
                work_s: 0.0,
                net_s: net.transfer_between_s(&root_info, &vo_broker_info, jdf_bytes),
                overhead_s: (vo_idx + 1) as f64 * dispatch_s,
            };

            // VO broker dispatches its jobs serially; nodes run in parallel.
            let mut node_branches: Vec<TaskTimeline> = Vec::new();
            // [query][node] -> node list.
            let mut node_lists: Vec<Vec<Vec<LocalHit>>> = vec![Vec::new(); nq];
            for (j_idx, (job, startup_s, out)) in vo_jobs.into_iter().enumerate() {
                let node_info = self.dep.fabric.node(job.node).clone();
                total_docs += out.docs;
                let reply_hits: usize = out.per_query_hits.iter().map(|h| h.len()).sum();
                let work_acc = out.work_measured / node_info.speed_factor;
                // Perf history: docs are scanned once per query in the
                // batch, so throughput accounting scales by nq.
                completions.push((job.id, out.docs * nq as u64, work_acc));

                let branch = TaskTimeline {
                    work_s: work_acc,
                    net_s: net.transfer_between_s(&vo_broker_info, &node_info, wire_of[&job.id])
                        + net.transfer_between_s(
                            &node_info,
                            &vo_broker_info,
                            result_wire_bytes(reply_hits),
                        ),
                    overhead_s: (j_idx + 1) as f64 * dispatch_s + startup_s,
                };
                node_branches.push(branch);
                for (qi, hits) in out.per_query_hits.into_iter().enumerate() {
                    total_candidates[qi] += out.per_query_candidates[qi];
                    total_counters[qi].merge(&out.per_query_counters[qi]);
                    node_lists[qi].push(hits);
                }
            }

            // Barrier at the VO broker: slowest member dominates.
            let slowest = node_branches
                .into_iter()
                .fold(TaskTimeline::default(), |acc, b| acc.max(b));
            vo_tl.add(slowest);

            // VO-level merge (measured, all queries) + WAN reply to root.
            let merge_clock = WallClock::start();
            let mut reply_hits = 0usize;
            for (qi, lists) in node_lists.into_iter().enumerate() {
                let merged = merge_topk(&lists, compiled[qi].top_k);
                reply_hits += merged.len();
                vo_lists[qi].push(merged);
            }
            let vo_merge_s = merge_clock.elapsed_s();
            merge_s += vo_merge_s;
            vo_tl.work_s += vo_merge_s;
            vo_tl.net_s +=
                net.transfer_between_s(&vo_broker_info, &root_info, result_wire_bytes(reply_hits));
            vo_timelines.push(vo_tl);
        }

        // Record completions (QM -> perf DB).
        for (id, docs, work_s) in completions {
            self.qm.complete(id, docs, work_s, &mut self.perf);
        }

        // Root barrier + final merge (shared batch critical path). The
        // USI-side compile share counts as root work, like plan time;
        // failover backoff shows up as root overhead (zero on the
        // fault-free path, so timelines match run for run).
        let mut timeline = TaskTimeline {
            work_s: compile_s + plan_s,
            net_s: 0.0,
            overhead_s: retry_backoff_s,
        };
        let slowest_vo = vo_timelines
            .into_iter()
            .fold(TaskTimeline::default(), |acc, b| acc.max(b));
        timeline.add(slowest_vo);
        let merge_clock = WallClock::start();
        let merged_per_query: Vec<Vec<LocalHit>> = vo_lists
            .into_iter()
            .enumerate()
            .map(|(qi, lists)| merge_topk(&lists, compiled[qi].top_k))
            .collect();
        let root_merge_s = merge_clock.elapsed_s();
        merge_s += root_merge_s;
        timeline.work_s += root_merge_s;

        // ---- Request trace: stage spans for this group's round --------
        // The root `search` span covers compile (measured upstream in
        // `search_batch`, attributed proportionally) plus everything the
        // group clock saw. Sequential children (compile, plan, execute,
        // merge) occupy disjoint windows, so they each fit under the
        // root and sum to at most its duration; `job` children of
        // `execute` ran in parallel, so each fits the window but their
        // sum may exceed it (see `obs::trace` docs).
        let mut execute_span = TraceSpan::new("execute", execute_s);
        for js in job_spans {
            execute_span.push_child(js);
        }
        let mut search_span = TraceSpan::new("search", compile_s + group_clock.elapsed_s())
            .with_meta("batch_size", nq.to_string())
            .with_meta("jobs", jobs_done.to_string())
            .with_meta("epoch", self.ingest.epoch.to_string());
        search_span.push_child(TraceSpan::new("compile", compile_s));
        search_span.push_child(TraceSpan::new("plan", plan_s));
        search_span.push_child(execute_span);
        search_span.push_child(TraceSpan::new("merge", merge_s));

        // ---- Materialize responses ------------------------------------
        let docs_per_query = total_docs; // every query scans every job's sources
        let mut responses = Vec::with_capacity(nq);
        for (qi, merged) in merged_per_query.into_iter().enumerate() {
            let hits = merged
                .into_iter()
                .map(|h| Hit {
                    global_id: h.global_id,
                    score: h.score,
                    // Overlay-aware lookup: a hit may come from a sealed
                    // ingestion segment the base deployment knows nothing
                    // about.
                    title: self
                        .publication(h.global_id)
                        .map(|p| p.title.clone())
                        .unwrap_or_default(),
                })
                .collect();
            let explain = compiled[qi].explain.then(|| Explain {
                ast: compiled[qi].query.ast.to_string(),
                keywords: compiled[qi].query.keywords.clone(),
                batch_size: nq,
                plan: plan_view.clone(),
                counters: total_counters[qi],
                epoch: self.ingest.epoch,
                stages: Some(search_span.clone()),
            });
            responses.push(SearchResponse {
                query: requests[qi].query.clone(),
                hits,
                timeline: timeline.clone(),
                jobs: jobs_done,
                candidates: total_candidates[qi],
                docs_scanned: docs_per_query,
                degraded,
                missing_sources: missing.clone(),
                explain,
                trace: Some(search_span.clone()),
            });
        }
        Ok(responses)
    }

    /// Service acquisitions on a node (container metrics).
    pub fn service_acquisitions(&self, node: NodeId) -> u64 {
        self.containers
            .get(&node)
            .map(|c| c.acquisitions("search-service"))
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GapsConfig, SchedulePolicy};
    use crate::search::Field;

    fn small_cfg() -> GapsConfig {
        let mut cfg = GapsConfig::default();
        cfg.workload.num_docs = 600;
        cfg.workload.sub_shards = 8;
        cfg.search.use_xla = false; // unit tests stay artifact-free
        cfg
    }

    #[test]
    fn deployment_covers_corpus_exactly() {
        let dep = Deployment::build(&small_cfg(), 4).unwrap();
        assert_eq!(dep.locator.total_docs(), 600);
        assert_eq!(dep.locator.len(), 8);
        assert_eq!(dep.active.len(), 4);
        // Every source's shard holds its declared docs.
        for src in dep.locator.sources() {
            let shard = dep.shard(src.id).unwrap();
            assert_eq!(shard.len() as u64, src.doc_count);
            assert_eq!(shard.docs[0].global_id, src.doc_start);
        }
    }

    #[test]
    fn replicas_stay_within_vo_when_possible() {
        // 6 nodes over 3 VOs = 2 per VO: every source can replicate in-VO.
        let dep = Deployment::build(&small_cfg(), 6).unwrap();
        for src in dep.locator.sources() {
            assert_eq!(src.replicas.len(), 2);
            let vos: std::collections::HashSet<u32> =
                src.replicas.iter().map(|&n| dep.fabric.node(n).vo.0).collect();
            assert_eq!(vos.len(), 1, "replicas of {} span VOs", src.id);
        }
    }

    #[test]
    fn lone_vo_member_replicates_cross_vo() {
        // 3 nodes = 1 per VO: secondary must fall back to another VO.
        let dep = Deployment::build(&small_cfg(), 3).unwrap();
        for src in dep.locator.sources() {
            assert_eq!(src.replicas.len(), 2, "source {} lacks a replica", src.id);
        }
    }

    #[test]
    fn publication_lookup_roundtrips() {
        let dep = Deployment::build(&small_cfg(), 3).unwrap();
        // Exhaustive: the binary search must agree with identity on every
        // id, including both ends of every source range.
        for id in 0u64..600 {
            let p = dep.publication(id).unwrap();
            assert_eq!(p.id, id);
        }
        assert!(dep.publication(600).is_none());
        assert!(dep.publication(u64::MAX).is_none());
    }

    #[test]
    fn search_returns_relevant_hits() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        // Query with the exact title of doc 42: it must be found.
        let title = sys.deployment().publication(42).unwrap().title.clone();
        let resp = sys.search(&title).unwrap();
        assert!(resp.jobs >= 1);
        assert!(resp.response_s() > 0.0);
        assert!(
            resp.hits.iter().any(|h| h.global_id == 42),
            "doc 42 not in {:?}",
            resp.hits.iter().map(|h| h.global_id).collect::<Vec<_>>()
        );
        assert!(resp.timeline.work_s > 0.0);
        assert!(resp.timeline.net_s > 0.0);
        assert!(resp.timeline.overhead_s > 0.0);
        assert!(resp.explain.is_none());
    }

    #[test]
    fn typed_request_controls_top_k_and_explain() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let req = SearchRequest::new("grid data search").top_k(3).explain(true);
        let resp = sys.search_request(&req).unwrap();
        assert!(resp.hits.len() <= 3);
        let explain = resp.explain.expect("explain requested");
        assert_eq!(explain.batch_size, 1);
        assert!(!explain.plan.is_empty());
        assert!(explain.keywords.contains(&"grid".to_string()));
    }

    #[test]
    fn plan_cache_hits_on_repeats_without_changing_results() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let cold = sys.search("grid computing publications").unwrap();
        let (h0, m0) = sys.plan_cache_stats();
        assert_eq!(h0, 0);
        assert!(m0 >= 1, "cold compile must be a recorded miss");
        let warm = sys.search("grid computing publications").unwrap();
        let (h1, _) = sys.plan_cache_stats();
        assert!(h1 >= 1, "repeat compile must hit the plan cache");
        // A plan-cache hit is invisible in the results: same hits, same
        // score bits.
        assert_eq!(cold.hits.len(), warm.hits.len());
        for (a, b) in cold.hits.iter().zip(warm.hits.iter()) {
            assert_eq!(a.global_id, b.global_id);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
    }

    #[test]
    fn plan_cache_respects_the_off_switch() {
        let mut cfg = small_cfg();
        cfg.cache.enabled = false;
        let mut sys = GapsSystem::deploy(cfg, 4).unwrap();
        sys.search("grid computing").unwrap();
        sys.search("grid computing").unwrap();
        assert_eq!(sys.plan_cache_stats(), (0, 0), "disabled cache must never be consulted");
    }

    #[test]
    fn plan_cache_distinguishes_request_knobs() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let a = sys.search_request(&SearchRequest::new("grid").top_k(3)).unwrap();
        let b = sys.search_request(&SearchRequest::new("grid").top_k(7)).unwrap();
        assert!(a.hits.len() <= 3);
        assert!(b.hits.len() <= 7);
        let (h, _) = sys.plan_cache_stats();
        assert_eq!(h, 0, "different knobs must not share a plan entry");
    }

    #[test]
    fn plan_cache_evicts_fifo_at_capacity() {
        let mut cfg = small_cfg();
        cfg.cache.plan_capacity = 2;
        let mut sys = GapsSystem::deploy(cfg, 4).unwrap();
        sys.search("grid").unwrap();
        sys.search("comput").unwrap();
        sys.search("publication").unwrap(); // evicts "grid"
        sys.search("grid").unwrap(); // miss again
        let (h, m) = sys.plan_cache_stats();
        assert_eq!(h, 0);
        assert_eq!(m, 4);
    }

    #[test]
    fn builder_year_filter_is_hard() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let year = sys.deployment().publication(10).unwrap().year;
        let req = SearchRequest::new("").year(year..=year).top_k(50);
        let resp = sys.search_request(&req).unwrap();
        assert!(!resp.hits.is_empty());
        for h in &resp.hits {
            assert_eq!(sys.deployment().publication(h.global_id).unwrap().year, year);
        }
    }

    #[test]
    fn require_field_builder_constrains_hits() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let title_word = sys
            .deployment()
            .publication(25)
            .unwrap()
            .title
            .split_whitespace()
            .find(|w| !crate::text::terms(w).is_empty())
            .unwrap()
            .to_string();
        let req = SearchRequest::new("grid data").require(Field::Title, title_word.clone());
        match sys.search_request(&req) {
            Ok(resp) => {
                let stemmed = crate::text::terms(&title_word);
                let bucket =
                    crate::text::term_feature(&stemmed[0], sys.cfg.search.features) as u32;
                for h in &resp.hits {
                    let dep = sys.deployment();
                    let src = dep
                        .locator
                        .sources()
                        .into_iter()
                        .find(|s| (s.doc_start..s.doc_start + s.doc_count).contains(&h.global_id))
                        .unwrap()
                        .id;
                    let shard = dep.shard(src).unwrap();
                    let lid = (h.global_id - dep.locator.source(src).unwrap().doc_start) as usize;
                    let has = shard.docs[lid].field_tf[Field::Title as usize]
                        .iter()
                        .any(|(b, _)| *b == bucket);
                    assert!(has, "hit {} lacks required title term", h.global_id);
                }
            }
            Err(e) => panic!("require() request failed: {e}"),
        }
    }

    #[test]
    fn duplicate_query_terms_do_not_change_results() {
        // Satellite regression: `grid grid computing` must return exactly
        // the hits (ids and scores) of `grid computing` — duplicates are
        // deduplicated at compile time instead of inflating OR match
        // counts and doubling the BM25F query weight.
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let a = sys.search("grid grid computing data data data").unwrap();
        let b = sys.search("grid computing data").unwrap();
        let ids_a: Vec<u64> = a.hits.iter().map(|h| h.global_id).collect();
        let ids_b: Vec<u64> = b.hits.iter().map(|h| h.global_id).collect();
        assert_eq!(ids_a, ids_b, "duplicated terms changed the hit set");
        for (ha, hb) in a.hits.iter().zip(&b.hits) {
            assert_eq!(ha.score.to_bits(), hb.score.to_bits(), "score diverged");
        }
        assert_eq!(a.candidates, b.candidates);
    }

    #[test]
    fn batch_returns_one_response_per_request_in_order() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let reqs = vec![
            SearchRequest::new("grid computing"),
            SearchRequest::new("the of and"), // parse error mid-batch
            SearchRequest::new("data search").top_k(2),
        ];
        let out = sys.search_batch(&reqs);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok());
        assert_eq!(out[1].as_ref().unwrap_err().kind(), "parse");
        let third = out[2].as_ref().unwrap();
        assert!(third.hits.len() <= 2);
        assert_eq!(third.query, "data search");
    }

    #[test]
    fn batch_matches_sequential_results() {
        let cfg = small_cfg();
        let dep = Arc::new(Deployment::build(&cfg, 4).unwrap());
        let mut batch_sys = GapsSystem::from_deployment(cfg.clone(), Arc::clone(&dep)).unwrap();
        let mut serial_sys = GapsSystem::from_deployment(cfg, dep).unwrap();
        let reqs: Vec<SearchRequest> = [
            "grid data search",
            "massive academic publications",
            "year:2000..2014 grid",
            "\"grid computing\"",
        ]
        .iter()
        .map(|q| SearchRequest::new(*q))
        .collect();
        let batch = batch_sys.search_batch(&reqs);
        for (req, b) in reqs.iter().zip(batch) {
            let b = b.unwrap();
            let s = serial_sys.search_request(req).unwrap();
            let ids_b: Vec<u64> = b.hits.iter().map(|h| h.global_id).collect();
            let ids_s: Vec<u64> = s.hits.iter().map(|h| h.global_id).collect();
            assert_eq!(ids_b, ids_s, "batch hits diverged for {:?}", req.query);
            for (hb, hs) in b.hits.iter().zip(&s.hits) {
                assert_eq!(hb.score.to_bits(), hs.score.to_bits());
            }
            assert_eq!(b.candidates, s.candidates);
            assert_eq!(b.docs_scanned, s.docs_scanned);
        }
    }

    #[test]
    fn batch_amortizes_dispatch() {
        // One batch of 4 queries acquires each node's service once; four
        // sequential searches acquire it four times.
        let cfg = small_cfg();
        let dep = Arc::new(Deployment::build(&cfg, 4).unwrap());
        let mut batch_sys = GapsSystem::from_deployment(cfg.clone(), Arc::clone(&dep)).unwrap();
        let mut serial_sys = GapsSystem::from_deployment(cfg, dep).unwrap();
        let reqs: Vec<SearchRequest> =
            (0..4).map(|i| SearchRequest::new(format!("grid data search {i}"))).collect();
        for r in batch_sys.search_batch(&reqs) {
            r.unwrap();
        }
        for r in &reqs {
            serial_sys.search_request(r).unwrap();
        }
        let total = |sys: &GapsSystem| -> u64 {
            sys.deployment().active.iter().map(|&n| sys.service_acquisitions(n)).sum()
        };
        let (batch_acq, serial_acq) = (total(&batch_sys), total(&serial_sys));
        assert!(
            batch_acq < serial_acq,
            "batch should amortize acquisitions: {batch_acq} vs {serial_acq}"
        );
    }

    #[test]
    fn replica_pref_changes_placement_not_results() {
        let cfg = small_cfg();
        let dep = Arc::new(Deployment::build(&cfg, 6).unwrap());
        let mut sys = GapsSystem::from_deployment(cfg, dep).unwrap();
        let q = "grid distributed search";
        let any = sys.search_request(&SearchRequest::new(q)).unwrap();
        let primary = sys
            .search_request(&SearchRequest::new(q).prefer_replicas(ReplicaPref::Primary))
            .unwrap();
        let same_vo = sys
            .search_request(&SearchRequest::new(q).prefer_replicas(ReplicaPref::SameVo))
            .unwrap();
        let ids: Vec<u64> = any.hits.iter().map(|h| h.global_id).collect();
        for other in [&primary, &same_vo] {
            let other_ids: Vec<u64> = other.hits.iter().map(|h| h.global_id).collect();
            assert_eq!(ids, other_ids, "replica preference changed results");
        }
        assert_eq!(any.docs_scanned, primary.docs_scanned);
    }

    #[test]
    fn response_json_roundtrips() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let resp = sys
            .search_request(&SearchRequest::new("grid computing data").explain(true))
            .unwrap();
        let parsed = SearchResponse::from_json(&resp.to_json()).unwrap();
        assert_eq!(parsed.query, resp.query);
        assert_eq!(parsed.hits, resp.hits);
        assert_eq!(parsed.jobs, resp.jobs);
        assert_eq!(parsed.candidates, resp.candidates);
        assert_eq!(parsed.docs_scanned, resp.docs_scanned);
        assert_eq!(parsed.explain, resp.explain);
        assert!((parsed.timeline.work_s - resp.timeline.work_s).abs() < 1e-12);
    }

    #[test]
    fn perf_history_populates_after_queries() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        assert!(!sys.perf_db().has_history());
        sys.search("grid data search").unwrap();
        assert!(sys.perf_db().has_history());
        assert!(sys.query_manager().completed_jobs() >= 1);
    }

    #[test]
    fn failed_node_is_routed_around() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let victim = sys.deployment().active[1];
        sys.fail_node(victim);
        let resp = sys.search("grid computing search").unwrap();
        // All sources still searched (replicas cover the victim).
        assert_eq!(resp.docs_scanned, 600);
        // And the victim got no jobs.
        assert_eq!(sys.service_acquisitions(victim), 0);
    }

    #[test]
    fn recovery_brings_node_back() {
        let mut sys = GapsSystem::deploy(small_cfg(), 2).unwrap();
        let victim = sys.deployment().active[1];
        sys.fail_node(victim);
        sys.search("grid").unwrap();
        sys.recover_node(victim);
        sys.search("grid").unwrap();
        assert!(sys.service_acquisitions(victim) > 0);
    }

    #[test]
    fn all_replicas_down_is_a_typed_error() {
        let mut cfg = small_cfg();
        cfg.workload.sub_shards = 2;
        let mut sys = GapsSystem::deploy(cfg, 2).unwrap();
        for &n in sys.deployment().active.clone().iter() {
            sys.fail_node(n);
        }
        match sys.search("grid") {
            Err(SearchError::NoNodes) | Err(SearchError::NoLiveReplica { .. }) => {}
            other => panic!("expected a typed availability error, got {other:?}"),
        }
    }

    #[test]
    fn round_robin_policy_also_covers_corpus() {
        let mut cfg = small_cfg();
        cfg.search.policy = SchedulePolicy::RoundRobin;
        let mut sys = GapsSystem::deploy(cfg, 4).unwrap();
        let resp = sys.search("massive academic publications").unwrap();
        assert_eq!(resp.docs_scanned, 600);
    }

    #[test]
    fn parallel_fanout_matches_serial_results() {
        // Exact result semantics: the gridpool fan-out must return
        // byte-identical hits (ids, scores, order) to serial dispatch.
        let mut cfg_par = small_cfg();
        cfg_par.search.workers = 4;
        let mut cfg_ser = small_cfg();
        cfg_ser.search.workers = 1;
        let dep = Arc::new(Deployment::build(&cfg_par, 6).unwrap());
        let mut par = GapsSystem::from_deployment(cfg_par, Arc::clone(&dep)).unwrap();
        let mut ser = GapsSystem::from_deployment(cfg_ser, dep).unwrap();
        for q in ["grid data search", "massive academic publications", "year:2000..2014 grid"] {
            let rp = par.search(q).unwrap();
            let rs = ser.search(q).unwrap();
            let ids_p: Vec<u64> = rp.hits.iter().map(|h| h.global_id).collect();
            let ids_s: Vec<u64> = rs.hits.iter().map(|h| h.global_id).collect();
            assert_eq!(ids_p, ids_s, "hit order diverged for {q:?}");
            for (a, b) in rp.hits.iter().zip(&rs.hits) {
                assert_eq!(a.score, b.score, "score diverged for {q:?}");
            }
            assert_eq!(rp.docs_scanned, rs.docs_scanned);
            assert_eq!(rp.candidates, rs.candidates);
        }
    }

    #[test]
    fn deterministic_hits_across_runs() {
        let mut a = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let mut b = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let ra = a.search("distributed grid search").unwrap();
        let rb = b.search("distributed grid search").unwrap();
        let ids_a: Vec<u64> = ra.hits.iter().map(|h| h.global_id).collect();
        let ids_b: Vec<u64> = rb.hits.iter().map(|h| h.global_id).collect();
        assert_eq!(ids_a, ids_b);
    }

    #[test]
    fn failover_reruns_failed_jobs_with_identical_results() {
        // A node crashing mid-flight must be invisible in the results:
        // its job's sources re-plan onto live replicas and the merged
        // top-k stays bit-identical to the fault-free run.
        use crate::fault::{ChaosPlan, FaultKind};
        let cfg = small_cfg();
        let dep = Arc::new(Deployment::build(&cfg, 4).unwrap());
        let mut oracle = GapsSystem::from_deployment(cfg.clone(), Arc::clone(&dep)).unwrap();
        let mut chaos = GapsSystem::from_deployment(cfg, dep).unwrap();
        let victim = chaos.deployment().active[1];
        chaos.set_fault_injector(
            ChaosPlan::new().with_fault(victim, FaultKind::CrashBeforeExecute),
        );
        let want = oracle.search("grid computing search").unwrap();
        let got = chaos.search("grid computing search").unwrap();
        assert_eq!(got.docs_scanned, 600, "failover must keep full coverage");
        assert!(!got.degraded);
        assert!(got.missing_sources.is_empty());
        let ids_w: Vec<u64> = want.hits.iter().map(|h| h.global_id).collect();
        let ids_g: Vec<u64> = got.hits.iter().map(|h| h.global_id).collect();
        assert_eq!(ids_w, ids_g, "failover changed the hit set");
        for (w, g) in want.hits.iter().zip(&got.hits) {
            assert_eq!(w.score.to_bits(), g.score.to_bits(), "failover changed a score");
        }
        assert_eq!(want.candidates, got.candidates);
        let fs = chaos.failover_stats();
        assert!(fs.jobs_failed >= 1, "victim never failed a job");
        assert!(fs.replans >= 1, "no failover replan happened");
        assert!(fs.nodes_marked_down >= 1);
    }

    #[test]
    fn flaky_node_recovers_after_probation() {
        use crate::fault::{ChaosPlan, FaultKind};
        let mut cfg = small_cfg();
        cfg.grid.probe_after_ticks = 1;
        let mut sys = GapsSystem::deploy(cfg, 2).unwrap();
        let victim = sys.deployment().active[1];
        sys.set_fault_injector(
            ChaosPlan::new().with_fault(victim, FaultKind::FlakyThenRecover { failures: 1 }),
        );
        // Batch 1: the flaky job fails once, fails over in-flight, and
        // the victim goes Down.
        let r1 = sys.search("grid computing").unwrap();
        assert_eq!(r1.docs_scanned, 600);
        // Batch 2: probation elapsed, the health probe finds the node
        // recovered (failure budget spent), and it rejoins the grid.
        let r2 = sys.search("grid computing").unwrap();
        assert_eq!(r2.docs_scanned, 600);
        let fs = sys.failover_stats();
        assert!(fs.jobs_failed >= 1, "flaky node never failed");
        assert!(fs.probes >= 1, "probation probe never ran");
        assert!(fs.recoveries >= 1, "flaky node never rejoined");
    }

    #[test]
    fn partial_results_when_no_replica_survives() {
        // Crash every replica of source 0: a strict request fails with a
        // typed availability error; an allow_partial request degrades
        // truthfully instead.
        use crate::fault::{ChaosPlan, FaultKind};
        let cfg = small_cfg();
        let dep = Arc::new(Deployment::build(&cfg, 4).unwrap());
        let replicas = dep.locator.source(0).unwrap().replicas.clone();
        let mut plan = ChaosPlan::new();
        for &n in &replicas {
            plan = plan.with_fault(n, FaultKind::CrashBeforeExecute);
        }

        let mut strict = GapsSystem::from_deployment(cfg.clone(), Arc::clone(&dep)).unwrap();
        strict.set_fault_injector(plan.clone());
        let err = strict.search("grid computing").unwrap_err();
        assert!(
            err.kind() == "no-live-replica" || err.kind() == "unavailable",
            "unexpected error kind {:?}",
            err.kind()
        );

        let mut partial = GapsSystem::from_deployment(cfg, dep).unwrap();
        partial.set_fault_injector(plan);
        let resp = partial
            .search_request(&SearchRequest::new("grid computing").allow_partial(true))
            .unwrap();
        assert!(resp.degraded, "losing a source must flag degraded");
        assert!(resp.missing_sources.contains(&0));
        // Scanned docs = corpus minus exactly the missing sources.
        let missing_docs: u64 = resp
            .missing_sources
            .iter()
            .map(|&s| partial.deployment().locator.source(s).unwrap().doc_count)
            .sum();
        assert_eq!(resp.docs_scanned, 600 - missing_docs);
        // No hit may leak out of a missing source's doc range.
        for h in &resp.hits {
            for &s in &resp.missing_sources {
                let src = partial.deployment().locator.source(s).unwrap();
                assert!(
                    !(src.doc_start..src.doc_start + src.doc_count).contains(&h.global_id),
                    "hit {} leaked from missing source {s}",
                    h.global_id
                );
            }
        }
        // The degraded wire form roundtrips.
        let parsed = SearchResponse::from_json(&resp.to_json()).unwrap();
        assert!(parsed.degraded);
        assert_eq!(parsed.missing_sources, resp.missing_sources);
    }

    /// Sample follow-on publications *beyond* the deployed corpus:
    /// generation is pure in (seed, id), so widening `num_docs` on a
    /// fresh generator yields new docs disjoint from the base ids.
    fn extra_pubs(sys: &GapsSystem, n: u64) -> Vec<Publication> {
        let base = sys.deployment().locator.total_docs();
        let spec = CorpusSpec {
            seed: sys.cfg.workload.seed,
            num_docs: base + n,
            ..CorpusSpec::default()
        };
        CorpusGenerator::new(spec).generate_range(base, n)
    }

    #[test]
    fn ingest_buffers_then_seals_and_is_searchable() {
        let mut cfg = small_cfg();
        cfg.storage.seal_docs = 4;
        let mut sys = GapsSystem::deploy(cfg, 4).unwrap();
        assert_eq!(sys.index_epoch(), 0);

        // Below the seal threshold: accepted but not yet searchable.
        let batch = extra_pubs(&sys, 40);
        let first_title = batch[0].title.clone();
        let rep = sys.ingest(batch[..10].to_vec());
        assert_eq!(rep.accepted, 10);
        assert_eq!(rep.sealed, 0, "10 docs over 8 sources must stay buffered");
        assert_eq!(rep.epoch, 0);
        let h = sys.index_health();
        assert_eq!(h.buffered_docs, 10);
        assert_eq!(h.searchable_docs, 600);
        // The assigned ids resolve even while buffered.
        assert!(sys.publication(600).is_some());

        // Push every source past the threshold: seals happen, epoch
        // moves, and the docs become searchable without a restart.
        let rep = sys.ingest(batch[10..].to_vec());
        assert_eq!(rep.accepted, 30);
        assert!(rep.sealed > 0, "40 docs over 8 sources must seal some buffers");
        assert!(rep.epoch > 0);
        sys.flush_ingest();
        let h = sys.index_health();
        assert_eq!(h.buffered_docs, 0);
        assert_eq!(h.searchable_docs, 640);
        assert!(h.seals > 0);
        assert!(!h.segments.is_empty());

        let resp = sys
            .search_request(&SearchRequest::new(&first_title).explain(true))
            .unwrap();
        assert!(
            resp.hits.iter().any(|hit| hit.global_id == 600),
            "ingested doc 600 not found by its own title: {:?}",
            resp.hits.iter().map(|hit| hit.global_id).collect::<Vec<_>>()
        );
        assert_eq!(resp.explain.unwrap().epoch, sys.index_epoch());
        assert_eq!(resp.docs_scanned, 640);
        // Title materialization crossed into the overlay lookup.
        let hit = resp.hits.iter().find(|hit| hit.global_id == 600).unwrap();
        assert_eq!(hit.title, first_title);
    }

    #[test]
    fn overlay_merge_compacts_segments() {
        let mut cfg = small_cfg();
        cfg.workload.sub_shards = 2;
        cfg.storage.seal_docs = 4;
        cfg.storage.merge_fanout = 2;
        let mut sys = GapsSystem::deploy(cfg, 2).unwrap();
        let pubs = extra_pubs(&sys, 32);
        let mut merges = 0usize;
        for chunk in pubs.chunks(8) {
            let rep = sys.ingest(chunk.to_vec());
            merges += rep.merges;
        }
        assert!(merges > 0, "fanout-2 compaction never fired");
        let h = sys.index_health();
        assert_eq!(h.searchable_docs, 600 + 32);
        assert!(h.merges > 0);
        // Compaction keeps every source's segment count under fanout.
        for &(_, n) in &h.segments {
            assert!(n < 2 + 1, "source kept {n} segments past fanout");
        }
        // Every ingested doc remains findable after compaction.
        for want in [600u64, 615, 631] {
            let title = sys.publication(want).unwrap().title.clone();
            let resp = sys.search(&title).unwrap();
            assert!(
                resp.hits.iter().any(|hit| hit.global_id == want),
                "doc {want} lost by compaction"
            );
        }
    }

    #[test]
    fn ingest_does_not_change_base_results_before_seal() {
        // Buffered (unsealed) docs must be invisible: searches return
        // byte-identical results to a never-ingested system.
        let cfg = small_cfg();
        let dep = Arc::new(Deployment::build(&cfg, 4).unwrap());
        let mut clean = GapsSystem::from_deployment(cfg.clone(), Arc::clone(&dep)).unwrap();
        let mut dirty = GapsSystem::from_deployment(cfg, dep).unwrap();
        let pubs = extra_pubs(&dirty, 5); // below seal_docs: stays buffered
        dirty.ingest(pubs);
        for q in ["grid data search", "massive academic publications"] {
            let a = clean.search(q).unwrap();
            let b = dirty.search(q).unwrap();
            let ids_a: Vec<u64> = a.hits.iter().map(|h| h.global_id).collect();
            let ids_b: Vec<u64> = b.hits.iter().map(|h| h.global_id).collect();
            assert_eq!(ids_a, ids_b);
            for (x, y) in a.hits.iter().zip(&b.hits) {
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
            assert_eq!(a.docs_scanned, b.docs_scanned);
        }
    }

    #[test]
    fn snapshot_roundtrip_restores_ingested_state() {
        let dir = std::env::temp_dir().join("gaps_test_system_snapshot");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = small_cfg();
        cfg.storage.seal_docs = 8;
        let mut sys = GapsSystem::deploy(cfg.clone(), 4).unwrap();
        sys.ingest(extra_pubs(&sys, 24));
        sys.flush_ingest();
        let manifest = sys.write_snapshot(&dir).unwrap();
        assert_eq!(manifest.num_docs, 600);
        assert_eq!(manifest.next_global_id, 624);
        assert!(!manifest.overlays.is_empty());

        let mut restored = GapsSystem::deploy_from_snapshot(cfg, 4, &dir).unwrap();
        assert_eq!(restored.index_epoch(), sys.index_epoch());
        let (ha, hb) = (sys.index_health(), restored.index_health());
        assert_eq!(ha.searchable_docs, hb.searchable_docs);
        assert_eq!(ha.segments, hb.segments);
        for q in ["grid computing search", "data distributed"] {
            let a = sys.search(q).unwrap();
            let b = restored.search(q).unwrap();
            let ids_a: Vec<u64> = a.hits.iter().map(|h| h.global_id).collect();
            let ids_b: Vec<u64> = b.hits.iter().map(|h| h.global_id).collect();
            assert_eq!(ids_a, ids_b, "restored hits diverged for {q:?}");
            for (x, y) in a.hits.iter().zip(&b.hits) {
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
        }
        // Ingestion resumes where the snapshot left off.
        let rep = restored.ingest(extra_pubs(&sys, 1));
        assert_eq!(rep.accepted, 1);
        assert!(restored.publication(624).is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn snapshot_rejects_feature_mismatch() {
        let dir = std::env::temp_dir().join("gaps_test_system_snapshot_f");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = small_cfg();
        let sys = GapsSystem::deploy(cfg.clone(), 2).unwrap();
        sys.write_snapshot(&dir).unwrap();
        let mut other = cfg;
        other.search.features = 256;
        let err = GapsSystem::deploy_from_snapshot(other, 2, &dir).unwrap_err();
        assert_eq!(err.kind(), "invalid-config");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn index_health_json_roundtrips() {
        let health = IndexHealth {
            epoch: 7,
            searchable_docs: 1234,
            buffered_docs: 5,
            segments: vec![(0, 2), (3, 1)],
            seals: 4,
            merges: 1,
        };
        let parsed = IndexHealth::from_json(&health.to_json()).unwrap();
        assert_eq!(parsed, health);
        assert!(IndexHealth::from_json(&Json::str("nope")).is_none());
    }

    #[test]
    fn zero_deadline_is_exceeded() {
        let mut sys = GapsSystem::deploy(small_cfg(), 2).unwrap();
        let err = sys
            .search_request(&SearchRequest::new("grid computing").deadline_ms(0))
            .unwrap_err();
        assert_eq!(err.kind(), "deadline-exceeded");
        // A generous deadline does not trip.
        let ok = sys
            .search_request(&SearchRequest::new("grid computing").deadline_ms(60_000))
            .unwrap();
        assert!(!ok.degraded);
    }
}
