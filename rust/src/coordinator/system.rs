//! The deployed GAPS system: fabric + data + services + `search()`.
//!
//! Execution topology (paper Fig 1 + §III):
//!
//! ```text
//! USI -> root broker QEE
//!          |-- ResourceManager (node status)
//!          |-- DataSourceLocator (sources + global stats)
//!          |-- QEE.plan (perf-history LPT)  -> QM.create_jobs (JDFs)
//!          |-- per VO (parallel, WAN):   VO broker QEE
//!          |        dispatches its jobs serially (LAN), nodes run the
//!          |        Search Service on their sources, reply to the broker
//!          |        which merges its VO's lists
//!          `-- root merges VO lists -> user
//! ```
//!
//! Timing: real measured compute (`work_s`, scaled by the node's simulated
//! speed factor) + accounted fabric costs (`net_s`, `overhead_s`). See
//! DESIGN.md §Substitutions for why this composition is faithful.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::GapsConfig;
use crate::corpus::{CorpusGenerator, CorpusSpec, Publication};
use crate::grid::{GridFabric, NodeId};
use crate::index::{GlobalStats, Shard};
use crate::runtime::Executor;
use crate::search::{LocalHit, ParsedQuery, Scorer, SearchService};
use crate::util::pool::par_map_scoped;

use crate::util::clock::{TaskTimeline, WallClock};

use super::jdf::JobDescription;
use super::locator::{DataSource, DataSourceLocator};
use super::merge::{merge_topk, result_wire_bytes};
use super::perf::PerfDb;
use super::qee::QueryExecutionEngine;
use super::qm::QueryManager;
use super::resource_manager::ResourceManager;

/// Analyzed corpus data: the expensive, node-count-independent half of a
/// deployment (generation + tokenization + indexing of every sub-shard).
/// Built once and shared across sweep points / systems via `Arc`.
#[derive(Debug)]
pub struct CorpusData {
    /// source id -> analyzed sub-shard.
    pub shards: BTreeMap<u32, Shard>,
    /// (doc_start, doc_count) per source id, in id order.
    pub ranges: Vec<(u64, u64)>,
    /// The corpus generator (query sampling, record lookups).
    pub generator: CorpusGenerator,
    /// Feature-space size the shards were analyzed with.
    pub features: usize,
}

impl CorpusData {
    /// Generate + analyze the corpus as `num_sources` contiguous shards.
    pub fn build(cfg: &GapsConfig, num_sources: u64) -> Result<CorpusData> {
        let spec = CorpusSpec {
            seed: cfg.workload.seed,
            num_docs: cfg.workload.num_docs,
            ..CorpusSpec::default()
        };
        let generator = CorpusGenerator::new(spec);
        let num_sources = num_sources.max(1);
        let docs_per = cfg.workload.num_docs / num_sources;
        if docs_per == 0 {
            bail!("corpus too small: {} docs over {num_sources} sources", cfg.workload.num_docs);
        }
        let mut shards = BTreeMap::new();
        let mut ranges = Vec::with_capacity(num_sources as usize);
        for sid in 0..num_sources {
            let start = sid * docs_per;
            let count = if sid == num_sources - 1 {
                cfg.workload.num_docs - start // last source takes the tail
            } else {
                docs_per
            };
            let shard =
                Shard::build(sid as u32, generator.generate_range(start, count), cfg.search.features);
            shards.insert(sid as u32, shard);
            ranges.push((start, count));
        }
        Ok(CorpusData { shards, ranges, generator, features: cfg.search.features })
    }
}

/// Immutable deployment: fabric + analyzed data + replica placement,
/// shared by GAPS and the traditional baseline so comparisons run over
/// identical bits.
#[derive(Debug)]
pub struct Deployment {
    pub fabric: GridFabric,
    /// Nodes participating in this experiment (first n, VO-balanced).
    pub active: Vec<NodeId>,
    /// The analyzed corpus (shared across deployments).
    pub data: Arc<CorpusData>,
    pub locator: DataSourceLocator,
    pub stats: GlobalStats,
}

impl Deployment {
    /// Build a deployment from scratch (corpus + placement). Sweeps that
    /// reuse one corpus across node counts should call [`CorpusData::
    /// build`] once and [`Deployment::assemble`] per point instead.
    pub fn build(cfg: &GapsConfig, n_nodes: usize) -> Result<Deployment> {
        let num_sources = cfg.workload.sub_shards.max(n_nodes).max(1) as u64;
        let data = Arc::new(CorpusData::build(cfg, num_sources)?);
        Deployment::assemble(cfg, n_nodes, data)
    }

    /// Place an analyzed corpus onto `n_nodes` nodes: each source gets a
    /// primary (round-robin over active nodes) plus a replica — same-VO
    /// when the VO has another active member (cheap LAN replication),
    /// any other active node otherwise.
    pub fn assemble(cfg: &GapsConfig, n_nodes: usize, data: Arc<CorpusData>) -> Result<Deployment> {
        let fabric = GridFabric::build(&cfg.grid);
        if n_nodes == 0 || n_nodes > fabric.nodes.len() {
            bail!("n_nodes {} out of range 1..={}", n_nodes, fabric.nodes.len());
        }
        if data.features != cfg.search.features {
            bail!("corpus analyzed with F={}, config wants F={}", data.features, cfg.search.features);
        }
        let active = fabric.first_nodes_balanced(n_nodes);

        let mut locator = DataSourceLocator::new();
        for (sid, &(start, count)) in data.ranges.iter().enumerate() {
            let primary = active[sid % n_nodes];
            let primary_vo = fabric.node(primary).vo;
            let same_vo = active
                .iter()
                .copied()
                .filter(|&n| n != primary && fabric.node(n).vo == primary_vo)
                .min_by_key(|n| (n.0 + fabric.nodes.len() as u32 - primary.0) % fabric.nodes.len() as u32);
            let secondary = same_vo.or_else(|| (n_nodes > 1).then(|| active[(sid + 1) % n_nodes]));
            let mut replicas = vec![primary];
            replicas.extend(secondary);
            locator.register(
                DataSource { id: sid as u32, doc_start: start, doc_count: count, replicas },
                &data.shards[&(sid as u32)].stats,
            );
        }
        let stats = locator.global_stats().context("no sources registered")?;
        Ok(Deployment { fabric, active, data, locator, stats })
    }

    /// Shard behind a source id.
    pub fn shard(&self, source_id: u32) -> Option<&Shard> {
        self.data.shards.get(&source_id)
    }

    /// The corpus generator (query sampling).
    pub fn generator(&self) -> &CorpusGenerator {
        &self.data.generator
    }

    /// Look up the publication record behind a corpus-global doc id.
    pub fn publication(&self, global_id: u64) -> Option<&Publication> {
        for src in self.locator.sources() {
            if (src.doc_start..src.doc_start + src.doc_count).contains(&global_id) {
                return self
                    .data
                    .shards
                    .get(&src.id)
                    .map(|s| &s.pubs[(global_id - src.doc_start) as usize]);
            }
        }
        None
    }
}

/// One search hit as returned to the user.
#[derive(Debug, Clone, PartialEq)]
pub struct Hit {
    pub global_id: u64,
    pub score: f32,
    pub title: String,
}

/// End-to-end response: hits + the composed timeline.
#[derive(Debug, Clone)]
pub struct SearchResponse {
    pub query: String,
    pub hits: Vec<Hit>,
    /// Composed critical-path timeline (work / net / overhead split).
    pub timeline: TaskTimeline,
    /// Jobs dispatched for this query.
    pub jobs: usize,
    /// Candidates retrieved across all nodes.
    pub candidates: usize,
    /// Documents in all searched sources.
    pub docs_scanned: u64,
}

impl SearchResponse {
    /// The paper's response-time metric.
    pub fn response_s(&self) -> f64 {
        self.timeline.total_s()
    }
}

/// Pure compute result of one search job (fabric costs are accounted by
/// the caller): merged local hits + measured work + scan counters.
struct JobOutput {
    hits: Vec<LocalHit>,
    work_measured: f64,
    candidates: usize,
    docs: u64,
}

/// Execute one job's search work over its sources. Free function (not a
/// `GapsSystem` method) so the parallel fan-out can call it from worker
/// threads while the coordinator keeps its `&mut self` bookkeeping.
fn run_job(
    service: &SearchService,
    dep: &Deployment,
    query: &ParsedQuery,
    job: &JobDescription,
    scorer: &mut Scorer<'_>,
    top_k: usize,
) -> Result<JobOutput> {
    let mut work_measured = 0.0f64;
    let mut candidates = 0usize;
    let mut docs = 0u64;
    let mut hits_lists: Vec<Vec<LocalHit>> = Vec::with_capacity(job.sources.len());
    for sid in &job.sources {
        let shard = dep.shard(*sid).context("unknown source")?;
        let out = service.search(shard, &dep.stats, query, scorer)?;
        work_measured += out.work_s;
        candidates += out.candidates;
        docs += out.shard_docs as u64;
        hits_lists.push(out.hits);
    }
    Ok(JobOutput { hits: merge_topk(&hits_lists, top_k), work_measured, candidates, docs })
}

/// The deployed GAPS system.
pub struct GapsSystem {
    pub cfg: GapsConfig,
    dep: Arc<Deployment>,
    rm: ResourceManager,
    perf: PerfDb,
    qm: QueryManager,
    qee: QueryExecutionEngine,
    service: SearchService,
    executor: Option<Executor>,
    /// Per-node service containers (globus-container analogue). Owned by
    /// the system (not the shared deployment) so acquisition counters and
    /// residency ablations stay per-system.
    containers: BTreeMap<NodeId, crate::grid::ServiceContainer>,
    /// The broker the USI talks to (broker of the first active node's VO).
    root_broker: NodeId,
}

impl std::fmt::Debug for GapsSystem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GapsSystem")
            .field("active_nodes", &self.dep.active.len())
            .field("sources", &self.dep.locator.len())
            .field("xla", &self.executor.is_some())
            .finish()
    }
}

impl GapsSystem {
    /// Deploy GAPS on `n_nodes` nodes (builds fabric + data).
    pub fn deploy(cfg: GapsConfig, n_nodes: usize) -> Result<GapsSystem> {
        let dep = Arc::new(Deployment::build(&cfg, n_nodes)?);
        Self::from_deployment(cfg, dep)
    }

    /// Deploy over an existing (shared) deployment.
    pub fn from_deployment(cfg: GapsConfig, dep: Arc<Deployment>) -> Result<GapsSystem> {
        let mut rm = ResourceManager::new(3);
        for &n in &dep.active {
            rm.register(dep.fabric.node(n).clone());
        }
        let executor = if cfg.search.use_xla {
            Some(Executor::new(std::path::Path::new(&cfg.search.artifact_dir))?)
        } else {
            None
        };
        let root_broker = dep.fabric.vo_of(dep.active[0]).broker;
        let mut containers = BTreeMap::new();
        for &n in &dep.active {
            let mut c = crate::grid::ServiceContainer::new(
                n.to_string(),
                cfg.grid.resident_services,
                cfg.grid.cold_start_ms * 1e-3,
            );
            c.deploy("search-service");
            containers.insert(n, c);
        }
        Ok(GapsSystem {
            service: SearchService::new(cfg.search.clone()),
            cfg,
            dep,
            rm,
            perf: PerfDb::default(),
            qm: QueryManager::new(),
            qee: QueryExecutionEngine,
            executor,
            containers,
            root_broker,
        })
    }

    pub fn deployment(&self) -> &Deployment {
        &self.dep
    }

    pub fn perf_db(&self) -> &PerfDb {
        &self.perf
    }

    pub fn query_manager(&self) -> &QueryManager {
        &self.qm
    }

    /// Inject a node failure (resource dynamicity).
    pub fn fail_node(&mut self, node: NodeId) {
        self.rm.mark_down(node);
    }

    /// Heartbeat a node back into the grid.
    pub fn recover_node(&mut self, node: NodeId) {
        self.rm.heartbeat(node);
    }

    /// Execute one query end to end. This is the paper's GAPS flow.
    pub fn search(&mut self, raw: &str) -> Result<SearchResponse> {
        let plan_clock = WallClock::start();
        let query = ParsedQuery::parse(raw, self.cfg.search.features)
            .map_err(|e| anyhow::anyhow!("{e}"))?;

        // Plan: resources + sources -> node assignments (QEE).
        let available = self.rm.available();
        let sources = self.dep.locator.sources();
        let plan = self.qee.plan(&sources, &available, &self.perf, self.cfg.search.policy)?;

        // QM materializes the JDFs (reply-to = each node's VO broker).
        let fabric = &self.dep.fabric;
        let jobs = self.qm.create_jobs(
            raw,
            &plan,
            |n| fabric.vo_of(n).broker,
            self.cfg.search.top_k,
        );
        let plan_s = plan_clock.elapsed_s();

        // Group jobs by VO for the decentralized dispatch.
        let mut by_vo: BTreeMap<u32, Vec<&JobDescription>> = BTreeMap::new();
        for j in &jobs {
            by_vo.entry(self.dep.fabric.node(j.node).vo.0).or_default().push(j);
        }

        let dispatch_s = self.cfg.grid.dispatch_ms * 1e-3;
        let net = &self.dep.fabric.net;
        let root_info = self.dep.fabric.node(self.root_broker).clone();

        // ---- Dispatch bookkeeping (serial: QM + containers) -----------
        // Flatten jobs in (vo, j_idx) order; the fan-out below returns
        // outputs in the same order, keeping merges deterministic.
        let mut flat_jobs: Vec<&JobDescription> = Vec::with_capacity(jobs.len());
        let mut startups: Vec<f64> = Vec::with_capacity(jobs.len());
        for vo_jobs in by_vo.values() {
            for job in vo_jobs {
                self.qm.mark_dispatched(job.id);
                let handle = self
                    .containers
                    .get_mut(&job.node)
                    .context("node has no container")?
                    .acquire("search-service")
                    .context("search-service not deployed")?;
                flat_jobs.push(job);
                startups.push(handle.startup_s);
            }
        }

        // ---- Execute every node's job (parallel shard fan-out) --------
        // Real concurrent work on the gridpool substrate. Per-job wall
        // time is measured inside each job; under contention that
        // measurement inflates, so the figure sweeps pin workers = 1
        // (see metrics::run_node_sweep) while serving paths default to
        // all cores.
        let top_k = self.cfg.search.top_k;
        let workers = self.cfg.search.effective_workers().min(flat_jobs.len().max(1));
        let outputs: Vec<JobOutput> = match self.executor.as_mut() {
            Some(exec) => {
                // PJRT handles are !Send: artifact execution stays on the
                // coordinator thread (see runtime::mod docs).
                let mut outs = Vec::with_capacity(flat_jobs.len());
                for job in &flat_jobs {
                    let mut scorer = Scorer::Xla(&mut *exec);
                    outs.push(run_job(&self.service, &self.dep, &query, job, &mut scorer, top_k)?);
                }
                outs
            }
            None if workers <= 1 => {
                let mut outs = Vec::with_capacity(flat_jobs.len());
                for job in &flat_jobs {
                    outs.push(run_job(&self.service, &self.dep, &query, job, &mut Scorer::Rust, top_k)?);
                }
                outs
            }
            None => {
                let service = &self.service;
                let dep: &Deployment = &self.dep;
                let q = &query;
                par_map_scoped(&flat_jobs, workers, |job| {
                    run_job(service, dep, q, job, &mut Scorer::Rust, top_k)
                })
                .into_iter()
                .collect::<Result<Vec<_>>>()?
            }
        };

        // ---- Assemble per-VO timelines from the job outputs -----------
        let mut vo_timelines: Vec<TaskTimeline> = Vec::new();
        let mut vo_lists: Vec<Vec<LocalHit>> = Vec::new();
        let mut total_candidates = 0usize;
        let mut total_docs = 0u64;
        let mut completions: Vec<(super::jdf::JobId, u64, f64)> = Vec::new();
        let mut outputs = outputs.into_iter();
        let mut startups = startups.into_iter();

        for (vo_idx, (vo, vo_jobs)) in by_vo.iter().enumerate() {
            let vo_broker = self.dep.fabric.vos[*vo as usize].broker;
            let vo_broker_info = self.dep.fabric.node(vo_broker).clone();
            // Root QEE hands this VO's QEE its slice (serial at root).
            let jdf_bytes: usize = vo_jobs.iter().map(|j| j.wire_bytes()).sum();
            let mut vo_tl = TaskTimeline {
                work_s: 0.0,
                net_s: net.transfer_between_s(&root_info, &vo_broker_info, jdf_bytes),
                overhead_s: (vo_idx + 1) as f64 * dispatch_s,
            };

            // VO broker dispatches its jobs serially; nodes run in parallel.
            let mut node_branches: Vec<TaskTimeline> = Vec::new();
            let mut node_lists: Vec<Vec<LocalHit>> = Vec::new();
            for (j_idx, job) in vo_jobs.iter().enumerate() {
                let out = outputs.next().expect("one output per job");
                let startup_s = startups.next().expect("one handle per job");
                let node_info = self.dep.fabric.node(job.node).clone();
                total_candidates += out.candidates;
                total_docs += out.docs;
                let work_acc = out.work_measured / node_info.speed_factor;
                completions.push((job.id, out.docs, work_acc));

                let branch = TaskTimeline {
                    work_s: work_acc,
                    net_s: net.transfer_between_s(&vo_broker_info, &node_info, job.wire_bytes())
                        + net.transfer_between_s(
                            &node_info,
                            &vo_broker_info,
                            result_wire_bytes(out.hits.len()),
                        ),
                    overhead_s: (j_idx + 1) as f64 * dispatch_s + startup_s,
                };
                node_branches.push(branch);
                node_lists.push(out.hits);
            }

            // Barrier at the VO broker: slowest member dominates.
            let slowest = node_branches
                .into_iter()
                .fold(TaskTimeline::default(), |acc, b| acc.max(b));
            vo_tl.add(slowest);

            // VO-level merge (measured) + WAN reply to root.
            let merge_clock = WallClock::start();
            let vo_merged = merge_topk(&node_lists, self.cfg.search.top_k);
            vo_tl.work_s += merge_clock.elapsed_s();
            vo_tl.net_s += net.transfer_between_s(
                &vo_broker_info,
                &root_info,
                result_wire_bytes(vo_merged.len()),
            );
            vo_lists.push(vo_merged);
            vo_timelines.push(vo_tl);
        }

        // Record completions (QM -> perf DB).
        for (id, docs, work_s) in completions {
            self.qm.complete(id, docs, work_s, &mut self.perf);
        }

        // Root barrier + final merge.
        let mut timeline = TaskTimeline { work_s: plan_s, net_s: 0.0, overhead_s: 0.0 };
        let slowest_vo = vo_timelines
            .into_iter()
            .fold(TaskTimeline::default(), |acc, b| acc.max(b));
        timeline.add(slowest_vo);
        let merge_clock = WallClock::start();
        let merged = merge_topk(&vo_lists, self.cfg.search.top_k);
        timeline.work_s += merge_clock.elapsed_s();

        let hits = merged
            .into_iter()
            .map(|h| Hit {
                global_id: h.global_id,
                score: h.score,
                title: self
                    .dep
                    .publication(h.global_id)
                    .map(|p| p.title.clone())
                    .unwrap_or_default(),
            })
            .collect();

        Ok(SearchResponse {
            query: raw.to_string(),
            hits,
            timeline,
            jobs: jobs.len(),
            candidates: total_candidates,
            docs_scanned: total_docs,
        })
    }

    /// Service acquisitions on a node (container metrics).
    pub fn service_acquisitions(&self, node: NodeId) -> u64 {
        self.containers
            .get(&node)
            .map(|c| c.acquisitions("search-service"))
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GapsConfig, SchedulePolicy};

    fn small_cfg() -> GapsConfig {
        let mut cfg = GapsConfig::default();
        cfg.workload.num_docs = 600;
        cfg.workload.sub_shards = 8;
        cfg.search.use_xla = false; // unit tests stay artifact-free
        cfg
    }

    #[test]
    fn deployment_covers_corpus_exactly() {
        let dep = Deployment::build(&small_cfg(), 4).unwrap();
        assert_eq!(dep.locator.total_docs(), 600);
        assert_eq!(dep.locator.len(), 8);
        assert_eq!(dep.active.len(), 4);
        // Every source's shard holds its declared docs.
        for src in dep.locator.sources() {
            let shard = dep.shard(src.id).unwrap();
            assert_eq!(shard.len() as u64, src.doc_count);
            assert_eq!(shard.docs[0].global_id, src.doc_start);
        }
    }

    #[test]
    fn replicas_stay_within_vo_when_possible() {
        // 6 nodes over 3 VOs = 2 per VO: every source can replicate in-VO.
        let dep = Deployment::build(&small_cfg(), 6).unwrap();
        for src in dep.locator.sources() {
            assert_eq!(src.replicas.len(), 2);
            let vos: std::collections::HashSet<u32> =
                src.replicas.iter().map(|&n| dep.fabric.node(n).vo.0).collect();
            assert_eq!(vos.len(), 1, "replicas of {} span VOs", src.id);
        }
    }

    #[test]
    fn lone_vo_member_replicates_cross_vo() {
        // 3 nodes = 1 per VO: secondary must fall back to another VO.
        let dep = Deployment::build(&small_cfg(), 3).unwrap();
        for src in dep.locator.sources() {
            assert_eq!(src.replicas.len(), 2, "source {} lacks a replica", src.id);
        }
    }

    #[test]
    fn publication_lookup_roundtrips() {
        let dep = Deployment::build(&small_cfg(), 3).unwrap();
        for id in [0u64, 17, 599] {
            let p = dep.publication(id).unwrap();
            assert_eq!(p.id, id);
        }
        assert!(dep.publication(600).is_none());
    }

    #[test]
    fn search_returns_relevant_hits() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        // Query with the exact title of doc 42: it must be found.
        let title = sys.deployment().publication(42).unwrap().title.clone();
        let resp = sys.search(&title).unwrap();
        assert!(resp.jobs >= 1);
        assert!(resp.response_s() > 0.0);
        assert!(
            resp.hits.iter().any(|h| h.global_id == 42),
            "doc 42 not in {:?}",
            resp.hits.iter().map(|h| h.global_id).collect::<Vec<_>>()
        );
        assert!(resp.timeline.work_s > 0.0);
        assert!(resp.timeline.net_s > 0.0);
        assert!(resp.timeline.overhead_s > 0.0);
    }

    #[test]
    fn perf_history_populates_after_queries() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        assert!(!sys.perf_db().has_history());
        sys.search("grid data search").unwrap();
        assert!(sys.perf_db().has_history());
        assert!(sys.query_manager().completed_jobs() >= 1);
    }

    #[test]
    fn failed_node_is_routed_around() {
        let mut sys = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let victim = sys.deployment().active[1];
        sys.fail_node(victim);
        let resp = sys.search("grid computing search").unwrap();
        // All sources still searched (replicas cover the victim).
        assert_eq!(resp.docs_scanned, 600);
        // And the victim got no jobs.
        assert_eq!(sys.service_acquisitions(victim), 0);
    }

    #[test]
    fn recovery_brings_node_back() {
        let mut sys = GapsSystem::deploy(small_cfg(), 2).unwrap();
        let victim = sys.deployment().active[1];
        sys.fail_node(victim);
        sys.search("grid").unwrap();
        sys.recover_node(victim);
        sys.search("grid").unwrap();
        assert!(sys.service_acquisitions(victim) > 0);
    }

    #[test]
    fn all_replicas_down_is_an_error() {
        let mut cfg = small_cfg();
        cfg.workload.sub_shards = 2;
        let mut sys = GapsSystem::deploy(cfg, 2).unwrap();
        for &n in sys.deployment().active.clone().iter() {
            sys.fail_node(n);
        }
        assert!(sys.search("grid").is_err());
    }

    #[test]
    fn round_robin_policy_also_covers_corpus() {
        let mut cfg = small_cfg();
        cfg.search.policy = SchedulePolicy::RoundRobin;
        let mut sys = GapsSystem::deploy(cfg, 4).unwrap();
        let resp = sys.search("massive academic publications").unwrap();
        assert_eq!(resp.docs_scanned, 600);
    }

    #[test]
    fn parallel_fanout_matches_serial_results() {
        // Exact result semantics: the gridpool fan-out must return
        // byte-identical hits (ids, scores, order) to serial dispatch.
        let mut cfg_par = small_cfg();
        cfg_par.search.workers = 4;
        let mut cfg_ser = small_cfg();
        cfg_ser.search.workers = 1;
        let dep = Arc::new(Deployment::build(&cfg_par, 6).unwrap());
        let mut par = GapsSystem::from_deployment(cfg_par, Arc::clone(&dep)).unwrap();
        let mut ser = GapsSystem::from_deployment(cfg_ser, dep).unwrap();
        for q in ["grid data search", "massive academic publications", "year:2000..2014 grid"] {
            let rp = par.search(q).unwrap();
            let rs = ser.search(q).unwrap();
            let ids_p: Vec<u64> = rp.hits.iter().map(|h| h.global_id).collect();
            let ids_s: Vec<u64> = rs.hits.iter().map(|h| h.global_id).collect();
            assert_eq!(ids_p, ids_s, "hit order diverged for {q:?}");
            for (a, b) in rp.hits.iter().zip(&rs.hits) {
                assert_eq!(a.score, b.score, "score diverged for {q:?}");
            }
            assert_eq!(rp.docs_scanned, rs.docs_scanned);
            assert_eq!(rp.candidates, rs.candidates);
        }
    }

    #[test]
    fn deterministic_hits_across_runs() {
        let mut a = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let mut b = GapsSystem::deploy(small_cfg(), 4).unwrap();
        let ra = a.search("distributed grid search").unwrap();
        let rb = b.search("distributed grid search").unwrap();
        let ids_a: Vec<u64> = ra.hits.iter().map(|h| h.global_id).collect();
        let ids_b: Vec<u64> = rb.hits.iter().map(|h| h.global_id).collect();
        assert_eq!(ids_a, ids_b);
    }
}
