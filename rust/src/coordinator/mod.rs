//! GAPS coordinator — the paper's system contribution.
//!
//! Components map 1:1 onto the paper's Figure 1:
//!
//! * [`QueryExecutionEngine`] (QEE) — one instance per VO; orchestrates
//!   query execution over the grid nodes, decentralized to avoid the
//!   bottleneck the paper attributes to centralized designs.
//! * [`QueryManager`] (QM) — builds Job Description Files, tracks job
//!   execution in its job table, and records per-node performance into
//!   the perf-history database used by future plans.
//! * [`ResourceManager`] — registry of node status ("stores the status
//!   and all information about system resources").
//! * [`DataSourceLocator`] — catalog of data sources (sub-shards) and
//!   their replicas across VOs, plus corpus-global BM25 statistics.
//! * [`merge_topk`] — the distributed result merger (node -> VO broker ->
//!   root broker).
//! * [`GapsSystem`] — the deployed system facade: fabric + data + services
//!   + the `search()` entry point the USI calls.
//!
//! Data model: the corpus is split into `sub_shards` fixed-count
//! data sources, each replicated on two nodes of the same VO (grid data
//! replication). The execution plan assigns every source to exactly one
//! live replica; the GAPS policy weights assignment by perf history, the
//! round-robin policy mimics the traditional uniform split.

mod jdf;
mod locator;
mod merge;
mod perf;
mod qee;
mod qm;
mod resource_manager;
mod system;

pub use jdf::{JobDescription, JobId};
pub use locator::{DataSource, DataSourceLocator};
pub use merge::{merge_topk, result_wire_bytes};
pub use perf::PerfDb;
pub use qee::{ExecutionPlan, QueryExecutionEngine};
pub use qm::{JobStatus, QueryManager};
pub use resource_manager::ResourceManager;
pub use system::{
    counters_from_json, counters_to_json, CorpusData, Deployment, Explain, FailoverStats,
    GapsSystem, Hit, IndexHealth, IngestReport, SearchResponse,
};
