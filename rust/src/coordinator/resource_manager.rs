//! Resource Manager: node status registry.
//!
//! Paper: the QEE "will request the resources information from the
//! Resource Manager, who stores the status and all information about
//! system resources." Nodes heartbeat; missing heartbeats mark a node
//! Down (grid dynamicity — "organizations resources that join or leaves
//! the system at any time"), and plans route around it.
//!
//! Downed nodes are not dead forever: they enter *probation*. After
//! [`ResourceManager::probe_due`] reports a node's down-time exceeding
//! the probation window, the coordinator probes it and feeds the result
//! back via [`ResourceManager::record_probe`] — a healthy probe rejoins
//! the node, a failed one restarts its probation clock.

use std::collections::BTreeMap;

use crate::grid::{NodeId, NodeInfo, NodeStatus};

/// Registry entry.
#[derive(Debug, Clone)]
struct Entry {
    info: NodeInfo,
    status: NodeStatus,
    /// Logical timestamp of the last heartbeat.
    last_heartbeat: u64,
    /// Logical timestamp at which the node went Down (probation clock).
    down_at: Option<u64>,
}

/// The resource registry.
#[derive(Debug, Default)]
pub struct ResourceManager {
    nodes: BTreeMap<NodeId, Entry>,
    /// Heartbeats older than this (in ticks) mark a node Down.
    stale_after: u64,
    now: u64,
}

impl ResourceManager {
    pub fn new(stale_after: u64) -> Self {
        ResourceManager { nodes: BTreeMap::new(), stale_after, now: 0 }
    }

    /// Register a node (joins Up).
    pub fn register(&mut self, info: NodeInfo) {
        self.nodes.insert(
            info.id,
            Entry { info, status: NodeStatus::Up, last_heartbeat: self.now, down_at: None },
        );
    }

    /// Record a heartbeat from a node; re-joins a Down node.
    pub fn heartbeat(&mut self, id: NodeId) {
        if let Some(e) = self.nodes.get_mut(&id) {
            e.last_heartbeat = self.now;
            e.status = NodeStatus::Up;
            e.down_at = None;
        }
    }

    /// Advance the logical clock and expire stale nodes.
    pub fn tick(&mut self) {
        self.now += 1;
        for e in self.nodes.values_mut() {
            if e.status == NodeStatus::Up && self.now - e.last_heartbeat > self.stale_after {
                e.status = NodeStatus::Down;
                e.down_at = Some(self.now);
            }
        }
    }

    /// One coordinator round: every currently-Up node heartbeats (the
    /// fabric is simulated in-process, so a node that has not been
    /// *observed* failing is presumed alive), then the clock ticks. This
    /// is what advances probation clocks between search batches.
    pub fn begin_round(&mut self) {
        let up: Vec<NodeId> = self
            .nodes
            .values()
            .filter(|e| e.status == NodeStatus::Up)
            .map(|e| e.info.id)
            .collect();
        for id in up {
            self.heartbeat(id);
        }
        self.tick();
    }

    /// Explicitly mark a node down (failure injection / mid-flight job
    /// failure).
    pub fn mark_down(&mut self, id: NodeId) {
        if let Some(e) = self.nodes.get_mut(&id) {
            if e.status != NodeStatus::Down {
                e.status = NodeStatus::Down;
                e.down_at = Some(self.now);
            }
        }
    }

    /// Down nodes whose probation window (`after` ticks since they went
    /// down) has elapsed — the coordinator should health-probe these.
    pub fn probe_due(&self, after: u64) -> Vec<NodeId> {
        self.nodes
            .values()
            .filter(|e| e.status == NodeStatus::Down)
            .filter(|e| e.down_at.map(|d| self.now.saturating_sub(d) >= after).unwrap_or(true))
            .map(|e| e.info.id)
            .collect()
    }

    /// Feed back a health-probe result: a healthy node rejoins
    /// immediately, an unhealthy one restarts its probation clock.
    pub fn record_probe(&mut self, id: NodeId, healthy: bool) {
        if healthy {
            self.heartbeat(id);
        } else if let Some(e) = self.nodes.get_mut(&id) {
            e.down_at = Some(self.now);
        }
    }

    pub fn status(&self, id: NodeId) -> Option<NodeStatus> {
        self.nodes.get(&id).map(|e| e.status)
    }

    pub fn info(&self, id: NodeId) -> Option<&NodeInfo> {
        self.nodes.get(&id).map(|e| &e.info)
    }

    /// All Up nodes, ordered by id.
    pub fn available(&self) -> Vec<NodeInfo> {
        self.nodes
            .values()
            .filter(|e| e.status == NodeStatus::Up)
            .map(|e| e.info.clone())
            .collect()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::VoId;

    fn info(id: u32) -> NodeInfo {
        NodeInfo { id: NodeId(id), vo: VoId(id / 4), speed_factor: 1.0, is_broker: id % 4 == 0 }
    }

    #[test]
    fn register_and_available() {
        let mut rm = ResourceManager::new(3);
        for i in 0..5 {
            rm.register(info(i));
        }
        assert_eq!(rm.len(), 5);
        assert_eq!(rm.available().len(), 5);
        assert_eq!(rm.status(NodeId(2)), Some(NodeStatus::Up));
        assert_eq!(rm.status(NodeId(9)), None);
    }

    #[test]
    fn stale_nodes_expire() {
        let mut rm = ResourceManager::new(2);
        rm.register(info(0));
        rm.register(info(1));
        for _ in 0..3 {
            rm.tick();
            rm.heartbeat(NodeId(0)); // only node 0 heartbeats
        }
        assert_eq!(rm.status(NodeId(0)), Some(NodeStatus::Up));
        assert_eq!(rm.status(NodeId(1)), Some(NodeStatus::Down));
        assert_eq!(rm.available().len(), 1);
    }

    #[test]
    fn down_node_rejoins_on_heartbeat() {
        let mut rm = ResourceManager::new(1);
        rm.register(info(0));
        rm.mark_down(NodeId(0));
        assert_eq!(rm.available().len(), 0);
        rm.heartbeat(NodeId(0));
        assert_eq!(rm.status(NodeId(0)), Some(NodeStatus::Up));
    }

    #[test]
    fn mark_down_is_immediate() {
        let mut rm = ResourceManager::new(100);
        rm.register(info(0));
        rm.mark_down(NodeId(0));
        assert_eq!(rm.status(NodeId(0)), Some(NodeStatus::Down));
    }

    #[test]
    fn probation_elapses_before_probe_is_due() {
        let mut rm = ResourceManager::new(3);
        rm.register(info(0));
        rm.register(info(1));
        rm.begin_round();
        rm.mark_down(NodeId(0));
        // Freshly downed: not yet due with a 2-tick probation window.
        assert!(rm.probe_due(2).is_empty());
        rm.begin_round();
        assert!(rm.probe_due(2).is_empty(), "only 1 tick since mark_down");
        rm.begin_round();
        assert_eq!(rm.probe_due(2), vec![NodeId(0)]);
        // Up nodes never show up as probe candidates.
        assert!(!rm.probe_due(0).contains(&NodeId(1)));
    }

    #[test]
    fn probe_results_rejoin_or_rearm() {
        let mut rm = ResourceManager::new(3);
        rm.register(info(0));
        rm.mark_down(NodeId(0));
        rm.begin_round();
        rm.begin_round();
        assert_eq!(rm.probe_due(2), vec![NodeId(0)]);
        // Unhealthy probe restarts the probation clock.
        rm.record_probe(NodeId(0), false);
        assert!(rm.probe_due(2).is_empty());
        rm.begin_round();
        rm.begin_round();
        assert_eq!(rm.probe_due(2), vec![NodeId(0)]);
        // Healthy probe rejoins.
        rm.record_probe(NodeId(0), true);
        assert_eq!(rm.status(NodeId(0)), Some(NodeStatus::Up));
        assert!(rm.probe_due(0).is_empty());
    }

    #[test]
    fn begin_round_keeps_up_nodes_alive() {
        // begin_round's implicit heartbeats mean the logical clock can
        // advance arbitrarily without expiring healthy nodes.
        let mut rm = ResourceManager::new(2);
        rm.register(info(0));
        for _ in 0..10 {
            rm.begin_round();
        }
        assert_eq!(rm.status(NodeId(0)), Some(NodeStatus::Up));
    }
}
