//! Job Description File (JDF).
//!
//! Paper: "the QM creates the Job Description File (JDF) with all jobs
//! that will be distributed over grid nodes. The JDF contains the location
//! of all data sources and the local search services that will participate
//! on the search process ... the user query text as well as the location
//! that should receive the result of the search."
//!
//! JDFs serialize to JSON; their byte length is what the network model
//! charges for dispatch transfers.

use crate::grid::NodeId;
use crate::util::json::Json;

/// Grid-wide job identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job{}", self.0)
    }
}

/// One search job: a query to run over a set of data sources on a node.
#[derive(Debug, Clone, PartialEq)]
pub struct JobDescription {
    pub id: JobId,
    /// Raw query text (the worker re-parses against its local analyzer —
    /// the paper ships query text, not parsed structures).
    pub query: String,
    /// Executing node.
    pub node: NodeId,
    /// Data source ids (sub-shards) this job must search.
    pub sources: Vec<u32>,
    /// Node that receives the result (the VO broker).
    pub reply_to: NodeId,
    /// Results wanted per query.
    pub top_k: usize,
}

impl JobDescription {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::from(self.id.0)),
            ("query", Json::str(&self.query)),
            ("node", Json::from(self.node.0 as i64)),
            ("sources", Json::Arr(self.sources.iter().map(|s| Json::from(*s as i64)).collect())),
            ("reply_to", Json::from(self.reply_to.0 as i64)),
            ("top_k", Json::from(self.top_k)),
        ])
    }

    /// Parse from the JSON wire form.
    pub fn from_json(v: &Json) -> Option<JobDescription> {
        Some(JobDescription {
            id: JobId(v.get("id")?.as_i64()? as u64),
            query: v.get("query")?.as_str()?.to_string(),
            node: NodeId(v.get("node")?.as_i64()? as u32),
            sources: v
                .get("sources")?
                .as_arr()?
                .iter()
                .map(|x| x.as_i64().map(|i| i as u32))
                .collect::<Option<Vec<_>>>()?,
            reply_to: NodeId(v.get("reply_to")?.as_i64()? as u32),
            top_k: v.get("top_k")?.as_i64()? as usize,
        })
    }

    /// Wire size in bytes (charged to the network model).
    pub fn wire_bytes(&self) -> usize {
        self.to_json().to_string_compact().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> JobDescription {
        JobDescription {
            id: JobId(7),
            query: "grid computing year:2010..2014".into(),
            node: NodeId(3),
            sources: vec![1, 5, 9],
            reply_to: NodeId(0),
            top_k: 10,
        }
    }

    #[test]
    fn json_roundtrip() {
        let jdf = sample();
        let parsed = JobDescription::from_json(&jdf.to_json()).unwrap();
        assert_eq!(parsed, jdf);
    }

    #[test]
    fn wire_bytes_reflect_content() {
        let small = sample();
        let mut big = sample();
        big.sources = (0..100).collect();
        assert!(big.wire_bytes() > small.wire_bytes());
        assert!(small.wire_bytes() > 50);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let v = Json::parse(r#"{"id": 1}"#).unwrap();
        assert!(JobDescription::from_json(&v).is_none());
    }
}
