//! Job Description File (JDF).
//!
//! Paper: "the QM creates the Job Description File (JDF) with all jobs
//! that will be distributed over grid nodes. The JDF contains the location
//! of all data sources and the local search services that will participate
//! on the search process ... the user query text as well as the location
//! that should receive the result of the search."
//!
//! A JDF now carries the whole **typed request batch** (one
//! [`SearchRequest`] per query) rather than one raw query string: the
//! request's JSON wire form is shared between the JDF, the response
//! envelope, and a future HTTP front-end, so every boundary speaks one
//! serialization. JDF byte length is what the network model charges for
//! dispatch transfers.

use std::sync::Arc;

use crate::grid::NodeId;
use crate::search::SearchRequest;
use crate::util::json::Json;

/// Grid-wide job identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job{}", self.0)
    }
}

/// One search job: a request batch to run over a set of data sources on
/// a node.
#[derive(Debug, Clone, PartialEq)]
pub struct JobDescription {
    pub id: JobId,
    /// The typed request batch, shared across the batch's JDFs (one
    /// `Arc` per fan-out, not one clone per node — the QM's job table
    /// retains every JDF it ever made). Workers re-compile against
    /// their local analyzer: the paper ships query text, not parsed
    /// structures.
    pub requests: Arc<Vec<SearchRequest>>,
    /// Executing node.
    pub node: NodeId,
    /// Data source ids (sub-shards) this job must search.
    pub sources: Vec<u32>,
    /// Node that receives the result (the VO broker).
    pub reply_to: NodeId,
}

impl JobDescription {
    /// Serialize to the JSON wire form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::from(self.id.0)),
            ("requests", Json::Arr(self.requests.iter().map(|r| r.to_json()).collect())),
            ("node", Json::from(self.node.0 as i64)),
            ("sources", Json::Arr(self.sources.iter().map(|s| Json::from(*s as i64)).collect())),
            ("reply_to", Json::from(self.reply_to.0 as i64)),
        ])
    }

    /// Parse from the JSON wire form.
    pub fn from_json(v: &Json) -> Option<JobDescription> {
        Some(JobDescription {
            id: JobId(v.get("id")?.as_i64()? as u64),
            requests: Arc::new(
                v.get("requests")?
                    .as_arr()?
                    .iter()
                    .map(SearchRequest::from_json)
                    .collect::<Option<Vec<_>>>()?,
            ),
            node: NodeId(v.get("node")?.as_i64()? as u32),
            sources: v
                .get("sources")?
                .as_arr()?
                .iter()
                .map(|x| x.as_i64().map(|i| i as u32))
                .collect::<Option<Vec<_>>>()?,
            reply_to: NodeId(v.get("reply_to")?.as_i64()? as u32),
        })
    }

    /// Wire size in bytes (charged to the network model).
    pub fn wire_bytes(&self) -> usize {
        self.to_json().to_string_compact().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::ReplicaPref;

    fn sample() -> JobDescription {
        JobDescription {
            id: JobId(7),
            requests: Arc::new(vec![
                SearchRequest::new("grid computing year:2010..2014"),
                SearchRequest::new("\"data replication\"")
                    .top_k(5)
                    .prefer_replicas(ReplicaPref::SameVo),
            ]),
            node: NodeId(3),
            sources: vec![1, 5, 9],
            reply_to: NodeId(0),
        }
    }

    #[test]
    fn json_roundtrip() {
        let jdf = sample();
        let parsed = JobDescription::from_json(&jdf.to_json()).unwrap();
        assert_eq!(parsed, jdf);
    }

    #[test]
    fn wire_bytes_reflect_content() {
        let small = sample();
        let mut big = sample();
        big.sources = (0..100).collect();
        assert!(big.wire_bytes() > small.wire_bytes());
        assert!(small.wire_bytes() > 50);
        // A bigger batch also costs more wire.
        let mut batched = sample();
        let mut reqs = (*batched.requests).clone();
        reqs.extend((0..8).map(|i| SearchRequest::new(format!("query {i}"))));
        batched.requests = Arc::new(reqs);
        assert!(batched.wire_bytes() > small.wire_bytes());
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let v = Json::parse(r#"{"id": 1}"#).unwrap();
        assert!(JobDescription::from_json(&v).is_none());
    }

    #[test]
    fn request_serialization_is_shared_with_the_jdf() {
        // The JDF embeds SearchRequest::to_json verbatim: parsing the
        // embedded object with the request parser yields the request.
        let jdf = sample();
        let wire = jdf.to_json();
        let embedded = wire.get("requests").unwrap().as_arr().unwrap();
        assert_eq!(SearchRequest::from_json(&embedded[0]).unwrap(), jdf.requests[0]);
    }
}
