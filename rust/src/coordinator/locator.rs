//! Data Source Locator: catalog of data sources and their replicas.
//!
//! Paper: "The lists of the data sources that are involved in the search
//! task are gathered from the Data Source Locator component." A data
//! source here is one sub-shard of the corpus (a JSONL "file" of article
//! records in the paper's terms), replicated on two nodes of the same VO
//! (grid data replication). The locator also aggregates corpus-global
//! BM25 statistics so all nodes rank with consistent IDF — that is what
//! makes distributed top-k lists mergeable.

use std::collections::BTreeMap;

use crate::grid::NodeId;
use crate::index::{GlobalStats, ShardStats};

/// One registered data source (sub-shard of the corpus).
#[derive(Debug, Clone, PartialEq)]
pub struct DataSource {
    pub id: u32,
    /// First corpus-global doc id in the source.
    pub doc_start: u64,
    /// Number of documents.
    pub doc_count: u64,
    /// Nodes hosting a replica (first = primary), all in one VO.
    pub replicas: Vec<NodeId>,
}

/// The catalog.
#[derive(Debug, Default)]
pub struct DataSourceLocator {
    sources: BTreeMap<u32, DataSource>,
    stats_acc: Option<ShardStats>,
}

impl DataSourceLocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a source and fold its shard statistics into the global
    /// accumulator.
    pub fn register(&mut self, source: DataSource, stats: &ShardStats) {
        assert!(!source.replicas.is_empty(), "source without replicas");
        match &mut self.stats_acc {
            Some(acc) => acc.merge(stats),
            None => self.stats_acc = Some(stats.clone()),
        }
        self.sources.insert(source.id, source);
    }

    /// All sources ordered by id.
    pub fn sources(&self) -> Vec<&DataSource> {
        self.sources.values().collect()
    }

    pub fn source(&self, id: u32) -> Option<&DataSource> {
        self.sources.get(&id)
    }

    pub fn len(&self) -> usize {
        self.sources.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sources.is_empty()
    }

    /// Total documents across sources.
    pub fn total_docs(&self) -> u64 {
        self.sources.values().map(|s| s.doc_count).sum()
    }

    /// Corpus-global statistics (after all sources registered).
    pub fn global_stats(&self) -> Option<GlobalStats> {
        self.stats_acc.as_ref().map(|acc| acc.finalize())
    }

    /// Sources hosted (as any replica) by `node`.
    pub fn sources_on(&self, node: NodeId) -> Vec<&DataSource> {
        self.sources.values().filter(|s| s.replicas.contains(&node)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(n: u64) -> ShardStats {
        let mut s = ShardStats::empty(16);
        s.num_docs = n;
        s.df[3] = n.min(2);
        s.field_len_sum = [5.0 * n as f64, 90.0 * n as f64, 4.0 * n as f64, 3.0 * n as f64];
        s
    }

    fn src(id: u32, start: u64, count: u64, nodes: &[u32]) -> DataSource {
        DataSource {
            id,
            doc_start: start,
            doc_count: count,
            replicas: nodes.iter().map(|&n| NodeId(n)).collect(),
        }
    }

    #[test]
    fn register_and_lookup() {
        let mut loc = DataSourceLocator::new();
        loc.register(src(0, 0, 100, &[0, 1]), &stats(100));
        loc.register(src(1, 100, 50, &[1, 2]), &stats(50));
        assert_eq!(loc.len(), 2);
        assert_eq!(loc.total_docs(), 150);
        assert_eq!(loc.source(1).unwrap().doc_start, 100);
        assert_eq!(loc.sources_on(NodeId(1)).len(), 2);
        assert_eq!(loc.sources_on(NodeId(2)).len(), 1);
        assert_eq!(loc.sources_on(NodeId(9)).len(), 0);
    }

    #[test]
    fn global_stats_aggregate() {
        let mut loc = DataSourceLocator::new();
        assert!(loc.global_stats().is_none());
        loc.register(src(0, 0, 100, &[0]), &stats(100));
        loc.register(src(1, 100, 50, &[1]), &stats(50));
        let g = loc.global_stats().unwrap();
        assert_eq!(g.total_docs, 150);
        assert_eq!(g.df[3], 4); // 2 + 2
        assert!((g.avg_field_len[1] - 90.0).abs() < 1e-3);
    }

    #[test]
    #[should_panic(expected = "without replicas")]
    fn empty_replicas_rejected() {
        let mut loc = DataSourceLocator::new();
        loc.register(src(0, 0, 10, &[]), &stats(10));
    }
}
