//! Query Execution Engine (QEE): turns (query batch, sources, resources,
//! perf history) into an execution plan.
//!
//! Paper: "The QEE determines the nodes that will perform a search at run
//! time by utilizing its internal modules ... The execution plan that
//! distributes the datasets over the nodes depends on the previous
//! performance and produces the best combination to handle the query."
//!
//! The GAPS policy is a throughput-weighted LPT greedy: sources (largest
//! first) go to the live replica that will finish earliest under the
//! perf-history throughput estimates. The round-robin policy (used by the
//! traditional baseline and as an ablation) ignores history and speeds.
//! A request's [`ReplicaPref`] narrows the replica choice before either
//! policy runs (replicas host identical data, so preference shifts where
//! work runs, never what is returned).

use std::collections::BTreeMap;

use crate::config::SchedulePolicy;
use crate::grid::{NodeId, NodeInfo, VoId};
use crate::search::{ReplicaPref, SearchError};

use super::locator::DataSource;
use super::perf::PerfDb;

/// Node -> assigned source ids. Every input source appears exactly once.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    pub assignments: BTreeMap<NodeId, Vec<u32>>,
}

impl ExecutionPlan {
    /// Total sources assigned.
    pub fn num_sources(&self) -> usize {
        self.assignments.values().map(|v| v.len()).sum()
    }

    /// Nodes participating in the plan.
    pub fn nodes(&self) -> Vec<NodeId> {
        self.assignments.keys().copied().collect()
    }
}

/// The planner. One QEE instance runs on each VO broker; the root QEE
/// plans globally and hands each VO's QEE its own slice (see
/// `coordinator::system` for the dispatch topology).
#[derive(Debug, Default)]
pub struct QueryExecutionEngine;

impl QueryExecutionEngine {
    /// Build an execution plan covering every source exactly once, using
    /// only `available` nodes. `pref` narrows replica choice; `home_vo`
    /// anchors [`ReplicaPref::SameVo`] (the root broker's VO).
    pub fn plan(
        &self,
        sources: &[&DataSource],
        available: &[NodeInfo],
        perf: &PerfDb,
        policy: SchedulePolicy,
        pref: ReplicaPref,
        home_vo: Option<VoId>,
    ) -> Result<ExecutionPlan, SearchError> {
        let (plan, uncovered) =
            self.plan_partial(sources, available, perf, policy, pref, home_vo)?;
        if let Some(&source) = uncovered.first() {
            return Err(SearchError::NoLiveReplica { source });
        }
        Ok(plan)
    }

    /// Like [`QueryExecutionEngine::plan`], but sources with no live
    /// replica do not fail the plan: they are returned as the sorted
    /// `uncovered` list alongside a plan over the coverable sources.
    /// This is the planning primitive for mid-flight failover and
    /// `allow_partial` degradation (the caller decides whether uncovered
    /// sources are an error or a truthful gap). Errors only on empty
    /// inputs (`NoSources` / `NoNodes`).
    pub fn plan_partial(
        &self,
        sources: &[&DataSource],
        available: &[NodeInfo],
        perf: &PerfDb,
        policy: SchedulePolicy,
        pref: ReplicaPref,
        home_vo: Option<VoId>,
    ) -> Result<(ExecutionPlan, Vec<u32>), SearchError> {
        if sources.is_empty() {
            return Err(SearchError::NoSources);
        }
        let live: std::collections::BTreeSet<NodeId> =
            available.iter().map(|n| n.id).collect();
        if live.is_empty() {
            return Err(SearchError::NoNodes);
        }
        let vo_of: BTreeMap<NodeId, VoId> = available.iter().map(|n| (n.id, n.vo)).collect();

        // Per-source candidate replicas: live, narrowed by preference
        // (falling back to all live replicas when the preference cannot
        // be honored — availability beats affinity). `None`: no live
        // replica at all.
        let candidates = |s: &DataSource| -> Option<Vec<NodeId>> {
            let live_replicas: Vec<NodeId> =
                s.replicas.iter().copied().filter(|r| live.contains(r)).collect();
            if live_replicas.is_empty() {
                return None;
            }
            let preferred: Vec<NodeId> = match pref {
                ReplicaPref::Any => live_replicas.clone(),
                ReplicaPref::Primary => s
                    .replicas
                    .first()
                    .filter(|p| live.contains(*p))
                    .map(|p| vec![*p])
                    .unwrap_or_default(),
                ReplicaPref::SameVo => match home_vo {
                    Some(h) => live_replicas
                        .iter()
                        .copied()
                        .filter(|r| vo_of.get(r) == Some(&h))
                        .collect(),
                    None => Vec::new(),
                },
            };
            Some(if preferred.is_empty() { live_replicas } else { preferred })
        };

        let mut assignments: BTreeMap<NodeId, Vec<u32>> = BTreeMap::new();
        let mut uncovered: Vec<u32> = Vec::new();
        match policy {
            SchedulePolicy::RoundRobin => {
                for s in sources {
                    let Some(replicas) = candidates(s) else {
                        uncovered.push(s.id);
                        continue;
                    };
                    // Rotate across replicas by source id: uniform spread,
                    // blind to node speed.
                    let node = replicas[s.id as usize % replicas.len()];
                    assignments.entry(node).or_default().push(s.id);
                }
            }
            SchedulePolicy::PerfHistory => {
                // LPT greedy weighted by estimated throughput.
                let mut order: Vec<&&DataSource> = sources.iter().collect();
                order.sort_by(|a, b| b.doc_count.cmp(&a.doc_count).then(a.id.cmp(&b.id)));
                let mut load_docs: BTreeMap<NodeId, f64> = BTreeMap::new();
                for s in order {
                    let Some(replicas) = candidates(s) else {
                        uncovered.push(s.id);
                        continue;
                    };
                    let mut best: Option<(f64, NodeId)> = None;
                    for r in replicas {
                        let tput = perf.estimate(r).max(1e-9);
                        let finish =
                            (load_docs.get(&r).copied().unwrap_or(0.0) + s.doc_count as f64) / tput;
                        if best.map(|(bf, _)| finish < bf).unwrap_or(true) {
                            best = Some((finish, r));
                        }
                    }
                    let (_, node) = best.expect("candidates() returns non-empty lists");
                    *load_docs.entry(node).or_default() += s.doc_count as f64;
                    assignments.entry(node).or_default().push(s.id);
                }
                for list in assignments.values_mut() {
                    list.sort_unstable();
                }
            }
        }
        uncovered.sort_unstable();
        Ok((ExecutionPlan { assignments }, uncovered))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::VoId;

    fn node(id: u32) -> NodeInfo {
        NodeInfo { id: NodeId(id), vo: VoId(id / 4), speed_factor: 1.0, is_broker: false }
    }

    fn src(id: u32, count: u64, replicas: &[u32]) -> DataSource {
        DataSource {
            id,
            doc_start: id as u64 * 1000,
            doc_count: count,
            replicas: replicas.iter().map(|&r| NodeId(r)).collect(),
        }
    }

    fn plan_any(
        sources: &[&DataSource],
        avail: &[NodeInfo],
        perf: &PerfDb,
        policy: SchedulePolicy,
    ) -> Result<ExecutionPlan, SearchError> {
        QueryExecutionEngine.plan(sources, avail, perf, policy, ReplicaPref::Any, None)
    }

    #[test]
    fn covers_every_source_exactly_once() {
        let sources = vec![
            src(0, 100, &[0, 1]),
            src(1, 100, &[1, 2]),
            src(2, 100, &[2, 0]),
            src(3, 100, &[0, 2]),
        ];
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0), node(1), node(2)];
        for policy in [SchedulePolicy::PerfHistory, SchedulePolicy::RoundRobin] {
            let plan = plan_any(&refs, &avail, &PerfDb::default(), policy).unwrap();
            assert_eq!(plan.num_sources(), 4, "{policy:?}");
            let mut all: Vec<u32> =
                plan.assignments.values().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn perf_history_prefers_fast_nodes() {
        // Node 0 measured 4x faster than node 1; both host everything.
        let sources: Vec<DataSource> =
            (0..8).map(|i| src(i, 100, &[0, 1])).collect();
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0), node(1)];
        let mut perf = PerfDb::default();
        for _ in 0..5 {
            perf.record(NodeId(0), 400, 1.0);
            perf.record(NodeId(1), 100, 1.0);
        }
        let plan = plan_any(&refs, &avail, &perf, SchedulePolicy::PerfHistory).unwrap();
        let n0 = plan.assignments.get(&NodeId(0)).map(|v| v.len()).unwrap_or(0);
        let n1 = plan.assignments.get(&NodeId(1)).map(|v| v.len()).unwrap_or(0);
        assert!(n0 > n1, "fast node got {n0}, slow got {n1}");
        // Roughly 4:1 (within LPT granularity): 6-7 vs 1-2.
        assert!(n0 >= 6, "expected ~4:1 split, got {n0}:{n1}");
    }

    #[test]
    fn round_robin_is_blind_to_speed() {
        let sources: Vec<DataSource> =
            (0..8).map(|i| src(i, 100, &[0, 1])).collect();
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0), node(1)];
        let mut perf = PerfDb::default();
        perf.record(NodeId(0), 1000, 1.0);
        let plan = plan_any(&refs, &avail, &perf, SchedulePolicy::RoundRobin).unwrap();
        let n0 = plan.assignments.get(&NodeId(0)).map(|v| v.len()).unwrap_or(0);
        let n1 = plan.assignments.get(&NodeId(1)).map(|v| v.len()).unwrap_or(0);
        assert_eq!(n0, 4);
        assert_eq!(n1, 4);
    }

    #[test]
    fn avoids_down_nodes() {
        let sources = vec![src(0, 100, &[0, 1]), src(1, 100, &[0, 1])];
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(1)]; // node 0 is down
        for policy in [SchedulePolicy::PerfHistory, SchedulePolicy::RoundRobin] {
            let plan = plan_any(&refs, &avail, &PerfDb::default(), policy).unwrap();
            assert_eq!(plan.nodes(), vec![NodeId(1)], "{policy:?}");
        }
    }

    #[test]
    fn unreachable_source_is_a_typed_error() {
        let sources = vec![src(0, 100, &[5])];
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0)];
        let err =
            plan_any(&refs, &avail, &PerfDb::default(), SchedulePolicy::PerfHistory).unwrap_err();
        assert_eq!(err, SearchError::NoLiveReplica { source: 0 });
    }

    #[test]
    fn partial_plan_reports_uncovered_sources() {
        // Source 1 only lives on a down node; sources 0 and 2 are fine.
        let sources = vec![src(0, 100, &[0]), src(1, 100, &[5]), src(2, 100, &[0])];
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0)];
        for policy in [SchedulePolicy::PerfHistory, SchedulePolicy::RoundRobin] {
            let (plan, uncovered) = QueryExecutionEngine
                .plan_partial(&refs, &avail, &PerfDb::default(), policy, ReplicaPref::Any, None)
                .unwrap();
            assert_eq!(uncovered, vec![1], "{policy:?}");
            assert_eq!(plan.num_sources(), 2, "{policy:?}");
            assert_eq!(plan.nodes(), vec![NodeId(0)], "{policy:?}");
        }
    }

    #[test]
    fn empty_inputs_rejected() {
        assert_eq!(
            plan_any(&[], &[node(0)], &PerfDb::default(), SchedulePolicy::PerfHistory)
                .unwrap_err(),
            SearchError::NoSources
        );
        let sources = vec![src(0, 1, &[0])];
        let refs: Vec<&DataSource> = sources.iter().collect();
        assert_eq!(
            plan_any(&refs, &[], &PerfDb::default(), SchedulePolicy::PerfHistory).unwrap_err(),
            SearchError::NoNodes
        );
    }

    #[test]
    fn balanced_load_with_equal_speeds() {
        let sources: Vec<DataSource> =
            (0..12).map(|i| src(i, 50, &[i % 3, (i % 3 + 1) % 3])).collect();
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0), node(1), node(2)];
        let plan =
            plan_any(&refs, &avail, &PerfDb::default(), SchedulePolicy::PerfHistory).unwrap();
        for n in plan.assignments.values() {
            assert_eq!(n.len(), 4, "uniform speeds => equal split: {plan:?}");
        }
    }

    #[test]
    fn primary_pref_pins_live_primaries() {
        let sources: Vec<DataSource> = (0..4).map(|i| src(i, 100, &[1, 0])).collect();
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0), node(1)];
        let plan = QueryExecutionEngine
            .plan(
                &refs,
                &avail,
                &PerfDb::default(),
                SchedulePolicy::PerfHistory,
                ReplicaPref::Primary,
                None,
            )
            .unwrap();
        // Every source's primary is node 1 and it is live: all jobs there.
        assert_eq!(plan.nodes(), vec![NodeId(1)]);
        // Primary down: falls back to the secondary instead of failing.
        let plan2 = QueryExecutionEngine
            .plan(
                &refs,
                &[node(0)],
                &PerfDb::default(),
                SchedulePolicy::PerfHistory,
                ReplicaPref::Primary,
                None,
            )
            .unwrap();
        assert_eq!(plan2.nodes(), vec![NodeId(0)]);
    }

    #[test]
    fn same_vo_pref_keeps_work_home_when_possible() {
        // Nodes 0..4 are VO 0, nodes 4..8 are VO 1 (node() maps id/4).
        let sources: Vec<DataSource> = (0..4).map(|i| src(i, 100, &[4, 0])).collect();
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail: Vec<NodeInfo> = (0..8).map(node).collect();
        let plan = QueryExecutionEngine
            .plan(
                &refs,
                &avail,
                &PerfDb::default(),
                SchedulePolicy::PerfHistory,
                ReplicaPref::SameVo,
                Some(VoId(0)),
            )
            .unwrap();
        // The VO-0 replica (node 0) hosts everything.
        assert_eq!(plan.nodes(), vec![NodeId(0)]);
    }
}
