//! Query Execution Engine (QEE): turns (query, sources, resources, perf
//! history) into an execution plan.
//!
//! Paper: "The QEE determines the nodes that will perform a search at run
//! time by utilizing its internal modules ... The execution plan that
//! distributes the datasets over the nodes depends on the previous
//! performance and produces the best combination to handle the query."
//!
//! The GAPS policy is a throughput-weighted LPT greedy: sources (largest
//! first) go to the live replica that will finish earliest under the
//! perf-history throughput estimates. The round-robin policy (used by the
//! traditional baseline and as an ablation) ignores history and speeds.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::SchedulePolicy;
use crate::grid::{NodeId, NodeInfo};

use super::locator::DataSource;
use super::perf::PerfDb;

/// Node -> assigned source ids. Every input source appears exactly once.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    pub assignments: BTreeMap<NodeId, Vec<u32>>,
}

impl ExecutionPlan {
    /// Total sources assigned.
    pub fn num_sources(&self) -> usize {
        self.assignments.values().map(|v| v.len()).sum()
    }

    /// Nodes participating in the plan.
    pub fn nodes(&self) -> Vec<NodeId> {
        self.assignments.keys().copied().collect()
    }
}

/// The planner. One QEE instance runs on each VO broker; the root QEE
/// plans globally and hands each VO's QEE its own slice (see
/// `coordinator::system` for the dispatch topology).
#[derive(Debug, Default)]
pub struct QueryExecutionEngine;

impl QueryExecutionEngine {
    /// Build an execution plan covering every source exactly once, using
    /// only `available` nodes.
    pub fn plan(
        &self,
        sources: &[&DataSource],
        available: &[NodeInfo],
        perf: &PerfDb,
        policy: SchedulePolicy,
    ) -> Result<ExecutionPlan> {
        if sources.is_empty() {
            bail!("no data sources registered");
        }
        let live: std::collections::BTreeSet<NodeId> =
            available.iter().map(|n| n.id).collect();
        if live.is_empty() {
            bail!("no nodes available");
        }

        let mut assignments: BTreeMap<NodeId, Vec<u32>> = BTreeMap::new();
        match policy {
            SchedulePolicy::RoundRobin => {
                for s in sources {
                    let replicas: Vec<NodeId> = s
                        .replicas
                        .iter()
                        .copied()
                        .filter(|r| live.contains(r))
                        .collect();
                    if replicas.is_empty() {
                        bail!("source {} has no live replica", s.id);
                    }
                    // Rotate across replicas by source id: uniform spread,
                    // blind to node speed.
                    let node = replicas[s.id as usize % replicas.len()];
                    assignments.entry(node).or_default().push(s.id);
                }
            }
            SchedulePolicy::PerfHistory => {
                // LPT greedy weighted by estimated throughput.
                let mut order: Vec<&&DataSource> = sources.iter().collect();
                order.sort_by(|a, b| b.doc_count.cmp(&a.doc_count).then(a.id.cmp(&b.id)));
                let mut load_docs: BTreeMap<NodeId, f64> = BTreeMap::new();
                for s in order {
                    let mut best: Option<(f64, NodeId)> = None;
                    for r in &s.replicas {
                        if !live.contains(r) {
                            continue;
                        }
                        let tput = perf.estimate(*r).max(1e-9);
                        let finish =
                            (load_docs.get(r).copied().unwrap_or(0.0) + s.doc_count as f64) / tput;
                        if best.map(|(bf, _)| finish < bf).unwrap_or(true) {
                            best = Some((finish, *r));
                        }
                    }
                    let Some((_, node)) = best else {
                        bail!("source {} has no live replica", s.id);
                    };
                    *load_docs.entry(node).or_default() += s.doc_count as f64;
                    assignments.entry(node).or_default().push(s.id);
                }
                for list in assignments.values_mut() {
                    list.sort_unstable();
                }
            }
        }
        Ok(ExecutionPlan { assignments })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::VoId;

    fn node(id: u32) -> NodeInfo {
        NodeInfo { id: NodeId(id), vo: VoId(id / 4), speed_factor: 1.0, is_broker: false }
    }

    fn src(id: u32, count: u64, replicas: &[u32]) -> DataSource {
        DataSource {
            id,
            doc_start: id as u64 * 1000,
            doc_count: count,
            replicas: replicas.iter().map(|&r| NodeId(r)).collect(),
        }
    }

    #[test]
    fn covers_every_source_exactly_once() {
        let sources = vec![
            src(0, 100, &[0, 1]),
            src(1, 100, &[1, 2]),
            src(2, 100, &[2, 0]),
            src(3, 100, &[0, 2]),
        ];
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0), node(1), node(2)];
        for policy in [SchedulePolicy::PerfHistory, SchedulePolicy::RoundRobin] {
            let plan = QueryExecutionEngine
                .plan(&refs, &avail, &PerfDb::default(), policy)
                .unwrap();
            assert_eq!(plan.num_sources(), 4, "{policy:?}");
            let mut all: Vec<u32> =
                plan.assignments.values().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn perf_history_prefers_fast_nodes() {
        // Node 0 measured 4x faster than node 1; both host everything.
        let sources: Vec<DataSource> =
            (0..8).map(|i| src(i, 100, &[0, 1])).collect();
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0), node(1)];
        let mut perf = PerfDb::default();
        for _ in 0..5 {
            perf.record(NodeId(0), 400, 1.0);
            perf.record(NodeId(1), 100, 1.0);
        }
        let plan = QueryExecutionEngine
            .plan(&refs, &avail, &perf, SchedulePolicy::PerfHistory)
            .unwrap();
        let n0 = plan.assignments.get(&NodeId(0)).map(|v| v.len()).unwrap_or(0);
        let n1 = plan.assignments.get(&NodeId(1)).map(|v| v.len()).unwrap_or(0);
        assert!(n0 > n1, "fast node got {n0}, slow got {n1}");
        // Roughly 4:1 (within LPT granularity): 6-7 vs 1-2.
        assert!(n0 >= 6, "expected ~4:1 split, got {n0}:{n1}");
    }

    #[test]
    fn round_robin_is_blind_to_speed() {
        let sources: Vec<DataSource> =
            (0..8).map(|i| src(i, 100, &[0, 1])).collect();
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0), node(1)];
        let mut perf = PerfDb::default();
        perf.record(NodeId(0), 1000, 1.0);
        let plan = QueryExecutionEngine
            .plan(&refs, &avail, &perf, SchedulePolicy::RoundRobin)
            .unwrap();
        let n0 = plan.assignments.get(&NodeId(0)).map(|v| v.len()).unwrap_or(0);
        let n1 = plan.assignments.get(&NodeId(1)).map(|v| v.len()).unwrap_or(0);
        assert_eq!(n0, 4);
        assert_eq!(n1, 4);
    }

    #[test]
    fn avoids_down_nodes() {
        let sources = vec![src(0, 100, &[0, 1]), src(1, 100, &[0, 1])];
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(1)]; // node 0 is down
        for policy in [SchedulePolicy::PerfHistory, SchedulePolicy::RoundRobin] {
            let plan = QueryExecutionEngine
                .plan(&refs, &avail, &PerfDb::default(), policy)
                .unwrap();
            assert_eq!(plan.nodes(), vec![NodeId(1)], "{policy:?}");
        }
    }

    #[test]
    fn unreachable_source_is_an_error() {
        let sources = vec![src(0, 100, &[5])];
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0)];
        let err = QueryExecutionEngine
            .plan(&refs, &avail, &PerfDb::default(), SchedulePolicy::PerfHistory)
            .unwrap_err();
        assert!(err.to_string().contains("no live replica"));
    }

    #[test]
    fn empty_inputs_rejected() {
        let qee = QueryExecutionEngine;
        assert!(qee
            .plan(&[], &[node(0)], &PerfDb::default(), SchedulePolicy::PerfHistory)
            .is_err());
        let sources = vec![src(0, 1, &[0])];
        let refs: Vec<&DataSource> = sources.iter().collect();
        assert!(qee
            .plan(&refs, &[], &PerfDb::default(), SchedulePolicy::PerfHistory)
            .is_err());
    }

    #[test]
    fn balanced_load_with_equal_speeds() {
        let sources: Vec<DataSource> =
            (0..12).map(|i| src(i, 50, &[i % 3, (i % 3 + 1) % 3])).collect();
        let refs: Vec<&DataSource> = sources.iter().collect();
        let avail = vec![node(0), node(1), node(2)];
        let plan = QueryExecutionEngine
            .plan(&refs, &avail, &PerfDb::default(), SchedulePolicy::PerfHistory)
            .unwrap();
        for n in plan.assignments.values() {
            assert_eq!(n.len(), 4, "uniform speeds => equal split: {plan:?}");
        }
    }
}
