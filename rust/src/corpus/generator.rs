//! Deterministic synthetic publication generator.
//!
//! Structure (per ARCHITECTURE.md §Substitutions):
//! * a domain vocabulary of real CS stems plus generated filler words,
//!   drawn Zipfian so term frequencies match natural text structure;
//! * `num_topics` topic distributions; each document mixes 1–3 topics,
//!   which gives the corpus the clustered co-occurrence structure real
//!   repositories have (queries hit topically-related subsets);
//! * an author pool with power-law productivity, venue pool, year range.
//!
//! `generate(i)` is pure in (spec.seed, i): any node can materialize any
//! document without coordination — this is how shards are "distributed"
//! to simulated grid nodes without copying a corpus around.

use super::record::Publication;
use crate::util::rng::{Rng, Zipf};

/// Corpus shape parameters.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub seed: u64,
    pub num_docs: u64,
    /// Domain vocabulary size (per-corpus; Zipfian draws).
    pub vocab_size: usize,
    /// Topic count for the mixture model.
    pub num_topics: usize,
    /// Author pool size.
    pub num_authors: usize,
    /// Venue pool size.
    pub num_venues: usize,
    /// Publication year range (inclusive).
    pub year_min: u32,
    pub year_max: u32,
    /// Mean abstract length in tokens (Poisson).
    pub abstract_len_mean: f64,
    /// Mean title length in tokens (Poisson, min 3).
    pub title_len_mean: f64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            seed: 0xA11CE,
            num_docs: 10_000,
            vocab_size: 20_000,
            num_topics: 64,
            num_authors: 4_000,
            num_venues: 120,
            year_min: 1995,
            year_max: 2014,
            abstract_len_mean: 90.0,
            title_len_mean: 8.0,
        }
    }
}

/// Seed CS stems mixed into the vocabulary head so queries look natural.
const DOMAIN_STEMS: &[&str] = &[
    "grid", "search", "technique", "massive", "academic", "publication", "distributed",
    "data", "computing", "resource", "query", "node", "service", "index", "cluster",
    "parallel", "scheduling", "broker", "virtual", "organization", "repository",
    "metadata", "retrieval", "ranking", "scalable", "latency", "throughput", "cache",
    "network", "storage", "replication", "federation", "middleware", "workflow",
    "semantic", "ontology", "crawler", "harvest", "corpus", "keyword", "relevance",
    "efficiency", "speedup", "baseline", "benchmark", "simulation", "algorithm",
    "optimization", "partition", "shard",
];

const FIRST_NAMES: &[&str] = &[
    "mohammed", "shafie", "ahmed", "fatima", "wei", "li", "ana", "carlos", "ivan",
    "olga", "raj", "priya", "kenji", "yuki", "sven", "ingrid", "omar", "leila",
    "john", "mary", "pierre", "claire", "abdul", "chen",
];

const LAST_NAMES: &[&str] = &[
    "bashir", "latiff", "abdulhamid", "loon", "zhang", "wang", "garcia", "santos",
    "petrov", "ivanova", "sharma", "patel", "tanaka", "sato", "larsson", "berg",
    "hassan", "rahman", "smith", "jones", "dubois", "martin", "aziz", "lin",
];

const VENUE_WORDS: &[&str] = &[
    "international", "conference", "journal", "workshop", "symposium", "transactions",
    "letters", "proceedings", "forum", "congress",
];

/// Deterministic publication generator (pure in (seed, doc id)).
#[derive(Debug, Clone)]
pub struct CorpusGenerator {
    spec: CorpusSpec,
    vocab: Vec<String>,
    /// topic -> word ranks biased into a topic-specific region.
    topic_offsets: Vec<usize>,
    word_zipf: Zipf,
    author_zipf: Zipf,
    venue_names: Vec<String>,
}

impl CorpusGenerator {
    pub fn new(spec: CorpusSpec) -> Self {
        assert!(spec.vocab_size > 100, "vocabulary too small");
        assert!(spec.num_topics > 0 && spec.num_venues > 0 && spec.num_authors > 0);
        assert!(spec.year_min <= spec.year_max);
        let mut rng = Rng::new(spec.seed);

        // Vocabulary: domain stems first (the Zipf head), then generated
        // filler words w_<n> with random consonant-vowel shapes.
        let mut vocab: Vec<String> =
            DOMAIN_STEMS.iter().map(|s| s.to_string()).collect();
        let consonants = b"bcdfghklmnprstvz";
        let vowels = b"aeiou";
        while vocab.len() < spec.vocab_size {
            let syllables = rng.range(2, 5);
            let mut w = String::new();
            for _ in 0..syllables {
                w.push(consonants[rng.range(0, consonants.len())] as char);
                w.push(vowels[rng.range(0, vowels.len())] as char);
            }
            vocab.push(w);
        }

        // Topics bias draws into a contiguous vocab region per topic.
        let topic_offsets: Vec<usize> = (0..spec.num_topics)
            .map(|_| rng.range(0, spec.vocab_size))
            .collect();

        // Venue names: 2–3 venue words + a domain stem.
        let mut venue_names = Vec::with_capacity(spec.num_venues);
        for _ in 0..spec.num_venues {
            let mut parts = vec![
                VENUE_WORDS[rng.range(0, VENUE_WORDS.len())].to_string(),
                DOMAIN_STEMS[rng.range(0, DOMAIN_STEMS.len())].to_string(),
            ];
            if rng.chance(0.5) {
                parts.insert(0, VENUE_WORDS[rng.range(0, VENUE_WORDS.len())].to_string());
            }
            venue_names.push(parts.join(" "));
        }

        CorpusGenerator {
            word_zipf: Zipf::new(spec.vocab_size, 1.07),
            author_zipf: Zipf::new(spec.num_authors, 1.2),
            spec,
            vocab,
            topic_offsets,
            venue_names,
        }
    }

    pub fn spec(&self) -> &CorpusSpec {
        &self.spec
    }

    /// Total number of documents in the corpus.
    pub fn len(&self) -> u64 {
        self.spec.num_docs
    }

    pub fn is_empty(&self) -> bool {
        self.spec.num_docs == 0
    }

    /// Draw one word for a topic: Zipfian rank shifted into the topic's
    /// vocab region (wrapping), which concentrates co-occurrence.
    fn topic_word(&self, rng: &mut Rng, topic: usize) -> &str {
        let rank = self.word_zipf.sample(rng);
        let idx = (self.topic_offsets[topic] + rank) % self.vocab.len();
        &self.vocab[idx]
    }

    fn gen_text(&self, rng: &mut Rng, topics: &[usize], len: usize) -> String {
        let mut words = Vec::with_capacity(len);
        for _ in 0..len {
            let t = topics[rng.range(0, topics.len())];
            words.push(self.topic_word(rng, t).to_string());
        }
        words.join(" ")
    }

    fn author_name(&self, author_id: usize) -> String {
        // Pure in author_id: derive name parts from a hash of the id.
        let mut r = Rng::new(self.spec.seed ^ (author_id as u64).wrapping_mul(0x9E37));
        format!(
            "{} {}",
            FIRST_NAMES[r.range(0, FIRST_NAMES.len())],
            LAST_NAMES[r.range(0, LAST_NAMES.len())],
        )
    }

    /// Generate document `i` (pure in (seed, i); 0 <= i < num_docs).
    pub fn generate(&self, i: u64) -> Publication {
        assert!(i < self.spec.num_docs, "doc id {i} out of range");
        let mut rng = Rng::new(self.spec.seed).fork(i.wrapping_add(1));

        // 1–3 topics per document.
        let k = 1 + rng.below(3) as usize;
        let topics: Vec<usize> =
            (0..k).map(|_| rng.range(0, self.spec.num_topics)).collect();

        let title_len = (rng.poisson(self.spec.title_len_mean).max(3)) as usize;
        let abstract_len = (rng.poisson(self.spec.abstract_len_mean).max(10)) as usize;
        let title = self.gen_text(&mut rng, &topics, title_len);
        let abstract_text = self.gen_text(&mut rng, &topics, abstract_len);

        let n_authors = 1 + rng.below(4) as usize;
        let authors = (0..n_authors)
            .map(|_| self.author_name(self.author_zipf.sample(&mut rng)))
            .collect::<Vec<_>>()
            .join(", ");

        let venue = self.venue_names[rng.range(0, self.venue_names.len())].clone();
        let year =
            self.spec.year_min + rng.below((self.spec.year_max - self.spec.year_min + 1) as u64) as u32;

        Publication { id: i, title, abstract_text, authors, venue, year }
    }

    /// Generate a contiguous shard [start, start+count).
    pub fn generate_range(&self, start: u64, count: u64) -> Vec<Publication> {
        (start..start + count).map(|i| self.generate(i)).collect()
    }

    /// A realistic query for this corpus: 1–4 words drawn from a random
    /// document's topical region (so queries actually match documents).
    pub fn sample_query(&self, rng: &mut Rng) -> String {
        let topic = rng.range(0, self.spec.num_topics);
        let n = rng.range(1, 5);
        (0..n)
            .map(|_| self.topic_word(rng, topic).to_string())
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> CorpusSpec {
        CorpusSpec {
            num_docs: 200,
            vocab_size: 2_000,
            num_topics: 8,
            num_authors: 100,
            num_venues: 10,
            ..CorpusSpec::default()
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let g1 = CorpusGenerator::new(small_spec());
        let g2 = CorpusGenerator::new(small_spec());
        for i in [0u64, 7, 99, 199] {
            assert_eq!(g1.generate(i), g2.generate(i));
        }
    }

    #[test]
    fn different_docs_differ() {
        let g = CorpusGenerator::new(small_spec());
        let a = g.generate(0);
        let b = g.generate(1);
        assert_ne!(a.title, b.title);
        assert_eq!(a.id, 0);
        assert_eq!(b.id, 1);
    }

    #[test]
    fn different_seeds_differ() {
        let mut spec2 = small_spec();
        spec2.seed = 999;
        let a = CorpusGenerator::new(small_spec()).generate(5);
        let b = CorpusGenerator::new(spec2).generate(5);
        assert_ne!(a.title, b.title);
    }

    #[test]
    fn fields_are_populated_and_year_in_range() {
        let g = CorpusGenerator::new(small_spec());
        for i in 0..50 {
            let p = g.generate(i);
            assert!(!p.title.is_empty());
            assert!(p.abstract_text.split_whitespace().count() >= 10);
            assert!(!p.authors.is_empty());
            assert!(!p.venue.is_empty());
            assert!((1995..=2014).contains(&p.year));
        }
    }

    #[test]
    fn range_generation_matches_pointwise() {
        let g = CorpusGenerator::new(small_spec());
        let shard = g.generate_range(10, 5);
        assert_eq!(shard.len(), 5);
        for (off, p) in shard.iter().enumerate() {
            assert_eq!(*p, g.generate(10 + off as u64));
        }
    }

    #[test]
    fn vocabulary_is_zipfian_in_documents() {
        // Most frequent word across docs should dominate the tail.
        let g = CorpusGenerator::new(small_spec());
        let mut counts = std::collections::HashMap::<String, usize>::new();
        for i in 0..100 {
            for w in g.generate(i).abstract_text.split_whitespace() {
                *counts.entry(w.to_string()).or_default() += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        assert!(freqs[0] >= 5 * freqs[freqs.len() / 2].max(1), "head {} not dominant", freqs[0]);
    }

    #[test]
    fn queries_hit_the_corpus() {
        // A topical query should match at least one document by substring
        // of some field (weak check; retrieval tests do this properly).
        let g = CorpusGenerator::new(small_spec());
        let mut rng = Rng::new(1);
        let mut hits = 0;
        for _ in 0..20 {
            let q = g.sample_query(&mut rng);
            let w = q.split_whitespace().next().unwrap().to_string();
            for i in 0..200 {
                let p = g.generate(i);
                if p.title.contains(&w) || p.abstract_text.contains(&w) {
                    hits += 1;
                    break;
                }
            }
        }
        assert!(hits >= 15, "only {hits}/20 queries matched anything");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_panics() {
        CorpusGenerator::new(small_spec()).generate(200);
    }
}
