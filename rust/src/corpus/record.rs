//! Publication record: the unit of data GAPS searches.

use crate::text::Field;
use crate::util::json::Json;

/// One academic publication (open-access metadata record).
#[derive(Debug, Clone, PartialEq)]
pub struct Publication {
    /// Global document id (unique across the whole corpus).
    pub id: u64,
    pub title: String,
    pub abstract_text: String,
    /// "First Last, First Last, ..." author list.
    pub authors: String,
    pub venue: String,
    pub year: u32,
}

impl Publication {
    /// Field accessor in ABI order.
    pub fn field_text(&self, field: Field) -> &str {
        match field {
            Field::Title => &self.title,
            Field::Abstract => &self.abstract_text,
            Field::Authors => &self.authors,
            Field::Venue => &self.venue,
        }
    }

    /// Serialize to a JSON object (the on-disk / JDF-result format).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::from(self.id)),
            ("title", Json::str(&self.title)),
            ("abstract", Json::str(&self.abstract_text)),
            ("authors", Json::str(&self.authors)),
            ("venue", Json::str(&self.venue)),
            ("year", Json::from(self.year as i64)),
        ])
    }

    /// Parse from the JSON object form.
    pub fn from_json(v: &Json) -> Option<Publication> {
        Some(Publication {
            id: v.get("id")?.as_i64()? as u64,
            title: v.get("title")?.as_str()?.to_string(),
            abstract_text: v.get("abstract")?.as_str()?.to_string(),
            authors: v.get("authors")?.as_str()?.to_string(),
            venue: v.get("venue")?.as_str()?.to_string(),
            year: v.get("year")?.as_i64()? as u32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Publication {
        Publication {
            id: 42,
            title: "Grid-based Search".into(),
            abstract_text: "We search massive publications.".into(),
            authors: "Mohammed Bashir, Shafie Latiff".into(),
            venue: "CS.DC".into(),
            year: 2014,
        }
    }

    #[test]
    fn json_roundtrip() {
        let p = sample();
        let v = p.to_json();
        assert_eq!(Publication::from_json(&v), Some(p));
    }

    #[test]
    fn field_accessor_order() {
        let p = sample();
        assert_eq!(p.field_text(Field::Title), "Grid-based Search");
        assert_eq!(p.field_text(Field::Venue), "CS.DC");
    }

    #[test]
    fn from_json_rejects_malformed() {
        let v = Json::parse(r#"{"id": 1, "title": "x"}"#).unwrap();
        assert_eq!(Publication::from_json(&v), None);
    }
}
