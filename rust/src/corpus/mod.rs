//! Synthetic academic-publication corpus.
//!
//! The paper's datasets are "articles collected from different academic
//! repositories ... open access information about the articles", scaling
//! to ~10M records — data we do not have, so this module synthesizes an
//! equivalent workload (ARCHITECTURE.md §Substitutions): Zipfian vocabulary,
//! topic-mixture titles/abstracts, an author pool with power-law
//! productivity, venue pools and a year range. Everything is derived
//! deterministically from a seed, so corpora are reproducible and can be
//! regenerated shard-by-shard on each simulated node without shipping
//! gigabytes around.

mod generator;
mod record;

pub use generator::{CorpusGenerator, CorpusSpec};
pub use record::Publication;
